//! End-to-end driver: pre-train a ~90M-parameter LLaMA-architecture
//! transformer with MISA for a few hundred steps on the synthetic
//! corpus, logging the loss curve — the full-system validation required
//! by DESIGN.md (all three layers composing: Rust coordinator → AOT XLA
//! graph → Pallas kernels).
//!
//! ```bash
//! make artifacts && cargo run --release --example pretrain_e2e [steps]
//! ```
//!
//! Results are written to results/e2e_loss.txt and recorded in
//! EXPERIMENTS.md.

use std::path::Path;

use misa::config::{DataSpec, MethodSpec, RunConfig};
use misa::coordinator::Trainer;
use misa::optim::sampler::{SamplerConfig, Strategy};
use misa::optim::MisaConfig;
use misa::runtime::Engine;
use misa::util::metrics::write_report;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let mut engine = Engine::new(Path::new("artifacts"))?;
    let cfg = RunConfig {
        model: "e2e".into(),
        method: MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig {
                strategy: Strategy::Importance { eta: 300.0 },
                delta: 0.25,
                ..Default::default()
            },
            t_inner: 50,
            pretrain: true,
            ..Default::default()
        }),
        data: DataSpec::Lm,
        lr: 1e-3,
        steps,
        pretrain: true,
        log_every: 1,
        seed: 0,
        ..Default::default()
    };
    let mut t = Trainer::new(&mut engine, cfg)?;
    let params = t.sess.spec.total_params();
    println!(
        "e2e pre-training: {:.1}M params, {} modules, {} steps, MISA(d=25%)",
        params as f64 / 1e6,
        t.sess.spec.matrix_module_indices().len(),
        steps
    );
    let t0 = std::time::Instant::now();
    let mut curve = String::from("# step wall_s train_loss val_loss ppl\n");
    let chunk = 25u64.min(steps);
    let mut done = 0;
    while done < steps {
        let n = chunk.min(steps - done);
        t.run(n)?;
        done += n;
        let e = t.evaluate(2)?;
        let train_loss = t.metrics.last("train_loss").unwrap_or(f64::NAN);
        let line = format!(
            "{done} {:.1} {train_loss:.4} {:.4} {:.3}",
            t0.elapsed().as_secs_f64(),
            e.loss,
            e.ppl
        );
        println!("{line}");
        curve.push_str(&line);
        curve.push('\n');
    }
    let (fb, op) = t.avg_times_ms();
    curve.push_str(&format!(
        "# avg per-step: fwd+bwd {fb:.1} ms, optimizer {op:.1} ms; total {:.1}s\n\
         # sim-peak {:.3} GiB\n",
        t0.elapsed().as_secs_f64(),
        misa::util::gib(t.alloc.peak_bytes())
    ));
    write_report(Path::new("results/e2e_loss.txt"), &curve)?;
    println!("\nloss curve written to results/e2e_loss.txt");
    println!("avg per-step: fwd+bwd {fb:.1} ms, optimizer {op:.1} ms");
    Ok(())
}
