//! Memory-analysis walkthrough: the Appendix-E closed forms at paper
//! scale — regenerates the data behind Fig. 2 and Fig. 5 and checks the
//! Lemma 4/5/6 crossover thresholds.
//!
//! ```bash
//! cargo run --release --example memory_analysis
//! ```
//!
//! Pure analytical computation — no artifacts needed.

use misa::memory::{self, Arch, Method, Workload};

fn main() {
    let arch = Arch::llama3_8b();

    println!("== Fig. 2: peak memory vs sequence length (LLaMA3-8B, b=4) ==");
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}", "seq", "LoRA(r=16)", "MISA(1%)", "MISA(3%)", "layerwise");
    for s in [256u64, 512, 1024, 2048, 4096, 8192, 16384] {
        let w = Workload::new(4, s);
        let gib = |e: u64| e as f64 * 4.0 / (1u64 << 30) as f64;
        println!(
            "{s:>8} {:>11.1}G {:>11.1}G {:>11.1}G {:>11.1}G",
            gib(memory::lora_peak_all(&arch, &w, 16)),
            gib(memory::misa_peak(&arch, &w, 0.01)),
            gib(memory::misa_peak(&arch, &w, 0.03)),
            gib(memory::layerwise_peak(&arch, &w)),
        );
    }

    println!("\n== Lemma 4: MISA beats layer-wise when δ below threshold ==");
    for s in [512u64, 2048, 8192] {
        let w = Workload::new(4, s);
        println!(
            "  s={s:<6} δ* = {:.4}  (1/L = {:.4})",
            memory::lemma4_delta_threshold(&arch, &w),
            1.0 / arch.l as f64
        );
    }

    println!("\n== Lemma 5: layer-wise beats LoRA beyond sequence threshold ==");
    for r in [8u64, 16, 32] {
        println!("  r={r:<3} s* = {:.0}", memory::lemma5_seq_threshold(&arch, 4, r));
    }

    println!("\n== Lemma 6: params-per-byte, layer-wise vs LoRA (s=2048) ==");
    let w = Workload::new(4, 2048);
    for r in [8u64, 16, 32] {
        println!(
            "  r={r:<3} layerwise {:.3e}  lora {:.3e}  (h>3rL/2: {})",
            memory::layerwise_params_per_mem(&arch, &w),
            memory::lora_params_per_mem(&arch, &w, r),
            memory::lemma6_holds(&arch, r)
        );
    }

    println!("\n== Fig. 5: 8B vs 70B, dense vs flash attention (s=8192) ==");
    for (tag, a) in [("8B", Arch::llama3_8b()), ("70B", Arch::llama3_70b())] {
        for flash in [false, true] {
            let w = if flash { Workload::flash(4, 8192) } else { Workload::new(4, 8192) };
            let gib = |e: u64| e as f64 * 4.0 / (1u64 << 30) as f64;
            println!(
                "  {tag} flash={flash:<5} LoRA {:>8.1}G  MISA(3%) {:>8.1}G",
                gib(memory::lora_peak_all(&a, &w, 16)),
                gib(memory::misa_peak(&a, &w, 0.03)),
            );
        }
    }

    println!("\n== Table 1 'Mem.(GB)' column @ b=4, s=512 ==");
    let w = Workload::new(4, 512);
    for m in [
        Method::FullFT,
        Method::Lora { r: 32 },
        Method::Dora { r: 16 },
        Method::Lisa,
        Method::BAdam,
        Method::Misa { delta: 0.01 },
        Method::Misa { delta: 0.03 },
    ] {
        println!("  {:<14} {:>7.1} GB", m.label(), memory::table_peak_gib(m, &arch, &w));
    }
}
