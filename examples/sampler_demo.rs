//! Importance-sampler walkthrough: Eq. 4 EMA tracking, Prop. 1 softmax,
//! Algorithm 2 δ-budget selection, and the exploration/exploitation
//! behaviour of η — on a synthetic module population, no model needed.
//!
//! ```bash
//! cargo run --release --example sampler_demo
//! ```

use misa::optim::sampler::{ImportanceSampler, SamplerConfig, ScoreFn, Strategy};
use misa::util::Rng;

fn main() {
    // 28 modules shaped like the `small` config: 7 kinds × 4 layers,
    // FFN modules ~2.7× larger than attention ones (as in LLaMA).
    let numel: Vec<u64> = (0..28)
        .map(|i| if i % 7 >= 4 { 44_032 } else { 16_384 })
        .collect();
    let n_model: u64 = numel.iter().sum::<u64>() + 2 * 512 * 128; // + embed/head

    for eta in [0.0, 1.0, 10.0] {
        let mut s = ImportanceSampler::new(
            SamplerConfig {
                strategy: Strategy::Importance { eta },
                score_fn: ScoreFn::GradNorm,
                beta: 0.9,
                delta: 0.05,
            },
            numel.clone(),
            n_model,
        );
        // plant a skewed importance profile: deeper layers matter more,
        // FFN kinds matter more (the paper's Fig. 1 shape)
        for i in 0..28 {
            let layer = i / 7;
            let kind = i % 7;
            let score = 0.1 + 0.2 * layer as f64 + if kind >= 4 { 0.5 } else { 0.0 };
            s.update_score(i, score);
        }
        let mut rng = Rng::new(42);
        let mut hist = vec![0u64; 28];
        for _ in 0..500 {
            for m in s.select(&mut rng) {
                hist[m] += 1;
            }
        }
        println!("η = {eta}");
        let p = s.probabilities();
        println!("  min p = {:.4}, max p = {:.4} (lower bound {:.4})",
                 p.iter().cloned().fold(f64::MAX, f64::min),
                 p.iter().cloned().fold(f64::MIN, f64::max),
                 s.probability_lower_bound());
        print!("  sample counts by module: ");
        for (i, h) in hist.iter().enumerate() {
            if i % 7 == 0 {
                print!("| ");
            }
            print!("{h:>4} ");
        }
        println!("|");
        let attn: u64 = hist.iter().enumerate().filter(|(i, _)| i % 7 < 4).map(|(_, &h)| h).sum();
        let ffn: u64 = hist.iter().enumerate().filter(|(i, _)| i % 7 >= 4).map(|(_, &h)| h).sum();
        println!("  attention picks: {attn}, ffn picks: {ffn}\n");
    }
    println!("note: η=0 is uniform; large η concentrates on the planted\n\
              high-importance modules while never starving the rest\n\
              (Corollary 1 lower bound) — the Table 10 story.");
}
