//! Quickstart: fine-tune a small LLaMA-style model with MISA.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole public API surface: engine + session creation,
//! the MISA optimizer (Algorithm 1), per-task exact-match evaluation and
//! the simulated-memory ledger.

use std::path::Path;

use misa::config::{DataSpec, MethodSpec, RunConfig};
use misa::coordinator::Trainer;
use misa::data::TaskKind;
use misa::optim::sampler::{SamplerConfig, Strategy};
use misa::optim::MisaConfig;
use misa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new(Path::new("artifacts"))?;
    let cfg = RunConfig {
        model: "small".into(),
        method: MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig {
                strategy: Strategy::Importance { eta: 1.0 },
                delta: 0.05,
                ..Default::default()
            },
            t_inner: 25,
            ..Default::default()
        }),
        data: DataSpec::Math,
        lr: 1e-3,
        steps: 300,
        log_every: 25,
        seed: 0,
        ..Default::default()
    };
    let mut t = Trainer::new(&mut engine, cfg)?;
    println!("training {} with {} …", t.sess.spec.config.name, t.opt.name());
    for round in 0..6 {
        t.run(50)?;
        let e = t.evaluate(4)?;
        println!(
            "step {:>4}  val_loss {:.4}  exact-match {:>5.1}%",
            (round + 1) * 50,
            e.loss,
            e.accuracy * 100.0
        );
    }
    println!("\nper-task accuracy:");
    for (kind, acc) in t.eval_per_task(&TaskKind::MATH, 8)? {
        println!("  {:<6} {:>5.1}%", kind.name(), acc * 100.0);
    }
    println!("\nsimulated device-memory ledger:\n{}", t.alloc.summary());
    let (fb, op) = t.avg_times_ms();
    println!("avg per-step: fwd+bwd {fb:.1} ms, optimizer {op:.1} ms");
    Ok(())
}
