//! `cargo bench --bench hotpath` — micro-benchmarks of the training hot
//! path: backend fwd/bwd execution, fused-Adam entry point vs host loop,
//! sampler selection, host linear algebra. These are the §Perf
//! measurements recorded in EXPERIMENTS.md.
//!
//! Runs entirely on the default host backend — no artifacts needed.

use std::time::Instant;

use misa::data::{Loader, TaskKind};
use misa::optim::sampler::{ImportanceSampler, SamplerConfig};
use misa::optim::{AdamHyper, AdamState};
use misa::runtime::{Engine, Session};
use misa::tensor::{matmul, range_finder, Mat};
use misa::util::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per >= 1.0 {
        format!("{per:.2} s")
    } else if per >= 1e-3 {
        format!("{:.2} ms", per * 1e3)
    } else {
        format!("{:.2} µs", per * 1e6)
    };
    println!("{name:<44} {unit:>12}/iter  ({iters} iters)");
}

fn main() -> anyhow::Result<()> {
    let threads = misa::tensor::threads();
    println!("== hot-path micro-benchmarks (threads={threads}) ==");

    // ---- L3 host primitives (no artifacts needed) ----------------------
    let mut rng = Rng::new(0);
    let a = Mat::randn(128, 344, 1.0, &mut rng);
    let b = Mat::randn(344, 128, 1.0, &mut rng);
    bench("tensor: matmul 128x344 @ 344x128", 200, || {
        std::hint::black_box(matmul(&a, &b));
    });
    // blocked + parallel GEMM at a training-relevant shape: large
    // enough to engage the packed-panel tiling and the worker pool
    let ga = Mat::randn(512, 512, 1.0, &mut rng);
    let gb = Mat::randn(512, 512, 1.0, &mut rng);
    bench(
        &format!("tensor: blocked matmul 512^3 ({threads} thr)"),
        20,
        || {
            std::hint::black_box(matmul(&ga, &gb));
        },
    );
    // GEMM GFLOP/s sweep across the microkernel-relevant shapes: a
    // decode-sized projection (pool wake latency dominates), the
    // LM-head tall-skinny, and a tile-ragged shape (work stealing
    // rebalances the uneven tail). `misa bench --gemm` is the JSON
    // twin of this table.
    let simd = misa::tensor::simd_label();
    for (m, k, n, iters) in [(8usize, 256usize, 256usize, 2000), (64, 256, 1024, 200),
                             (97, 161, 133, 500)] {
        let sa = Mat::randn(m, k, 1.0, &mut rng);
        let sb = Mat::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let t0 = Instant::now();
        bench(
            &format!("tensor: gemm_nn {m}x{k}x{n} ({threads} thr, {simd})"),
            iters,
            || {
                std::hint::black_box(matmul(&sa, &sb));
            },
        );
        let per = t0.elapsed().as_secs_f64() / (iters + 1) as f64;
        println!("{:<44} {:>9.2} GFLOP/s", format!("  └ gemm_nn {m}x{k}x{n} throughput"),
                 flops / per / 1e9);
    }
    let g = Mat::randn(344, 128, 1.0, &mut rng);
    bench("tensor: range_finder r=16 (GaLore refresh)", 50, || {
        let mut r2 = Rng::new(1);
        std::hint::black_box(range_finder(&g, 16, &mut r2));
    });

    let mut st = AdamState::zeros(128 * 344);
    let mut p = vec![0.1f32; 128 * 344];
    let gv = vec![0.01f32; 128 * 344];
    bench("optim: host Adam step 128x344", 500, || {
        st.step(&mut p, &gv, 1e-3, AdamHyper::default());
    });

    let numel: Vec<u64> = (0..84).map(|i| 16_384 + (i % 7) as u64 * 4000).collect();
    let total: u64 = numel.iter().sum();
    let mut sampler = ImportanceSampler::new(
        SamplerConfig { delta: 0.03, ..Default::default() },
        numel,
        total * 2,
    );
    for i in 0..84 {
        sampler.update_score(i, (i as f64) * 0.01);
    }
    let mut srng = Rng::new(2);
    bench("sampler: Alg.2 select over 84 modules", 2000, || {
        std::hint::black_box(sampler.select(&mut srng));
    });
    bench("sampler: Prop.1 softmax over 84 modules", 20000, || {
        std::hint::black_box(sampler.probabilities());
    });

    // ---- backend execution (host backend, builtin registry) -------------
    let mut engine = Engine::host();
    for model in ["tiny", "small"] {
        let mut sess = Session::create(&mut engine, model, 0)?;
        let mc = sess.spec.config.clone();
        let mut loader = Loader::tasks(&TaskKind::ALL, mc.vocab, mc.batch, mc.seq_len, 1);
        let batch = loader.next_batch();
        bench(&format!("backend: fwd_bwd ({model})"), 20, || {
            std::hint::black_box(sess.fwd_bwd(&batch).unwrap());
        });
        bench(&format!("backend: predict ({model})"), 20, || {
            std::hint::black_box(sess.predict(&batch).unwrap());
        });
        // backend adam entry point vs bare host loop on the largest
        // module: this bench is host-only (Engine::host() above), and
        // on the host backend both paths run the same AdamState::step —
        // the pair measures Session/backend dispatch + moment-buffer
        // allocation overhead, nothing else
        let idx = *sess
            .spec
            .matrix_module_indices()
            .iter()
            .max_by_key(|&&i| sess.spec.params[i].numel())
            .unwrap();
        let n = sess.spec.params[idx].numel();
        let grad = vec![0.01f32; n];
        let m = vec![0.0f32; n];
        let v = vec![0.0f32; n];
        bench(&format!("backend: adam dispatch {n}-elem ({model})"), 50, || {
            std::hint::black_box(sess.adam_update(idx, &grad, &m, &v, 1e-3).unwrap());
        });
        let mut host_state = AdamState::zeros(n);
        let mut host_p = vec![0.1f32; n];
        bench(&format!("optim: bare Adam loop {n}-elem ({model})"), 200, || {
            host_state.step(&mut host_p, &grad, 1e-3, AdamHyper::default());
        });
    }
    Ok(())
}
