//! `cargo bench --bench serve` — serving-path benchmarks on the host
//! backend: prefill latency, per-token decode latency, single-stream
//! generation, and continuous-batching throughput at several
//! concurrency levels. Artifact-free (builtin registry, random init).
//!
//! The slot sweep measures batched decode: `slots = 1` decodes the
//! 8-request workload one stream at a time (the per-slot baseline),
//! while `slots = 8` runs the same workload through one batched
//! `decode_batch` forward per iteration — the aggregate tok/s ratio is
//! the batching win. The shared-prefix sweep measures prompt-cache
//! reuse: 8 requests behind one 64-token system prompt, with and
//! without the prefix cache — the mean TTFT ratio is the reuse win.
//! The speculative sweep runs a repeated-structure greedy workload
//! with self-drafting speculation off and on — the tok/s ratio is the
//! multi-token-per-forward win, reported next to the acceptance rate.
//! Honors `MISA_THREADS` (worker-pool width) and with `-- --json FILE`
//! writes both sweeps as a JSON **array** of records (one per
//! model x configuration point; the `misa bench-serve --json` CLI path
//! writes a single bare object).

use std::time::Instant;

use misa::runtime::{Engine, Session};
use misa::serve::{
    generate, CacheStoreCfg, GenerateCfg, Request, SamplerCfg, Scheduler, SchedulerCfg,
    SpecCfg,
};
use misa::util::{BenchRecord, Rng};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per >= 1.0 {
        format!("{per:.2} s")
    } else if per >= 1e-3 {
        format!("{:.2} ms", per * 1e3)
    } else {
        format!("{:.2} µs", per * 1e6)
    };
    println!("{name:<44} {unit:>12}/iter  ({iters} iters)");
}

fn prompt(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut p = vec![1i32];
    while p.len() < len {
        p.push(rng.range(32, vocab) as i32);
    }
    p
}

fn main() -> anyhow::Result<()> {
    let json_path = {
        let argv: Vec<String> = std::env::args().collect();
        argv.iter()
            .position(|a| a == "--json")
            .and_then(|i| argv.get(i + 1))
            .cloned()
    };
    let threads = misa::tensor::threads();
    println!("== serving benchmarks (host backend, builtin registry, threads={threads}) ==");
    let mut records: Vec<BenchRecord> = Vec::new();
    for model in ["tiny", "small"] {
        let mut eng = Engine::host();
        let sess = Session::create(&mut eng, model, 0)?;
        let vocab = sess.spec.config.vocab;
        let p16 = prompt(16, vocab, 1);

        bench(&format!("{model}: prefill 16 tokens"), 30, || {
            let mut cache = sess.kv_cache(16).unwrap();
            sess.prefill(&p16, &mut cache).unwrap();
        });

        let mut cache = sess.kv_cache(256)?;
        let mut logits = sess.prefill(&p16, &mut cache)?;
        bench(&format!("{model}: decode step (ctx ~16+)"), 100, || {
            let next = misa::serve::argmax(&logits) as i32;
            logits = sess.decode_step(next, cache.len(), &mut cache).unwrap();
        });

        bench(&format!("{model}: generate 32 greedy tokens"), 5, || {
            let cfg = GenerateCfg { max_new: 32, ..GenerateCfg::default() };
            generate(&sess, &p16, &cfg).unwrap();
        });

        // the acceptance sweep: 8 concurrent requests, per-slot
        // baseline (slots=1) vs truly batched decode (slots=8)
        let n_req = 8usize;
        let max_new = 24usize;
        let mut baseline_tok_s = 0.0f64;
        for slots in [1usize, 4, 8] {
            let t0 = Instant::now();
            let mut sched = Scheduler::new(SchedulerCfg {
                max_slots: slots,
                token_budget: 4096,
                // pinned off so a MISA_SPEC environment does not skew
                // the batching baseline untagged
                spec: None,
                ..SchedulerCfg::default()
            });
            for id in 0..n_req as u64 {
                sched.submit(Request {
                    id,
                    prompt: prompt(8, vocab, 2 + id),
                    max_new,
                    sampler: SamplerCfg { temperature: 0.8, top_k: 32, top_p: 0.95 },
                    seed: id,
                    eos: None,
                })?;
            }
            let done = sched.run(&sess)?;
            let wall = t0.elapsed().as_secs_f64();
            let toks: usize = done.iter().map(|c| c.tokens.len()).sum();
            let tok_s = toks as f64 / wall.max(1e-9);
            let ttft =
                done.iter().map(|c| c.ttft_s).sum::<f64>() / done.len() as f64 * 1e3;
            if slots == 1 {
                baseline_tok_s = tok_s;
            }
            let speedup = tok_s / baseline_tok_s.max(1e-9);
            println!(
                "{model}: bench-serve {n_req} reqs @ {slots} slots      \
                 {tok_s:>8.1} tok/s  mean ttft {ttft:.1} ms  ({speedup:.2}x vs 1 slot)",
            );
            records.push(
                BenchRecord::new("bench-serve")
                    .tag("model", model)
                    .tag("backend", sess.backend_name())
                    .tag("prefix_cache", "off")
                    .num("threads", threads as f64)
                    .num("requests", n_req as f64)
                    .num("slots", slots as f64)
                    .num("prompt_len", 8.0)
                    .num("max_new", max_new as f64)
                    .num("wall_s", wall)
                    .num("aggregate_tok_s", tok_s)
                    .num("mean_ttft_ms", ttft)
                    .num("speedup_vs_1_slot", speedup),
            );
        }

        // the prefix-sharing sweep: 8 requests behind one 64-token
        // system prompt, with and without the prompt cache — the mean
        // TTFT delta is the prefix-reuse win (the shared prefix is
        // prefilled once and forked, instead of 8 times)
        let shared = prompt(64, vocab, 77);
        let mut baseline_ttft = 0.0f64;
        for cache_on in [false, true] {
            let t0 = Instant::now();
            let mut sched = Scheduler::new(SchedulerCfg {
                max_slots: 4,
                token_budget: 4096,
                prefix_cache: cache_on.then(|| CacheStoreCfg {
                    capacity: 256,
                    max_entries: 16,
                    min_prefix: 8,
                }),
                // pinned off so a MISA_SPEC environment does not skew
                // the TTFT baseline untagged
                spec: None,
                ..SchedulerCfg::default()
            });
            for id in 0..n_req as u64 {
                let mut p = shared.clone();
                let mut rng = Rng::new(500 + id);
                for _ in 0..8 {
                    p.push(rng.range(32, vocab) as i32);
                }
                sched.submit(Request {
                    id,
                    prompt: p,
                    max_new,
                    sampler: SamplerCfg { temperature: 0.8, top_k: 32, top_p: 0.95 },
                    seed: id,
                    eos: None,
                })?;
            }
            let done = sched.run(&sess)?;
            let wall = t0.elapsed().as_secs_f64();
            let toks: usize = done.iter().map(|c| c.tokens.len()).sum();
            let tok_s = toks as f64 / wall.max(1e-9);
            let ttft =
                done.iter().map(|c| c.ttft_s).sum::<f64>() / done.len() as f64 * 1e3;
            let stats = sched.cache_stats().unwrap_or_default();
            if !cache_on {
                baseline_ttft = ttft;
            }
            println!(
                "{model}: shared-prefix {n_req} reqs, cache {}   \
                 {tok_s:>8.1} tok/s  mean ttft {ttft:.1} ms  ({:.2}x vs cold)  \
                 hit-rate {:.2}  reused {}",
                if cache_on { "on " } else { "off" },
                baseline_ttft / ttft.max(1e-9),
                stats.hit_rate(),
                stats.reused_tokens,
            );
            records.push(
                BenchRecord::new("bench-serve")
                    .tag("model", model)
                    .tag("backend", sess.backend_name())
                    .tag("prefix_cache", if cache_on { "on" } else { "off" })
                    .num("threads", threads as f64)
                    .num("requests", n_req as f64)
                    .num("slots", 4.0)
                    .num("prompt_len", 8.0)
                    .num("shared_prefix", 64.0)
                    .num("max_new", max_new as f64)
                    .num("wall_s", wall)
                    .num("aggregate_tok_s", tok_s)
                    .num("mean_ttft_ms", ttft)
                    .num("ttft_speedup_vs_cold", baseline_ttft / ttft.max(1e-9))
                    .num("cache_hit_rate", stats.hit_rate())
                    .num("cache_reused_tokens", stats.reused_tokens as f64),
            );
        }

        // the speculative sweep: 8 greedy requests over a
        // repeated-structure workload (each prompt cycles a 4-token
        // motif), decode off vs on — the aggregate tok/s ratio is the
        // multi-token-per-forward win, weighted by the acceptance rate
        let mut baseline_spec_tok_s = 0.0f64;
        for spec_on in [false, true] {
            let t0 = Instant::now();
            let mut sched = Scheduler::new(SchedulerCfg {
                max_slots: 4,
                token_budget: 4096,
                spec: spec_on.then(SpecCfg::default),
                ..SchedulerCfg::default()
            });
            for id in 0..n_req as u64 {
                let motif = prompt(5, vocab, 900 + id);
                let mut p = vec![1i32];
                for j in 0..23 {
                    p.push(motif[1 + j % 4]);
                }
                sched.submit(Request {
                    id,
                    prompt: p,
                    max_new,
                    sampler: SamplerCfg::greedy(),
                    seed: id,
                    eos: None,
                })?;
            }
            let done = sched.run(&sess)?;
            let wall = t0.elapsed().as_secs_f64();
            let toks: usize = done.iter().map(|c| c.tokens.len()).sum();
            let tok_s = toks as f64 / wall.max(1e-9);
            let st = sched.spec_stats().unwrap_or_default();
            if !spec_on {
                baseline_spec_tok_s = tok_s;
            }
            println!(
                "{model}: speculative {n_req} reqs, spec {}     \
                 {tok_s:>8.1} tok/s  ({:.2}x vs off)  drafted {}  accepted {}  \
                 acceptance {:.2}",
                if spec_on { "on " } else { "off" },
                tok_s / baseline_spec_tok_s.max(1e-9),
                st.drafted,
                st.accepted,
                st.acceptance_rate(),
            );
            records.push(
                BenchRecord::new("bench-serve")
                    .tag("model", model)
                    .tag("backend", sess.backend_name())
                    .tag("spec", if spec_on { "on" } else { "off" })
                    .num("threads", threads as f64)
                    .num("requests", n_req as f64)
                    .num("slots", 4.0)
                    .num("prompt_len", 24.0)
                    .num("max_new", max_new as f64)
                    .num("draft_len", if spec_on { 4.0 } else { 0.0 })
                    .num("wall_s", wall)
                    .num("aggregate_tok_s", tok_s)
                    .num("speedup_vs_no_spec", tok_s / baseline_spec_tok_s.max(1e-9))
                    .num("drafted_tokens", st.drafted as f64)
                    .num("accepted_tokens", st.accepted as f64)
                    .num("acceptance_rate", st.acceptance_rate()),
            );
        }
    }
    if let Some(path) = json_path {
        let rows: Vec<String> = records
            .iter()
            .map(|r| r.to_json().trim_end().to_string())
            .collect();
        std::fs::write(&path, format!("[\n{}\n]\n", rows.join(",\n")))?;
        println!("bench records written: {path}");
    }
    Ok(())
}
