//! `cargo bench --bench serve` — serving-path benchmarks on the host
//! backend: prefill latency, per-token decode latency, single-stream
//! generation, and continuous-batching throughput at several
//! concurrency levels. Artifact-free (builtin registry, random init).

use std::time::Instant;

use misa::runtime::{Engine, Session};
use misa::serve::{generate, GenerateCfg, Request, SamplerCfg, Scheduler, SchedulerCfg};
use misa::util::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per >= 1.0 {
        format!("{per:.2} s")
    } else if per >= 1e-3 {
        format!("{:.2} ms", per * 1e3)
    } else {
        format!("{:.2} µs", per * 1e6)
    };
    println!("{name:<44} {unit:>12}/iter  ({iters} iters)");
}

fn prompt(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut p = vec![1i32];
    while p.len() < len {
        p.push(rng.range(32, vocab) as i32);
    }
    p
}

fn main() -> anyhow::Result<()> {
    println!("== serving benchmarks (host backend, builtin registry) ==");
    for model in ["tiny", "small"] {
        let mut eng = Engine::host();
        let sess = Session::create(&mut eng, model, 0)?;
        let vocab = sess.spec.config.vocab;
        let p16 = prompt(16, vocab, 1);

        bench(&format!("{model}: prefill 16 tokens"), 30, || {
            let mut cache = sess.kv_cache(16).unwrap();
            sess.prefill(&p16, &mut cache).unwrap();
        });

        let mut cache = sess.kv_cache(256)?;
        let mut logits = sess.prefill(&p16, &mut cache)?;
        bench(&format!("{model}: decode step (ctx ~16+)"), 100, || {
            let next = misa::serve::argmax(&logits) as i32;
            logits = sess.decode_step(next, cache.len(), &mut cache).unwrap();
        });

        bench(&format!("{model}: generate 32 greedy tokens"), 5, || {
            let cfg = GenerateCfg { max_new: 32, ..GenerateCfg::default() };
            generate(&sess, &p16, &cfg).unwrap();
        });

        for slots in [1usize, 4] {
            let t0 = Instant::now();
            let mut sched =
                Scheduler::new(SchedulerCfg { max_slots: slots, token_budget: 4096 });
            let n_req = 8;
            let max_new = 24;
            for id in 0..n_req as u64 {
                sched.submit(Request {
                    id,
                    prompt: prompt(8, vocab, 2 + id),
                    max_new,
                    sampler: SamplerCfg { temperature: 0.8, top_k: 32, top_p: 0.95 },
                    seed: id,
                    eos: None,
                })?;
            }
            let done = sched.run(&sess)?;
            let wall = t0.elapsed().as_secs_f64();
            let toks: usize = done.iter().map(|c| c.tokens.len()).sum();
            let ttft =
                done.iter().map(|c| c.ttft_s).sum::<f64>() / done.len() as f64 * 1e3;
            println!(
                "{model}: bench-serve {n_req} reqs @ {slots} slots      \
                 {:>8.1} tok/s  mean ttft {ttft:.1} ms",
                toks as f64 / wall.max(1e-9),
            );
        }
    }
    Ok(())
}
