//! `cargo bench --bench paper_tables` — regenerates EVERY table and
//! figure of the paper's evaluation through the experiment harness
//! (fast profile). Reports land under `results/` and are echoed here.
//! Runs on the host backend (builtin registry) when `artifacts/` is
//! absent, so it works in a fresh offline checkout.
//!
//! criterion is not vendorable offline; this is a plain harness=false
//! bench binary, which also suits these end-to-end (minutes-long)
//! workloads better than statistical micro-benchmarking.

use std::path::Path;

use misa::coordinator::experiments::{registry, ExpCtx};
use misa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // `cargo bench -- <filter>` runs a subset
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let mut engine = Engine::new(Path::new("artifacts"))?;
    let mut ctx = ExpCtx::new(&mut engine, true);
    let mut failed = 0;
    for (name, f, desc) in registry() {
        if !filter.is_empty() && !filter.iter().any(|x| name.contains(x.as_str())) {
            continue;
        }
        let t0 = std::time::Instant::now();
        match f(&mut ctx) {
            Ok(body) => {
                println!(
                    "==== {name}: {desc} ({:.1}s) ====\n{body}\n",
                    t0.elapsed().as_secs_f64()
                );
            }
            Err(e) => {
                failed += 1;
                println!("==== {name} FAILED: {e:#} ====\n");
            }
        }
    }
    if failed > 0 {
        anyhow::bail!("{failed} experiments failed");
    }
    Ok(())
}
