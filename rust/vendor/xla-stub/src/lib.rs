//! Offline **stub** of the `xla` PJRT bindings.
//!
//! This crate exists so the `misa` coordinator's `pjrt` feature compiles
//! in environments where the real XLA/PJRT binding (and its C++
//! dependency closure) is not vendorable. It reproduces exactly the API
//! surface `misa::runtime::backend::pjrt` consumes; every operation that
//! would touch a real PJRT runtime returns an error at runtime instead.
//!
//! To run against a real PJRT build, replace this path dependency with
//! the real `xla` crate (same signatures) via a `[patch]` section or by
//! editing `rust/Cargo.toml`.

/// Stub error: carries a message explaining that no PJRT runtime exists.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime not available (offline `xla` stub); \
         link a real xla binding to use the pjrt backend"
    )))
}

/// Element types accepted by `buffer_from_host_buffer`.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// PJRT client handle (stub).
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Device-resident buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host-side literal value (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute_b(&[]).is_err());
        let lit = Literal;
        assert!(lit.to_vec::<f32>().is_err());
    }
}
