//! Machine-readable benchmark records (`--json out.json`).
//!
//! `misa bench-serve`, `misa bench` and the `cargo bench` harnesses
//! emit one flat JSON object per run so the perf trajectory is
//! diffable across PRs (`BENCH_serve.json` at the repo root is the
//! committed sample). serde is not vendorable offline, so the writer
//! is hand-rolled at the ~40 lines this schema needs: string fields
//! first, then numeric fields, insertion-ordered.

use std::path::Path;

use anyhow::{Context, Result};

/// One benchmark run: identity strings plus `(name, value)` metrics.
#[derive(Clone, Debug, Default)]
pub struct BenchRecord {
    /// fields rendered as JSON strings, e.g. `("bin", "bench-serve")`
    pub tags: Vec<(&'static str, String)>,
    /// fields rendered as JSON numbers, e.g. `("tok_s", 412.3)`
    pub nums: Vec<(&'static str, f64)>,
}

impl BenchRecord {
    pub fn new(bin: &str) -> Self {
        BenchRecord { tags: vec![("bin", bin.to_string())], nums: Vec::new() }
    }

    pub fn tag(mut self, key: &'static str, value: impl Into<String>) -> Self {
        self.tags.push((key, value.into()));
        self
    }

    pub fn num(mut self, key: &'static str, value: f64) -> Self {
        self.nums.push((key, value));
        self
    }

    /// Append a block of numeric fields at once (e.g. a stats struct
    /// flattened by the caller) — the same insertion-order semantics as
    /// chained [`Self::num`] calls.
    pub fn nums(mut self, kvs: &[(&'static str, f64)]) -> Self {
        self.nums.extend_from_slice(kvs);
        self
    }

    /// Render as a single JSON object. Non-finite numbers become
    /// `null` (JSON has no NaN/inf).
    pub fn to_json(&self) -> String {
        let mut parts = Vec::with_capacity(self.tags.len() + self.nums.len());
        for (k, v) in &self.tags {
            parts.push(format!("\"{k}\": \"{}\"", escape(v)));
        }
        for (k, v) in &self.nums {
            if v.is_finite() {
                parts.push(format!("\"{k}\": {v}"));
            } else {
                parts.push(format!("\"{k}\": null"));
            }
        }
        format!("{{\n  {}\n}}\n", parts.join(",\n  "))
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing bench record to {path:?}"))
    }
}

/// JSON string-escape (shared with the metrics JSONL writer and the
/// Chrome trace exporter — one escaping routine, one set of tests).
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_flat_json_object() {
        let r = BenchRecord::new("bench-serve")
            .tag("model", "tiny")
            .num("tok_s", 123.5)
            .nums(&[("threads", 4.0)])
            .num("bad", f64::NAN);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'), "{j}");
        assert!(j.contains("\"bin\": \"bench-serve\""), "{j}");
        assert!(j.contains("\"model\": \"tiny\""), "{j}");
        assert!(j.contains("\"tok_s\": 123.5"), "{j}");
        assert!(j.contains("\"threads\": 4"), "{j}");
        assert!(j.contains("\"bad\": null"), "{j}");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn roundtrips_to_disk() {
        let path = std::env::temp_dir().join(format!("misa_bench_{}.json", std::process::id()));
        BenchRecord::new("bench").num("steps", 5.0).write(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"steps\": 5"));
        let _ = std::fs::remove_file(&path);
    }
}
