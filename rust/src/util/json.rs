//! Minimal JSON parser — enough to re-read this tool's own emitted
//! artifacts (bench records, capacity fits) without external crates.
//!
//! The writer side ([`crate::util::BenchRecord`] and the capacity
//! model's hand-rolled emit) produces plain objects/arrays of numbers
//! and strings, so the parser covers exactly standard JSON: objects,
//! arrays, strings with escapes, `f64` numbers, `true`/`false`/`null`.
//! Key order is preserved (objects are association lists, not maps);
//! duplicate keys resolve to the first occurrence on [`Json::get`].

use anyhow::{anyhow, bail, ensure, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null` (the writer uses it for non-finite numbers).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, as `f64`.
    Num(f64),
    /// A string, escapes decoded.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as an association list in source order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Field lookup on an object (`None` on other variants or a
    /// missing key).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The boolean, if this is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required numeric field of an object, with a named error.
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing or non-numeric field {key:?}"))
    }

    /// Required string field of an object, with a named error.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing or non-string field {key:?}"))
    }

    /// Required array field of an object, with a named error.
    pub fn arr_field(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing or non-array field {key:?}"))
    }
}

/// Escape a string for embedding in emitted JSON — the writer-side
/// counterpart of the parser's escape decoding (one escaping routine
/// repo-wide, shared with [`crate::util::bench`]'s writers).
pub fn escape(s: &str) -> String {
    crate::util::bench::escape(s)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.keyword("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.keyword("null").map(|_| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected input at byte {}", self.pos),
        }
    }

    fn keyword(&mut self, kw: &str) -> Result<()> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(())
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number chars");
        let x: f64 = s.parse().map_err(|_| anyhow!("bad number {s:?} at byte {start}"))?;
        Ok(Json::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow!("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| anyhow!("non-ascii \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape {hex:?}"))?;
                            self.pos += 4;
                            // this tool's own artifacts never emit
                            // surrogate pairs; lone surrogates decode
                            // to the replacement character
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("unknown escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| anyhow!("invalid utf-8 in string"))?;
                    let ch = s.chars().next().expect("non-empty by construction");
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_documents() {
        let doc = r#"{"bin": "capacity", "n": -3.5e2, "ok": true, "miss": null,
                      "pts": [{"x": 1, "y": 2.5}, {"x": 2, "y": 5.0}],
                      "tags": ["a", "b"]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.str_field("bin").unwrap(), "capacity");
        assert_eq!(j.f64_field("n").unwrap(), -350.0);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("miss"), Some(&Json::Null));
        let pts = j.arr_field("pts").unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].f64_field("y").unwrap(), 5.0);
        assert_eq!(j.arr_field("tags").unwrap()[0].as_str(), Some("a"));
        assert!(j.get("absent").is_none());
        assert!(j.f64_field("bin").is_err(), "type mismatch must be an error");
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "line1\nline2\t\"quoted\" back\\slash";
        let doc = format!("{{\"s\": \"{}\"}}", escape(original));
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.str_field("s").unwrap(), original);
        // \u escapes decode
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1, 2", "{\"a\": }", "{\"a\": 1,}", "nul", "1 2", "{\"a\" 1}",
            "\"unterminated", "[1, 2]]",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn parses_a_bench_record_line() {
        // the exact shape BenchRecord::to_json emits (non-finite → null)
        let rec = crate::util::BenchRecord::new("t")
            .tag("mode", "fuzz")
            .num("ops", 2048.0)
            .num("bad", f64::NAN);
        let j = Json::parse(&rec.to_json()).unwrap();
        assert_eq!(j.str_field("mode").unwrap(), "fuzz");
        assert_eq!(j.f64_field("ops").unwrap(), 2048.0);
        assert_eq!(j.get("bad"), Some(&Json::Null));
    }
}
