//! Mini property-testing harness.
//!
//! `proptest` cannot be vendored in this offline environment, so this is
//! a deliberately small stand-in: run a property over N randomized cases
//! drawn from an explicit `Rng`, report the failing seed/case on panic.
//! Coordinator invariants (routing, batching, sampler state) are tested
//! through this harness — see the paper-invariant tests in
//! `optim::sampler`, `memory`, and `coordinator`.

use super::rng::Rng;

/// Number of cases per property (kept modest: each case may build a
/// sampler or allocator).
pub const DEFAULT_CASES: usize = 200;

/// Run `f` over `cases` randomized inputs. On failure the panic message
/// includes the case index and the master seed so the case replays.
pub fn check<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: usize, mut f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Shorthand macro so property tests read like proptest blocks:
/// `prop!(name, |rng| { ... });`
#[macro_export]
macro_rules! prop {
    ($name:expr, |$rng:ident| $body:block) => {
        $crate::util::prop::check($name, 0xC0FFEE, $crate::util::prop::DEFAULT_CASES, |$rng| {
            let $rng = $rng;
            $body
        })
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 1, 50, |_| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        check("fails", 1, 50, |rng| {
            assert!(rng.f64() < 0.9, "drew a large value");
        });
    }

    #[test]
    fn prop_macro_compiles() {
        prop!("macro", |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }
}
