//! Mini property-testing harness.
//!
//! `proptest` cannot be vendored in this offline environment, so this is
//! a deliberately small stand-in: run a property over N randomized cases
//! drawn from an explicit `Rng`, report the failing seed/case on panic.
//! Coordinator invariants (routing, batching, sampler state) are tested
//! through this harness — see the paper-invariant tests in
//! `optim::sampler`, `memory`, and `coordinator`.
//!
//! Seed and case count are overridable from the environment so a CI
//! failure reproduces locally in one copy-paste: `MISA_PROP_SEED`
//! (decimal or `0x…` hex) replaces the property's built-in seed and
//! `MISA_PROP_CASES` the case count. On failure the panic message
//! includes exactly that replay command, pre-filled with the failing
//! case's derived seed so it replays as case 0 of a 1-case run.

use super::rng::Rng;

/// Number of cases per property (kept modest: each case may build a
/// sampler or allocator).
pub const DEFAULT_CASES: usize = 200;

/// Multiplier deriving each case's RNG seed from the master seed
/// (golden-ratio stride, the same constant `Rng::fork` uses).
const CASE_STRIDE: u64 = 0x9E3779B97F4A7C15;

/// Parse an environment variable as `u64`, accepting decimal or `0x…`
/// hex. Unset or empty yields `None`; a malformed value panics (a typo
/// must not silently run a different seed than the one on screen).
pub fn env_u64(name: &str) -> Option<u64> {
    let raw = std::env::var(name).ok()?;
    let s = raw.trim();
    if s.is_empty() {
        return None;
    }
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse(),
    };
    match parsed {
        Ok(v) => Some(v),
        Err(_) => panic!("{name}={raw:?} is not a u64 (decimal or 0x-hex)"),
    }
}

/// Run `f` over `cases` randomized inputs, honoring the
/// `MISA_PROP_SEED` / `MISA_PROP_CASES` environment overrides. On
/// failure the panic message includes the case index, the master seed,
/// and a one-line replay command.
pub fn check<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: usize, f: F) {
    let seed = env_u64("MISA_PROP_SEED").unwrap_or(seed);
    let cases = env_u64("MISA_PROP_CASES").map(|c| c as usize).unwrap_or(cases);
    check_with(name, seed, cases, f)
}

/// [`check`] without the environment overrides — the deterministic core
/// (used by the harness's own self-tests, which must not change shape
/// when a user exports `MISA_PROP_*` globally).
pub fn check_with<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: usize, mut f: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(CASE_STRIDE);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // case_seed ^ 0*STRIDE == case_seed: the failing case replays
            // as case 0 of a 1-case run under these env overrides
            panic!(
                "property {name:?} failed at case {case} (seed {seed}): {msg}\n  \
                 replay: MISA_PROP_SEED={case_seed:#x} MISA_PROP_CASES=1 cargo test {name}"
            );
        }
    }
}

/// Shorthand macro so property tests read like proptest blocks:
/// `prop!(name, |rng| { ... });`
#[macro_export]
macro_rules! prop {
    ($name:expr, |$rng:ident| $body:block) => {
        $crate::util::prop::check($name, 0xC0FFEE, $crate::util::prop::DEFAULT_CASES, |$rng| {
            let $rng = $rng;
            $body
        })
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check_with("count", 1, 50, |_| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        check_with("fails", 1, 50, |rng| {
            assert!(rng.f64() < 0.9, "drew a large value");
        });
    }

    #[test]
    fn failure_message_carries_a_replay_command() {
        let got = std::panic::catch_unwind(|| {
            check_with("replayable", 7, 50, |rng| {
                assert!(rng.f64() < 0.5, "coin came up tails");
            });
        });
        let msg = match got {
            Ok(()) => panic!("a coin-flip property cannot pass 50 cases"),
            Err(e) => e.downcast_ref::<String>().cloned().unwrap(),
        };
        assert!(msg.contains("replay: MISA_PROP_SEED=0x"), "{msg}");
        assert!(msg.contains("MISA_PROP_CASES=1"), "{msg}");
        // the advertised seed really is the failing case's seed: running
        // one case with it must hit the same failure
        let seed_hex = msg.split("MISA_PROP_SEED=0x").nth(1).unwrap();
        let seed = u64::from_str_radix(seed_hex.split_whitespace().next().unwrap(), 16).unwrap();
        let replay = std::panic::catch_unwind(|| {
            check_with("replayable", seed, 1, |rng| {
                assert!(rng.f64() < 0.5, "coin came up tails");
            });
        });
        assert!(replay.is_err(), "replay seed {seed:#x} did not reproduce");
    }

    #[test]
    fn env_u64_parses_decimal_and_hex() {
        // set/remove env vars under a lock-free test harness: use
        // process-unique names so parallel tests cannot collide
        let name = format!("MISA_PROP_TEST_{}", std::process::id());
        assert_eq!(env_u64(&name), None);
        std::env::set_var(&name, "42");
        assert_eq!(env_u64(&name), Some(42));
        std::env::set_var(&name, "0xC0FFEE");
        assert_eq!(env_u64(&name), Some(0xC0FFEE));
        std::env::set_var(&name, "  ");
        assert_eq!(env_u64(&name), None);
        std::env::remove_var(&name);
    }

    #[test]
    fn prop_macro_compiles() {
        prop!("macro", |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }
}
