//! Small self-contained utilities: PRNG, metrics sink, property harness.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so `rand`, `serde`, `proptest` and friends are hand-rolled
//! here at the minimal size this project needs.

pub mod bench;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod rng;

pub use bench::BenchRecord;
pub use json::Json;
pub use metrics::MetricsSink;
pub use rng::Rng;

/// Format a byte count as GiB with two decimals (memory tables).
pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0)
}

/// Index of the largest value, first on ties — the argmax convention
/// shared by the predict graph and the greedy token sampler.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for j in 1..xs.len() {
        if xs[j] > xs[best] {
            best = j;
        }
    }
    best
}

/// Wall-clock seconds since an `Instant`.
pub fn secs_since(t: std::time::Instant) -> f64 {
    t.elapsed().as_secs_f64()
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Ordinary-least-squares slope of y against x (convergence-rate fits).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        num += (a - mx) * (b - my);
        den += (a - mx) * (a - mx);
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gib_converts() {
        assert!((gib(1 << 30) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_handles_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ols_slope_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-9);
    }
}
