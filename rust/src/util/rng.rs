//! Deterministic PRNG: xoshiro256** with a SplitMix64 seeder.
//!
//! Every stochastic component in the coordinator (module sampling, data
//! generation, parameter init) takes an explicit `Rng` so runs are
//! reproducible from a single seed — required for the paper-table
//! regeneration harness to be rerunnable.

/// xoshiro256** — fast, high-quality, tiny. Public-domain algorithm
/// (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift rejection-free mapping (bias negligible at n << 2^64)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a buffer with N(0, std^2) f32 samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for x in buf.iter_mut() {
            *x = (self.normal() as f32) * std;
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample over zero mass");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf(s) sample over [0, n) via inverse-CDF on precomputed weights.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(7);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(1);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(11);
        let w = [0.0, 0.0, 5.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..10_000 {
            if r.weighted(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
