//! JSONL metrics sink + in-memory run history.
//!
//! Each trainer step appends one JSON object per line to
//! `results/<run>/metrics.jsonl` (hand-serialized — no serde offline).
//! The experiment harness reads the in-memory history to print paper
//! tables and figure series.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::Result;

/// One logged scalar record.
#[derive(Clone, Debug)]
pub struct Record {
    pub step: u64,
    pub wall_s: f64,
    pub fields: Vec<(String, f64)>,
}

/// Metrics sink: JSONL file (optional) + in-memory history.
pub struct MetricsSink {
    writer: Option<BufWriter<File>>,
    pub history: Vec<Record>,
    start: std::time::Instant,
}

impl MetricsSink {
    /// In-memory only (tests, sweeps).
    pub fn memory() -> Self {
        MetricsSink { writer: None, history: Vec::new(), start: std::time::Instant::now() }
    }

    /// Backed by `dir/metrics.jsonl` (directory is created).
    pub fn to_dir(dir: &Path) -> Result<Self> {
        fs::create_dir_all(dir)?;
        let f = File::create(dir.join("metrics.jsonl"))?;
        Ok(MetricsSink {
            writer: Some(BufWriter::new(f)),
            history: Vec::new(),
            start: std::time::Instant::now(),
        })
    }

    pub fn log(&mut self, step: u64, fields: &[(&str, f64)]) {
        let rec = Record {
            step,
            wall_s: self.start.elapsed().as_secs_f64(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        if let Some(w) = self.writer.as_mut() {
            let mut line = format!("{{\"step\":{},\"wall_s\":{:.3}", rec.step, rec.wall_s);
            for (k, v) in &rec.fields {
                // field names are caller-supplied: escape them, or a
                // key containing `"` emits invalid JSONL
                line.push_str(&format!(
                    ",\"{}\":{}",
                    super::bench::escape(k),
                    json_f64(*v)
                ));
            }
            line.push('}');
            let _ = writeln!(w, "{line}");
        }
        self.history.push(rec);
    }

    pub fn flush(&mut self) {
        if let Some(w) = self.writer.as_mut() {
            let _ = w.flush();
        }
    }

    /// Series of (step, value) for a field name.
    pub fn series(&self, field: &str) -> Vec<(u64, f64)> {
        self.history
            .iter()
            .filter_map(|r| {
                r.fields
                    .iter()
                    .find(|(k, _)| k == field)
                    .map(|(_, v)| (r.step, *v))
            })
            .collect()
    }

    /// Series of (wall seconds, value) for a field name (Fig. 3 x-axis).
    pub fn series_wall(&self, field: &str) -> Vec<(f64, f64)> {
        self.history
            .iter()
            .filter_map(|r| {
                r.fields
                    .iter()
                    .find(|(k, _)| k == field)
                    .map(|(_, v)| (r.wall_s, *v))
            })
            .collect()
    }

    pub fn last(&self, field: &str) -> Option<f64> {
        self.series(field).last().map(|&(_, v)| v)
    }
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Write a text report (paper table / figure series) under `results/`.
pub fn write_report(path: &Path, body: &str) -> Result<PathBuf> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    fs::write(path, body)?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_sink_series() {
        let mut m = MetricsSink::memory();
        m.log(1, &[("loss", 2.0)]);
        m.log(2, &[("loss", 1.5), ("acc", 0.3)]);
        assert_eq!(m.series("loss"), vec![(1, 2.0), (2, 1.5)]);
        assert_eq!(m.series("acc"), vec![(2, 0.3)]);
        assert_eq!(m.last("loss"), Some(1.5));
    }

    #[test]
    fn jsonl_file_written() {
        let dir = std::env::temp_dir().join(format!("misa_metrics_{}", std::process::id()));
        let mut m = MetricsSink::to_dir(&dir).unwrap();
        m.log(0, &[("x", 1.0)]);
        m.flush();
        let text = std::fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        assert!(text.contains("\"x\":1"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(1.5), "1.5");
    }

    #[test]
    fn field_keys_are_escaped() {
        let dir = std::env::temp_dir().join(format!("misa_metrics_esc_{}", std::process::id()));
        let mut m = MetricsSink::to_dir(&dir).unwrap();
        m.log(0, &[("weird\"key\\name", 1.0)]);
        m.flush();
        let text = std::fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        assert!(text.contains("\"weird\\\"key\\\\name\":1"), "{text}");
        // the line is balanced: every unescaped quote is a delimiter
        let line = text.lines().next().unwrap();
        let unescaped_quotes = line
            .as_bytes()
            .iter()
            .enumerate()
            .filter(|&(i, &b)| b == b'"' && (i == 0 || line.as_bytes()[i - 1] != b'\\'))
            .count();
        assert_eq!(unescaped_quotes % 2, 0, "{line}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
