//! The trainer: the coordinator's event loop.
//!
//! One step = dataloader batch → `fwd_bwd` executable (L2 graph with the
//! L1 Pallas norm kernel fused in) → optimizer update (fused-Adam Pallas
//! executables on the hot path) → metrics + simulated-memory accounting.
//! Python never runs here.

use anyhow::{Context, Result};

use crate::config::{DataSpec, MethodSpec, RunConfig};
use crate::data::{loader::exact_match, Loader, TaskKind};
use crate::memory::{Allocator, Category};
use crate::modelspec::ModuleKind;
use crate::obs::memory::MemCategory;
use crate::obs::optstats::{self, StepRecord, TrainReport, VarianceEstimator, VarianceSample};
use crate::optim::{
    BAdam, Dora, FullAdam, Galore, Lisa, Lora, LoraMisa, Misa, Optimizer,
};
use crate::runtime::{Engine, Session};
use crate::util::MetricsSink;

/// Evaluation result over the validation stream.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub loss: f64,
    pub ppl: f64,
    /// exact-match accuracy (task data only)
    pub accuracy: f64,
}

/// Wall-clock breakdown of a run (Table 8).
#[derive(Clone, Debug, Default)]
pub struct TimeBreakdown {
    pub fwd_bwd_s: f64,
    pub optim_s: f64,
    pub steps: u64,
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub sess: Session,
    pub opt: Box<dyn Optimizer>,
    train: Loader,
    val: Loader,
    pub metrics: MetricsSink,
    pub alloc: Allocator,
    pub times: TimeBreakdown,
    step_no: u64,
    /// gradient sq-norm sums by (kind, layer) — Fig. 1 statistics
    pub grad_norm_stats: Vec<(ModuleKind, i32, f64, u64)>,
    collect_grad_stats: bool,
    /// online MISA-vs-layerwise gradient-variance estimator (always
    /// on: it only reads norms the backend already computed)
    pub varest: VarianceEstimator,
    /// per-step records when `--report-out` is enabled
    report: Option<TrainReport>,
}

impl Trainer {
    pub fn new(engine: &mut Engine, cfg: RunConfig) -> Result<Self> {
        let sess = Session::create(engine, &cfg.model, cfg.seed)?;
        Self::with_session(sess, cfg)
    }

    /// Build around an existing session (keeps pre-trained weights).
    pub fn with_session(sess: Session, cfg: RunConfig) -> Result<Self> {
        let spec = &sess.spec;
        let mc = &spec.config;
        let (b, s) = (mc.batch, mc.seq_len);
        let (train, val) = match &cfg.data {
            DataSpec::Lm => (
                Loader::lm(mc.vocab, b, s, cfg.seed ^ 0x7261494E),
                Loader::lm(mc.vocab, b, s, cfg.seed ^ 0x76614C21),
            ),
            other => {
                let kinds = other.kinds();
                (
                    Loader::tasks(&kinds, mc.vocab, b, s, cfg.seed ^ 0x7261494E),
                    Loader::tasks(&kinds, mc.vocab, b, s, cfg.seed ^ 0x76614C21),
                )
            }
        };
        let opt: Box<dyn Optimizer> = match &cfg.method {
            MethodSpec::Misa(mcfg) => {
                let mut mcfg = mcfg.clone();
                mcfg.pretrain = cfg.pretrain;
                mcfg.use_kernel = cfg.use_kernel;
                Box::new(Misa::new(spec, mcfg, cfg.seed))
            }
            // baselines run host-Adam (the fused-kernel path is MISA's);
            // integration tests cover kernel==host equivalence
            MethodSpec::FullAdam => Box::new(FullAdam::new(spec, cfg.pretrain, false)),
            MethodSpec::BAdam { t_inner } => Box::new(BAdam::new(spec, *t_inner, false)),
            MethodSpec::Lisa { t_inner } => {
                Box::new(Lisa::new(spec, *t_inner, false, cfg.seed))
            }
            MethodSpec::Lora { rank, alpha } => Box::new(Lora::new(
                spec,
                &sess.host,
                *rank,
                *alpha,
                &crate::optim::lora::default_targets(),
                cfg.seed,
            )),
            MethodSpec::Dora { rank, alpha } => Box::new(Dora::new(
                spec,
                &sess.host,
                *rank,
                *alpha,
                &crate::optim::lora::default_targets(),
                cfg.seed,
            )),
            MethodSpec::Galore { rank, update_freq, scale } => Box::new(Galore::new(
                spec,
                *rank,
                *update_freq,
                *scale,
                cfg.pretrain,
                cfg.seed,
            )),
            MethodSpec::LoraMisa { rank, alpha, delta, eta, t_inner } => Box::new(LoraMisa::new(
                spec,
                &sess.host,
                *rank,
                *alpha,
                &crate::optim::lora::default_targets(),
                *delta,
                *eta,
                *t_inner,
                cfg.seed,
            )),
        };
        let metrics = match &cfg.out_dir {
            Some(dir) => MetricsSink::to_dir(std::path::Path::new(dir))?,
            None => MetricsSink::memory(),
        };
        Ok(Trainer {
            cfg,
            sess,
            opt,
            train,
            val,
            metrics,
            alloc: Allocator::new(),
            times: TimeBreakdown::default(),
            step_no: 0,
            grad_norm_stats: Vec::new(),
            collect_grad_stats: false,
            varest: VarianceEstimator::new(),
            report: None,
        })
    }

    /// Record per-(kind, layer) gradient norms during training (Fig. 1).
    pub fn collect_grad_stats(&mut self, on: bool) {
        self.collect_grad_stats = on;
    }

    /// Start collecting per-step `--report-out` records. Collection is
    /// a pure read-out of already-computed norms and counters — the
    /// training trajectory is bit-identical with it on or off
    /// (test-pinned).
    pub fn enable_report(&mut self) {
        self.report = Some(TrainReport::new(&self.cfg.model, &self.opt.name()));
    }

    /// Write the structured training report collected since
    /// [`Self::enable_report`] as one `json.load`-valid document.
    pub fn write_report(&self, path: &std::path::Path) -> Result<()> {
        let rep = self
            .report
            .as_ref()
            .context("report collection was not enabled (call enable_report first)")?;
        let (units, rounds) = match self.opt.telemetry() {
            Some(t) => (t.units(), t.rounds()),
            None => (Vec::new(), 0),
        };
        std::fs::write(path, rep.to_json(&self.varest, &units, rounds))
            .with_context(|| format!("writing training report {path:?}"))
    }

    pub fn step_no(&self) -> u64 {
        self.step_no
    }

    /// One training step; returns the train loss.
    pub fn step(&mut self) -> Result<f32> {
        let _sp = crate::span!("train_step", "coordinator");
        let batch = self.train.next_batch();
        let t0 = std::time::Instant::now();
        let out = self.sess.fwd_bwd(&batch)?;
        let fwd_bwd_s = t0.elapsed().as_secs_f64();
        if self.collect_grad_stats {
            for (i, p) in self.sess.spec.params.iter().enumerate() {
                if p.kind.is_matrix_module() {
                    self.grad_norm_stats.push((
                        p.kind,
                        p.layer,
                        (out.sq_norms[i] as f64).sqrt(),
                        self.step_no,
                    ));
                }
            }
        }
        let t1 = std::time::Instant::now();
        let optim_s = {
            let _sp = crate::span!("optim_step", "coordinator");
            self.opt.step(&mut self.sess, &out, self.cfg.lr)?;
            t1.elapsed().as_secs_f64()
        };
        self.times.fwd_bwd_s += fwd_bwd_s;
        self.times.optim_s += optim_s;
        self.times.steps += 1;
        crate::obs::metrics::observe("train.fwd_bwd_ms", fwd_bwd_s * 1e3);
        crate::obs::metrics::observe("train.optim_ms", optim_s * 1e3);
        crate::obs::metrics::counter_add("train.steps", 1);
        self.charge_memory();
        // total grad norm = Σ sq_norms (convergence metric, Thm. 1)
        let total_grad_sq: f64 = out.sq_norms.iter().map(|&x| x as f64).sum();
        // sampler telemetry + the variance counterfactual: a pure
        // read-out of the sq-norms above and counters the optimizer
        // already tracks — never perturbs the step (bit-parity pinned)
        let sample = if let Some(telem) = self.opt.telemetry() {
            let units = telem.units();
            let s: Vec<f64> = units
                .iter()
                .map(|u| {
                    let sq: f64 = u.params.iter().map(|&p| out.sq_norms[p] as f64).sum();
                    sq / u.numel.max(1) as f64
                })
                .collect();
            let sample = self.varest.record(&units, &s);
            optstats::publish(telem.sampler_label(), telem.rounds(), &units, &sample);
            sample
        } else {
            VarianceSample {
                var_sampled: 0.0,
                var_layerwise: 0.0,
                ratio: 1.0,
                counted: false,
            }
        };
        if let Some(rep) = &mut self.report {
            rep.push(StepRecord {
                step: self.step_no,
                loss: out.loss as f64,
                var_sampled: sample.var_sampled,
                var_layerwise: sample.var_layerwise,
                var_ratio: sample.ratio,
                grad_sq_norm: total_grad_sq,
                optim_state_bytes: crate::obs::memory::current(MemCategory::OptimStates),
                activation_scratch_bytes: crate::obs::memory::current(
                    MemCategory::ActivationScratch,
                ),
            });
        }
        if self.step_no % self.cfg.log_every == 0 {
            self.metrics.log(
                self.step_no,
                &[
                    ("train_loss", out.loss as f64),
                    ("grad_sq_norm", total_grad_sq),
                    ("sim_peak_gib", crate::util::gib(self.alloc.peak_bytes())),
                ],
            );
        }
        self.step_no += 1;
        Ok(out.loss)
    }

    /// Charge the simulated allocator with this step's residency
    /// (params + per-method grads/states/activations), then release the
    /// transient categories so the ledger's peak reflects the method's
    /// true high-water mark.
    fn charge_memory(&mut self) {
        let mc = &self.sess.spec.config;
        let arch = crate::memory::Arch {
            h: mc.dim as u64,
            l: mc.n_layers as u64,
            a: mc.n_heads as u64,
            v: mc.vocab as u64,
        };
        let w = crate::memory::Workload::new(mc.batch as u64, mc.seq_len as u64);
        let prof = self.opt.mem_profile();
        let f32b = crate::memory::F32;
        // params always resident
        let params = self.alloc.alloc(
            Category::Params,
            self.sess.spec.total_params() as u64 * f32b,
        );
        // activations: frozen-layer cost everywhere + active surcharge
        let frozen = crate::memory::act_frozen_layer(&arch, &w) * arch.l;
        let active_layers: std::collections::HashSet<i32> = prof
            .active_indices
            .iter()
            .map(|&i| self.sess.spec.params[i].layer)
            .filter(|&l| l >= 0)
            .collect();
        let surcharge = active_layers.len() as u64
            * (crate::memory::act_active_layer(&arch, &w)
                - crate::memory::act_frozen_layer(&arch, &w));
        let acts = self
            .alloc
            .alloc(Category::Activations, (frozen + surcharge) * f32b);
        let grads = self.alloc.alloc(Category::Grads, prof.grad_elems * f32b);
        let optim = self
            .alloc
            .alloc(Category::OptimStates, prof.optim_elems * f32b);
        let adapters = self
            .alloc
            .alloc(Category::Adapters, prof.adapter_elems * f32b);
        // live byte gauge: what actually holds Adam moments right now
        // (the quantity Alg. 1 line 17's state-clearing shrinks)
        crate::obs::memory::set_current(
            MemCategory::OptimStates,
            (prof.optim_elems + prof.adapter_elems) * f32b,
        );
        // transient: free activations + grads at step end; optimizer
        // states/adapters/params conceptually persist but we re-charge
        // each step, so free everything to keep the ledger flat.
        for id in [params, acts, grads, optim, adapters] {
            let _ = self.alloc.free(id);
        }
    }

    /// Run `n` steps.
    pub fn run(&mut self, n: u64) -> Result<()> {
        for _ in 0..n {
            self.step()?;
        }
        Ok(())
    }

    /// Evaluate on the validation stream.
    pub fn evaluate(&mut self, batches: usize) -> Result<EvalReport> {
        let mut loss_sum = 0.0;
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..batches {
            let batch = self.val.next_batch();
            let out = self.sess.predict(&batch)?;
            loss_sum += out.loss as f64;
            let (h, t) = exact_match(&batch, &out.correct);
            hits += h;
            total += t;
        }
        let loss = loss_sum / batches.max(1) as f64;
        Ok(EvalReport {
            loss,
            ppl: loss.exp(),
            accuracy: if total > 0 { hits as f64 / total as f64 } else { 0.0 },
        })
    }

    /// Per-task answer-token accuracy (the table columns): fraction of
    /// supervised (answer-span) positions predicted correctly under
    /// teacher forcing. More graded than exact match at our substrate
    /// scale; `evaluate` still reports whole-answer exact match.
    pub fn eval_per_task(&mut self, kinds: &[TaskKind], batches: usize)
        -> Result<Vec<(TaskKind, f64)>> {
        let mc = self.sess.spec.config.clone();
        let mut out = Vec::new();
        for &kind in kinds {
            let mut loader = Loader::single_task(
                kind,
                mc.vocab,
                mc.batch,
                mc.seq_len,
                self.cfg.seed ^ 0xE7A1 ^ (kind.marker() as u64) << 32,
            );
            let mut hits = 0.0f64;
            let mut total = 0.0f64;
            for _ in 0..batches {
                let batch = loader.next_batch();
                let pred = self.sess.predict(&batch)?;
                for (i, &m) in batch.mask.iter().enumerate() {
                    if m > 0.0 {
                        total += 1.0;
                        hits += pred.correct[i] as f64;
                    }
                }
            }
            out.push((kind, hits / total.max(1.0)));
        }
        Ok(out)
    }

    /// Average per-step times in milliseconds: (fwd+bwd, optimizer).
    pub fn avg_times_ms(&self) -> (f64, f64) {
        let n = self.times.steps.max(1) as f64;
        (self.times.fwd_bwd_s * 1e3 / n, self.times.optim_s * 1e3 / n)
    }
}
