//! The L3 coordinator: training orchestration, evaluation, and the
//! paper-experiment harness.

pub mod ckpt;
pub mod experiments;
pub mod trainer;

pub use trainer::{EvalReport, Trainer};
