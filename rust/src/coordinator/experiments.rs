//! The paper-experiment harness: one function per table and figure.
//!
//! Each experiment trains real models through the runtime on the
//! synthetic substitute workloads (DESIGN.md Sec. 4), prints the same
//! rows/series the paper reports, and writes the report under
//! `results/`. "Mem.(GB)" columns come from the Appendix-E analytical
//! model evaluated at the *paper's* architecture constants, so they are
//! directly comparable to the published numbers; accuracy/perplexity
//! columns come from our substrate models, where the reproduction
//! target is the *shape* (who wins, by roughly what factor).
//!
//! Run with `misa exp <name>` (or `all`); `--full` multiplies step
//! budgets by 4.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::config::{DataSpec, MethodSpec, RunConfig};
use crate::coordinator::{ckpt, Trainer};
use crate::data::TaskKind;
use crate::memory::{self, Arch, Method, Workload};
use crate::modelspec::ModuleKind;
use crate::optim::sampler::{SamplerConfig, ScoreFn, Strategy};
use crate::optim::MisaConfig;
use crate::runtime::{Engine, Session};
use crate::util::metrics::write_report;

/// GiB from f32 element count (report helper).
fn gib4(elems: u64) -> f64 {
    (elems * memory::F32) as f64 / (1u64 << 30) as f64
}

/// Experiment context shared by the harness.
pub struct ExpCtx<'a> {
    pub engine: &'a mut Engine,
    /// fast profile: quarter step budgets (default)
    pub fast: bool,
    pub results: PathBuf,
}

impl<'a> ExpCtx<'a> {
    pub fn new(engine: &'a mut Engine, fast: bool) -> Self {
        ExpCtx { engine, fast, results: PathBuf::from("results") }
    }

    fn steps(&self, full: u64) -> u64 {
        if self.fast {
            (full / 6).max(20)
        } else {
            full
        }
    }

    /// Pre-trained base checkpoint for fine-tuning experiments
    /// (cached under results/cache). Dense-Adam pre-training on the
    /// instruction mixture, full-parameter.
    fn base_params(&mut self, model: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
        // NOT scaled by the fast profile: every accuracy table feeds off
        // this checkpoint, so its quality is non-negotiable (cached).
        let steps = 1500;
        let path = self
            .results
            .join("cache")
            .join(format!("base_{model}_{seed}_{steps}.bin"));
        if let Ok(params) = ckpt::load(&path) {
            return Ok(params);
        }
        let cfg = RunConfig {
            model: model.into(),
            method: MethodSpec::FullAdam,
            data: DataSpec::Instruction,
            lr: 2e-3,
            steps,
            pretrain: true,
            log_every: 100,
            seed,
            ..Default::default()
        };
        let mut t = Trainer::new(self.engine, cfg)?;
        t.run(steps)?;
        ckpt::save(&path, &t.sess.host)?;
        Ok(t.sess.host)
    }

    /// Fine-tune from the shared base; returns the trainer for
    /// inspection/eval.
    fn finetune(&mut self, model: &str, method: MethodSpec, data: DataSpec,
                lr: f32, steps: u64, seed: u64) -> Result<Trainer> {
        let base = self.base_params(model, 7)?;
        let spec = self.engine.manifest.model(model)?.clone();
        let sess = Session::with_params(self.engine, spec, base)?;
        let cfg = RunConfig {
            model: model.into(),
            method,
            data,
            lr,
            steps,
            log_every: (steps / 20).max(1),
            seed,
            ..Default::default()
        };
        let mut t = Trainer::with_session(sess, cfg)?;
        t.run(steps)?;
        Ok(t)
    }

    fn report(&self, name: &str, body: &str) -> Result<()> {
        write_report(&self.results.join(format!("{name}.txt")), body)?;
        Ok(())
    }
}

fn misa_method(delta: f64, eta: f64, t_inner: usize) -> MethodSpec {
    MethodSpec::Misa(MisaConfig {
        sampler: SamplerConfig {
            strategy: Strategy::Importance { eta },
            delta,
            ..Default::default()
        },
        t_inner,
        ..Default::default()
    })
}

/// The fine-tuning method roster of Tables 1/3/4 with the memory-model
/// analog of each.
fn roster() -> Vec<(MethodSpec, Method)> {
    vec![
        (MethodSpec::FullAdam, Method::FullFT),
        (MethodSpec::Lora { rank: 16, alpha: 32.0 }, Method::Lora { r: 32 }),
        (MethodSpec::Dora { rank: 16, alpha: 32.0 }, Method::Dora { r: 16 }),
        (MethodSpec::Lisa { t_inner: 50 }, Method::Lisa),
        (MethodSpec::BAdam { t_inner: 50 }, Method::BAdam),
        (misa_method(0.01, 1.0, 50), Method::Misa { delta: 0.01 }),
        (misa_method(0.03, 1.0, 50), Method::Misa { delta: 0.03 }),
    ]
}

fn fmt_row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

// ---------------------------------------------------------------------------
// Tables 1 & 3: commonsense reasoning
// ---------------------------------------------------------------------------

fn commonsense_table(ctx: &mut ExpCtx, name: &str, model: &str, arch: Arch,
                     seed: u64) -> Result<String> {
    let w = Workload::new(4, 512); // paper fine-tuning workload shape
    let steps = ctx.steps(500);
    let kinds = TaskKind::COMMONSENSE;
    let mut body = format!(
        "# {name}: commonsense fine-tuning ({model} substrate; Mem at paper arch h={} L={})\n",
        arch.h, arch.l
    );
    let mut header = vec!["Method".to_string(), "Mem(GB)".into()];
    header.extend(kinds.iter().map(|k| k.name().to_string()));
    header.push("Avg".into());
    let widths: Vec<usize> = header.iter().map(|h| h.len().max(7)).collect();
    body.push_str(&fmt_row(&header, &widths));
    body.push('\n');
    for (method, mem_method) in roster() {
        let label = method.label();
        let mut t = ctx.finetune(model, method, DataSpec::Commonsense, 1e-3, steps, seed)?;
        let per_task = t.eval_per_task(&kinds, 6)?;
        let avg: f64 = per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len() as f64;
        let mem = memory::table_peak_gib(mem_method, &arch, &w);
        let mut cells = vec![label, format!("{mem:.1}")];
        cells.extend(per_task.iter().map(|(_, a)| format!("{:.1}", a * 100.0)));
        cells.push(format!("{:.1}", avg * 100.0));
        body.push_str(&fmt_row(&cells, &widths));
        body.push('\n');
    }
    Ok(body)
}

pub fn table1(ctx: &mut ExpCtx) -> Result<String> {
    let body = commonsense_table(ctx, "Table 1 (LLaMA3-8B analog)", "small",
                                 Arch::llama3_8b(), 11)?;
    ctx.report("table1", &body)?;
    Ok(body)
}

pub fn table3(ctx: &mut ExpCtx) -> Result<String> {
    let body = commonsense_table(ctx, "Table 3 (Qwen2.5-7B analog)", "small",
                                 Arch::qwen25_7b(), 13)?;
    ctx.report("table3", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Table 4: math reasoning
// ---------------------------------------------------------------------------

pub fn table4(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(500);
    let kinds = TaskKind::MATH;
    let mut body = String::from(
        "# Table 4: math reasoning fine-tuning (small substrate; Mem at paper archs)\n",
    );
    for (tag, arch, seed) in [
        ("LLaMA3-8B", Arch::llama3_8b(), 21u64),
        ("Qwen2.5-7B", Arch::qwen25_7b(), 23),
    ] {
        let w = Workload::new(4, 512);
        body.push_str(&format!("## {tag} analog\n"));
        let mut header = vec!["Method".to_string(), "Mem(GB)".into()];
        header.extend(kinds.iter().map(|k| k.name().to_string()));
        header.push("Avg".into());
        let widths: Vec<usize> = header.iter().map(|h| h.len().max(7)).collect();
        body.push_str(&fmt_row(&header, &widths));
        body.push('\n');
        for (method, mem_method) in roster() {
            if matches!(method, MethodSpec::FullAdam) {
                continue; // paper Table 4 omits FT
            }
            let label = method.label();
            let mut t = ctx.finetune("small", method, DataSpec::Math, 1e-3, steps, seed)?;
            let per_task = t.eval_per_task(&kinds, 6)?;
            let avg: f64 =
                per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len() as f64;
            let mem = memory::table_peak_gib(mem_method, &arch, &w);
            let mut cells = vec![label, format!("{mem:.1}")];
            cells.extend(per_task.iter().map(|(_, a)| format!("{:.1}", a * 100.0)));
            cells.push(format!("{:.1}", avg * 100.0));
            body.push_str(&fmt_row(&cells, &widths));
            body.push('\n');
        }
    }
    ctx.report("table4", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Table 5 + Fig. 3: instruction tuning
// ---------------------------------------------------------------------------

pub fn table5(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(400);
    let mut body = String::from(
        "# Table 5: instruction tuning (Alpaca-GPT4 analog = 12-family mixture)\n\
         # held-out metrics: val loss + exact-match accuracy proxy\n",
    );
    let archs = [
        ("TinyLLaMA", Arch::tinyllama(), 31u64),
        ("LLaMA2-7B", Arch::llama2_7b(), 33),
        ("Mistral-7B", Arch::mistral_7b(), 35),
    ];
    let w = Workload::new(2, 512); // paper: batch size 2
    let methods: Vec<(MethodSpec, Method)> = vec![
        (MethodSpec::Lora { rank: 16, alpha: 32.0 }, Method::Lora { r: 32 }),
        (
            MethodSpec::Galore { rank: 16, update_freq: 200, scale: 0.25 },
            Method::Galore { r: 32 },
        ),
        (MethodSpec::Lisa { t_inner: 50 }, Method::Lisa),
        (MethodSpec::BAdam { t_inner: 50 }, Method::BAdam),
        (misa_method(0.03, 0.5, 50), Method::Misa { delta: 0.03 }),
    ];
    for (tag, arch, seed) in archs {
        body.push_str(&format!("## {tag} analog\n"));
        let header: Vec<String> = ["Method", "Mem(GB)", "ValLoss", "Acc(EM)"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let widths: Vec<usize> = header.iter().map(|h| h.len().max(9)).collect();
        body.push_str(&fmt_row(&header, &widths));
        body.push('\n');
        for (method, mem_method) in &methods {
            let mut t = ctx.finetune("small", method.clone(), DataSpec::Instruction,
                                     1e-3, steps, seed)?;
            let eval = t.evaluate(8)?;
            let mem = memory::table_peak_gib(*mem_method, &arch, &w);
            body.push_str(&fmt_row(
                &[
                    method.label(),
                    format!("{mem:.2}"),
                    format!("{:.3}", eval.loss),
                    format!("{:.1}", eval.accuracy * 100.0),
                ],
                &widths,
            ));
            body.push('\n');
        }
    }
    ctx.report("table5", &body)?;
    Ok(body)
}

pub fn fig3(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(400);
    let mut body = String::from(
        "# Fig. 3: validation loss vs wall-clock (instruction tuning)\n\
         # series: method wall_seconds val_loss\n",
    );
    let methods = vec![
        MethodSpec::Lisa { t_inner: 25 },
        MethodSpec::BAdam { t_inner: 25 },
        misa_method(0.03, 0.5, 25),
    ];
    for method in methods {
        let label = method.label();
        let base = ctx.base_params("small", 7)?;
        let spec = ctx.engine.manifest.model("small")?.clone();
        let sess = Session::with_params(ctx.engine, spec, base)?;
        let cfg = RunConfig {
            model: "small".into(),
            method,
            data: DataSpec::Instruction,
            lr: 1e-3,
            steps,
            log_every: 1000,
            seed: 41,
            ..Default::default()
        };
        let mut t = Trainer::with_session(sess, cfg)?;
        let t0 = std::time::Instant::now();
        let chunk = (steps / 10).max(1);
        for _ in 0..10 {
            t.run(chunk)?;
            let eval = t.evaluate(4)?;
            body.push_str(&format!(
                "{label} {:.2} {:.4}\n",
                t0.elapsed().as_secs_f64(),
                eval.loss
            ));
        }
    }
    ctx.report("fig3", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Table 6 + Fig. 4: pre-training
// ---------------------------------------------------------------------------

fn misa_pretrain(delta: f64) -> MethodSpec {
    MethodSpec::Misa(MisaConfig {
        sampler: SamplerConfig {
            strategy: Strategy::Importance { eta: 300.0 },
            delta,
            ..Default::default()
        },
        t_inner: 50,
        pretrain: true,
        ..Default::default()
    })
}

pub fn table6(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(800);
    let mut body = String::from(
        "# Table 6 / Fig. 4: pre-training validation perplexity (C4 analog =\n\
         # Zipf-Markov stream). Mem(GB) at the paper's LLaMA 130M/350M archs.\n",
    );
    let runs: Vec<(&str, MethodSpec, Method)> = vec![
        ("Adam", MethodSpec::FullAdam, Method::FullFT),
        (
            "GaLore(r=lo)",
            MethodSpec::Galore { rank: 4, update_freq: 200, scale: 0.25 },
            Method::Galore { r: 32 },
        ),
        (
            "GaLore(r=hi)",
            MethodSpec::Galore { rank: 32, update_freq: 200, scale: 0.25 },
            Method::Galore { r: 256 },
        ),
        ("MISA(d=3%)", misa_pretrain(0.03), Method::Misa { delta: 0.03 }),
        ("MISA(d=25%)", misa_pretrain(0.25), Method::Misa { delta: 0.25 }),
    ];
    for (model, arch_tag, arch) in [
        ("pt130", "LLaMA-130M", Arch::llama_130m()),
        ("pt350", "LLaMA-350M", Arch::llama_350m()),
    ] {
        let w = Workload::new(32, 256); // paper pre-training workload
        body.push_str(&format!("## {arch_tag} analog ({model} substrate)\n"));
        let header: Vec<String> =
            ["Method", "PPL", "Mem(GB)"].iter().map(|s| s.to_string()).collect();
        let widths = vec![14, 9, 9];
        body.push_str(&fmt_row(&header, &widths));
        body.push('\n');
        let mut series = String::new();
        for (label, method, mem_method) in &runs {
            let cfg = RunConfig {
                model: model.into(),
                method: method.clone(),
                data: DataSpec::Lm,
                lr: 2e-3,
                steps,
                pretrain: true,
                log_every: 1000,
                seed: 51,
                ..Default::default()
            };
            let mut t = Trainer::new(ctx.engine, cfg)?;
            let chunk = (steps / 8).max(1);
            for _ in 0..8 {
                t.run(chunk)?;
                let e = t.evaluate(4)?;
                series.push_str(&format!(
                    "fig4 {arch_tag} {label} {} {:.3}\n",
                    t.step_no(),
                    e.ppl
                ));
            }
            let eval = t.evaluate(8)?;
            let mem = memory::table_peak_gib(*mem_method, &arch, &w);
            body.push_str(&fmt_row(
                &[
                    label.to_string(),
                    format!("{:.2}", eval.ppl),
                    format!("{mem:.2}"),
                ],
                &widths,
            ));
            body.push('\n');
        }
        body.push_str("\n# Fig. 4 series (step, ppl):\n");
        body.push_str(&series);
    }
    ctx.report("table6", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Fig. 1: gradient-norm heterogeneity
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &mut ExpCtx) -> Result<String> {
    let base = ctx.base_params("small", 7)?;
    let spec = ctx.engine.manifest.model("small")?.clone();
    let sess = Session::with_params(ctx.engine, spec, base)?;
    let cfg = RunConfig {
        model: "small".into(),
        method: MethodSpec::FullAdam,
        data: DataSpec::Commonsense,
        lr: 1e-4,
        steps: 20,
        log_every: 1000,
        seed: 61,
        ..Default::default()
    };
    let mut t = Trainer::with_session(sess, cfg)?;
    t.collect_grad_stats(true);
    t.run(20)?;
    // average ||g|| per (kind, layer)
    let mut agg: HashMap<(ModuleKind, i32), (f64, u64)> = HashMap::new();
    for &(kind, layer, norm, _) in &t.grad_norm_stats {
        let e = agg.entry((kind, layer)).or_insert((0.0, 0));
        e.0 += norm;
        e.1 += 1;
    }
    let mut body = String::from(
        "# Fig. 1: per-module gradient norms while fine-tuning (small substrate)\n\
         # rows: module kind; cols: layer index; cell: mean ||g||_F\n",
    );
    let n_layers = t.sess.spec.config.n_layers as i32;
    body.push_str("kind     ");
    for l in 0..n_layers {
        body.push_str(&format!(" layer{l:<3}"));
    }
    body.push('\n');
    let mut kind_means: Vec<(ModuleKind, f64)> = Vec::new();
    for kind in ModuleKind::matrix_kinds() {
        body.push_str(&format!("{:<9}", kind.as_str()));
        let mut ksum = 0.0;
        for l in 0..n_layers {
            let (s, c) = agg.get(&(kind, l)).copied().unwrap_or((0.0, 1));
            let mean = s / c.max(1) as f64;
            ksum += mean;
            body.push_str(&format!(" {mean:8.4}"));
        }
        kind_means.push((kind, ksum / n_layers as f64));
        body.push('\n');
    }
    // heterogeneity check (the paper's Fig. 1 point): spread across kinds
    let vals: Vec<f64> = kind_means.iter().map(|(_, v)| *v).collect();
    let mx = vals.iter().cloned().fold(f64::MIN, f64::max);
    let mn = vals.iter().cloned().fold(f64::MAX, f64::min);
    body.push_str(&format!(
        "\nheterogeneity: max/min mean-norm ratio across kinds = {:.2}\n",
        mx / mn.max(1e-12)
    ));
    ctx.report("fig1", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Fig. 2 / Fig. 5: analytical memory curves
// ---------------------------------------------------------------------------

pub fn fig2(ctx: &mut ExpCtx) -> Result<String> {
    let arch = Arch::llama3_8b();
    let mut body = String::from(
        "# Fig. 2: peak memory vs sequence length, LLaMA3-8B (Appendix E model)\n\
         # seq_len  LoRA(r=16)  MISA(d=1%)  MISA(d=3%)  BAdam(layer)\n",
    );
    for s in [256u64, 512, 1024, 2048, 4096, 8192, 16384] {
        let w = Workload::new(4, s);
        body.push_str(&format!(
            "{s:7}  {:10.1}  {:10.1}  {:10.1}  {:12.1}\n",
            gib4(memory::lora_peak_all(&arch, &w, 16)),
            gib4(memory::misa_peak(&arch, &w, 0.01)),
            gib4(memory::misa_peak(&arch, &w, 0.03)),
            gib4(memory::layerwise_peak(&arch, &w)),
        ));
    }
    ctx.report("fig2", &body)?;
    Ok(body)
}

pub fn fig5(ctx: &mut ExpCtx) -> Result<String> {
    let mut body = String::from(
        "# Fig. 5: peak memory, 8B vs 70B, dense vs flash attention\n\
         # arch flash seq_len LoRA(r=16) MISA(d=3%)\n",
    );
    for (tag, arch) in [("8B", Arch::llama3_8b()), ("70B", Arch::llama3_70b())] {
        for flash in [false, true] {
            for s in [512u64, 2048, 8192] {
                let w = if flash { Workload::flash(4, s) } else { Workload::new(4, s) };
                body.push_str(&format!(
                    "{tag} {flash} {s} {:.1} {:.1}\n",
                    gib4(memory::lora_peak_all(&arch, &w, 16)),
                    gib4(memory::misa_peak(&arch, &w, 0.03)),
                ));
            }
        }
    }
    ctx.report("fig5", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Table 8: computation efficiency
// ---------------------------------------------------------------------------

pub fn table8(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(60);
    let mut body = String::from(
        "# Table 8: average per-step time (ms) on the small substrate\n\
         # fwd+bwd is one fused graph; optimizer column is coordinator-side\n",
    );
    let methods = vec![
        MethodSpec::Lora { rank: 16, alpha: 32.0 },
        MethodSpec::Galore { rank: 16, update_freq: 50, scale: 0.25 },
        MethodSpec::BAdam { t_inner: 50 },
        MethodSpec::Lisa { t_inner: 50 },
        misa_method(0.03, 0.5, 50),
    ];
    let header: Vec<String> = ["Method", "Fwd+Bwd(ms)", "Optimizer(ms)", "Total(ms)"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let widths = vec![16, 12, 13, 10];
    body.push_str(&fmt_row(&header, &widths));
    body.push('\n');
    for method in methods {
        let label = method.label();
        let mut t = ctx.finetune("small", method, DataSpec::Instruction, 1e-3, steps, 71)?;
        let (fb, op) = t.avg_times_ms();
        body.push_str(&fmt_row(
            &[
                label,
                format!("{fb:.1}"),
                format!("{op:.1}"),
                format!("{:.1}", fb + op),
            ],
            &widths,
        ));
        body.push('\n');
    }
    ctx.report("table8", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Ablations: Tables 9-12, Figs 6-11
// ---------------------------------------------------------------------------

pub fn table9(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(400);
    let mut body = String::from(
        "# Table 9: inner-loop T ablation (instruction tuning, small)\n  T  ValLoss  Acc(EM)\n",
    );
    for t_inner in [5usize, 15, 30, 50, 100, 200] {
        let mut t = ctx.finetune("small", misa_method(0.03, 0.5, t_inner),
                                 DataSpec::Instruction, 1e-3, steps, 81)?;
        let e = t.evaluate(8)?;
        body.push_str(&format!("{t_inner:3}  {:.4}  {:.1}\n", e.loss, e.accuracy * 100.0));
    }
    ctx.report("table9", &body)?;
    Ok(body)
}

pub fn table10(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(500);
    let mut body = String::from(
        "# Table 10: sampling-strategy ablation (math + commonsense EM avg)\nStrategy   Math  Commonsense\n",
    );
    let strategies = [
        ("MISA", Strategy::Importance { eta: 1.0 }),
        ("Uniform", Strategy::Uniform),
        ("Top-K", Strategy::TopK),
        ("Bottom-K", Strategy::BottomK),
    ];
    for (label, strategy) in strategies {
        let mk = || {
            MethodSpec::Misa(MisaConfig {
                sampler: SamplerConfig { strategy, delta: 0.03, ..Default::default() },
                t_inner: 50,
                ..Default::default()
            })
        };
        let mut tm = ctx.finetune("small", mk(), DataSpec::Math, 1e-3, steps, 91)?;
        let math = avg_acc(&mut tm, &TaskKind::MATH)?;
        let mut tc = ctx.finetune("small", mk(), DataSpec::Commonsense, 1e-3, steps, 91)?;
        let cs = avg_acc(&mut tc, &TaskKind::COMMONSENSE)?;
        body.push_str(&format!(
            "{label:<9}  {:.1}  {:.1}\n",
            math * 100.0,
            cs * 100.0
        ));
    }
    ctx.report("table10", &body)?;
    Ok(body)
}

fn avg_acc(t: &mut Trainer, kinds: &[TaskKind]) -> Result<f64> {
    let per = t.eval_per_task(kinds, 6)?;
    Ok(per.iter().map(|(_, a)| a).sum::<f64>() / per.len() as f64)
}

pub fn table11(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(500);
    let mut body = String::from(
        "# Table 11: importance-scoring ablation (EM avg)\nScore          Math  Commonsense\n",
    );
    for (label, score_fn) in [
        ("WeightNorm", ScoreFn::WeightNorm),
        ("ParamCount", ScoreFn::ParamCount),
        ("GradNorm", ScoreFn::GradNorm),
    ] {
        let method = MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig {
                score_fn,
                strategy: Strategy::Importance { eta: 1.0 },
                delta: 0.03,
                ..Default::default()
            },
            t_inner: 50,
            ..Default::default()
        });
        let mut tm = ctx.finetune("small", method.clone(), DataSpec::Math, 1e-3, steps, 95)?;
        let math = avg_acc(&mut tm, &TaskKind::MATH)?;
        let mut tc = ctx.finetune("small", method, DataSpec::Commonsense, 1e-3, steps, 95)?;
        let cs = avg_acc(&mut tc, &TaskKind::COMMONSENSE)?;
        body.push_str(&format!("{label:<13}  {:.1}  {:.1}\n", math * 100.0, cs * 100.0));
    }
    ctx.report("table11", &body)?;
    Ok(body)
}

pub fn table12(ctx: &mut ExpCtx) -> Result<String> {
    // per-module-kind fine-tuning, uniform vs MISA (also Fig. 10)
    let steps = ctx.steps(300);
    let mut body = String::from(
        "# Table 12 / Fig. 10: per-module-kind fine-tuning (math EM avg)\nKind    Uniform  MISA\n",
    );
    for kind in ModuleKind::matrix_kinds() {
        let mut accs = Vec::new();
        for strategy in [Strategy::Uniform, Strategy::Importance { eta: 1.0 }] {
            let base = ctx.base_params("small", 7)?;
            let spec = ctx.engine.manifest.model("small")?.clone();
            let sess = Session::with_params(ctx.engine, spec.clone(), base)?;
            let cfg = RunConfig {
                model: "small".into(),
                method: MethodSpec::FullAdam, // replaced below
                data: DataSpec::Math,
                lr: 1e-3,
                steps,
                log_every: 1000,
                seed: 99,
                ..Default::default()
            };
            let mut t = Trainer::with_session(sess, cfg)?;
            // restrict MISA to one module kind
            let mcfg = MisaConfig {
                sampler: SamplerConfig { strategy, delta: 0.03, ..Default::default() },
                t_inner: 25,
                ..Default::default()
            };
            t.opt = Box::new(crate::optim::Misa::restrict_pool(&spec, mcfg, 99, &[kind]));
            t.run(steps)?;
            accs.push(avg_acc(&mut t, &TaskKind::MATH)?);
        }
        body.push_str(&format!(
            "{:<7} {:6.1}  {:5.1}\n",
            kind.as_str(),
            accs[0] * 100.0,
            accs[1] * 100.0
        ));
    }
    ctx.report("table12", &body)?;
    Ok(body)
}

pub fn fig11(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(600);
    let mut t = ctx.finetune("small", misa_method(0.03, 1.0, 10),
                             DataSpec::Instruction, 1e-3, steps, 103)?;
    let counts = t.opt.sampling_counts().unwrap();
    let mut body = String::from(
        "# Fig. 11: module sampling frequency (MISA on small)\n# module  layer  kind  count\n",
    );
    let mut by_kind: HashMap<ModuleKind, u64> = HashMap::new();
    for (idx, c) in counts {
        let p = &t.sess.spec.params[idx];
        body.push_str(&format!("{}  {}  {}  {}\n", p.name, p.layer, p.kind.as_str(), c));
        *by_kind.entry(p.kind).or_insert(0) += c;
    }
    body.push_str("\n# totals by kind:\n");
    for kind in ModuleKind::matrix_kinds() {
        body.push_str(&format!("{} {}\n", kind.as_str(), by_kind.get(&kind).unwrap_or(&0)));
    }
    ctx.report("fig11", &body)?;
    Ok(body)
}

pub fn fig7(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(500);
    let mut body = String::from(
        "# Fig. 7: clearing vs preserving optimizer states\n# setting  phase  final_metric\n",
    );
    for (label, clear) in [("clear", true), ("preserve", false)] {
        // fine-tuning phase (loss)
        let method = MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.03, ..Default::default() },
            t_inner: 25,
            clear_states: clear,
            ..Default::default()
        });
        let mut t = ctx.finetune("small", method, DataSpec::Math, 1e-3, steps, 107)?;
        let e = t.evaluate(8)?;
        body.push_str(&format!("{label} finetune_loss {:.4}\n", e.loss));
        // pre-training phase (ppl)
        let method = MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.25, ..Default::default() },
            t_inner: 25,
            pretrain: true,
            clear_states: clear,
            ..Default::default()
        });
        let cfg = RunConfig {
            model: "pt130".into(),
            method,
            data: DataSpec::Lm,
            lr: 2e-3,
            steps,
            pretrain: true,
            log_every: 1000,
            seed: 109,
            ..Default::default()
        };
        let mut t = Trainer::new(ctx.engine, cfg)?;
        t.run(steps)?;
        let e = t.evaluate(8)?;
        body.push_str(&format!("{label} pretrain_ppl {:.3}\n", e.ppl));
    }
    ctx.report("fig7", &body)?;
    Ok(body)
}

pub fn fig8(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(300);
    let mut body = String::from(
        "# Fig. 8: lr × eta sensitivity (math EM avg)\n#   lr      eta   acc\n",
    );
    for lr in [5e-4f32, 1e-3, 3e-3] {
        for eta in [0.1f64, 0.5, 1.0] {
            let mut t = ctx.finetune("small", misa_method(0.03, eta, 25),
                                     DataSpec::Math, lr, steps, 113)?;
            let acc = avg_acc(&mut t, &TaskKind::MATH)?;
            body.push_str(&format!("{lr:.0e}  {eta:5.2}  {:.1}\n", acc * 100.0));
        }
    }
    ctx.report("fig8", &body)?;
    Ok(body)
}

pub fn fig9(ctx: &mut ExpCtx) -> Result<String> {
    let steps = ctx.steps(600);
    let mut body = String::from(
        "# Fig. 9: delta sweep, validation loss across training (instruction)\n# delta step val_loss\n",
    );
    for delta in [0.01f64, 0.03, 0.10, 0.25] {
        let base = ctx.base_params("small", 7)?;
        let spec = ctx.engine.manifest.model("small")?.clone();
        let sess = Session::with_params(ctx.engine, spec, base)?;
        let cfg = RunConfig {
            model: "small".into(),
            method: misa_method(delta, 0.5, 25),
            data: DataSpec::Instruction,
            lr: 1e-3,
            steps,
            log_every: 1000,
            seed: 127,
            ..Default::default()
        };
        let mut t = Trainer::with_session(sess, cfg)?;
        let chunk = (steps / 6).max(1);
        for _ in 0..6 {
            t.run(chunk)?;
            let e = t.evaluate(4)?;
            body.push_str(&format!("{delta} {} {:.4}\n", t.step_no(), e.loss));
        }
    }
    ctx.report("fig9", &body)?;
    Ok(body)
}

pub fn fig6(ctx: &mut ExpCtx) -> Result<String> {
    // LoRA+MISA hybrid sweep + Table 7-style comparison
    let steps = ctx.steps(500);
    let mut body = String::from(
        "# Fig. 6 / Table 7: LoRA+MISA hybrid (math EM; mem at LLaMA3-8B arch)\n# delta acc mem_gb\n",
    );
    let arch = Arch::llama3_8b();
    let w = Workload::new(4, 512);
    let lora_mem = memory::table_peak_gib(Method::Lora { r: 32 }, &arch, &w);
    let mut tl = ctx.finetune("small", MethodSpec::Lora { rank: 16, alpha: 32.0 },
                              DataSpec::Math, 1e-3, steps, 131)?;
    let lora_acc = avg_acc(&mut tl, &TaskKind::MATH)?;
    body.push_str(&format!("LoRA(full) {:.1} {lora_mem:.1}\n", lora_acc * 100.0));
    for delta in [0.1f64, 0.3, 0.5, 0.7] {
        let method = MethodSpec::LoraMisa {
            rank: 16,
            alpha: 32.0,
            delta,
            eta: 1.0,
            t_inner: 25,
        };
        let mut t = ctx.finetune("small", method, DataSpec::Math, 1e-3, steps, 131)?;
        let acc = avg_acc(&mut t, &TaskKind::MATH)?;
        // hybrid memory: inactive adapters contribute no grad memory
        // (states retained per Appendix B.2) — grads are the ~8% slice
        let mem = lora_mem * (0.92 + 0.08 * delta);
        body.push_str(&format!("{delta} {:.1} {mem:.1}\n", acc * 100.0));
    }
    ctx.report("fig6", &body)?;
    Ok(body)
}

pub fn conv(ctx: &mut ExpCtx) -> Result<String> {
    // Theorem 1 sanity: avg ||∇f||² decays with N (outer epochs)
    let mut body = String::from(
        "# Thm. 1 sanity: mean grad sq-norm over training (should decay)\n# step grad_sq_mean\n",
    );
    let steps = ctx.steps(600);
    let cfg = RunConfig {
        model: "pt130".into(),
        method: misa_pretrain(0.25),
        data: DataSpec::Lm,
        lr: 2e-3,
        steps,
        pretrain: true,
        log_every: 1,
        seed: 137,
        ..Default::default()
    };
    let mut t = Trainer::new(ctx.engine, cfg)?;
    t.run(steps)?;
    let series = t.metrics.series("grad_sq_norm");
    let chunks = 6;
    let per = series.len() / chunks;
    let mut means = Vec::new();
    for c in 0..chunks {
        let m: f64 = series[c * per..(c + 1) * per].iter().map(|&(_, v)| v).sum::<f64>()
            / per as f64;
        body.push_str(&format!("{} {m:.5}\n", (c + 1) * per));
        means.push(m);
    }
    let first = means[..2].iter().sum::<f64>() / 2.0;
    let last = means[chunks - 2..].iter().sum::<f64>() / 2.0;
    body.push_str(&format!("\nfirst-third mean {first:.5}, last-third mean {last:.5}\n"));
    ctx.report("conv", &body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type ExpFn = fn(&mut ExpCtx) -> Result<String>;

pub fn registry() -> Vec<(&'static str, ExpFn, &'static str)> {
    vec![
        ("table1", table1 as ExpFn, "commonsense fine-tuning, LLaMA3-8B analog"),
        ("table3", table3, "commonsense fine-tuning, Qwen2.5-7B analog"),
        ("table4", table4, "math reasoning fine-tuning"),
        ("table5", table5, "instruction tuning"),
        ("table6", table6, "pre-training perplexity (+Fig. 4 series)"),
        ("table8", table8, "per-step time breakdown"),
        ("table9", table9, "inner-loop T ablation"),
        ("table10", table10, "sampling-strategy ablation"),
        ("table11", table11, "importance-scoring ablation"),
        ("table12", table12, "per-module-kind ablation (+Fig. 10)"),
        ("fig1", fig1, "module gradient-norm heterogeneity"),
        ("fig2", fig2, "peak memory vs seq length (8B)"),
        ("fig3", fig3, "val loss vs wall-clock"),
        ("fig5", fig5, "peak memory 8B vs 70B (+flash)"),
        ("fig6", fig6, "LoRA+MISA hybrid (+Table 7)"),
        ("fig7", fig7, "clear vs preserve optimizer states"),
        ("fig8", fig8, "lr × eta sensitivity"),
        ("fig9", fig9, "delta overfitting sweep"),
        ("fig11", fig11, "module sampling frequency"),
        ("conv", conv, "Theorem 1 convergence sanity"),
    ]
}

pub fn run(ctx: &mut ExpCtx, name: &str) -> Result<String> {
    for (n, f, _) in registry() {
        if n == name {
            return f(ctx);
        }
    }
    anyhow::bail!("unknown experiment {name:?}; see `misa exp list`")
}
