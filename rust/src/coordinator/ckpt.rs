//! Checkpointing: host parameters ⇄ flat binary file.
//!
//! Format: magic "MISA" + u32 param count + per-param (u64 element
//! count + f32 LE data), registry order. Used to share the pre-trained
//! base between fine-tuning experiments (the paper fine-tunes published
//! checkpoints; we pre-train our own base once and cache it).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"MISA";

pub fn save(path: &Path, params: &[Vec<f32>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        f.write_all(&(p.len() as u64).to_le_bytes())?;
        // SAFETY-free path: serialize via byte conversion per element
        let mut bytes = Vec::with_capacity(p.len() * 4);
        for &x in p {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<Vec<f32>>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a MISA checkpoint");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let n = u64::from_le_bytes(u64b) as usize;
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let mut p = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            p.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.push(p);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let params = vec![vec![1.0f32, -2.5, 3e-7], vec![], vec![0.0; 100]];
        let path = std::env::temp_dir().join(format!("misa_ckpt_{}.bin", std::process::id()));
        save(&path, &params).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(params, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("misa_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"nope").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
