//! Checkpointing: host parameters ⇄ flat binary file.
//!
//! Format: magic "MISA" + u32 param count + per-param (u64 element
//! count + f32 LE data), registry order. Used to share the pre-trained
//! base between fine-tuning experiments (the paper fine-tunes published
//! checkpoints; we pre-train our own base once and cache it).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"MISA";

pub fn save(path: &Path, params: &[Vec<f32>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        f.write_all(&(p.len() as u64).to_le_bytes())?;
        // SAFETY-free path: serialize via byte conversion per element
        let mut bytes = Vec::with_capacity(p.len() * 4);
        for &x in p {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<Vec<f32>>> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut f = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a MISA checkpoint");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;
    // every param needs at least its 8-byte length header
    let mut remaining = file_len.saturating_sub(8);
    if (count as u64).saturating_mul(8) > remaining {
        bail!(
            "{path:?}: header declares {count} params but only {remaining} \
             bytes follow — corrupt or truncated checkpoint"
        );
    }
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        remaining -= 8;
        let n64 = u64::from_le_bytes(u64b);
        // validate the declared element count against the bytes actually
        // present BEFORE allocating: a corrupt header must not be able to
        // request a multi-GiB buffer.
        let byte_len = n64
            .checked_mul(4)
            .filter(|&b| b <= remaining)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "{path:?}: param {i} declares {n64} elements but only \
                     {remaining} bytes remain — corrupt or truncated checkpoint"
                )
            })?;
        let n = n64 as usize;
        let mut bytes = vec![0u8; byte_len as usize];
        f.read_exact(&mut bytes)?;
        remaining -= byte_len;
        let mut p = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            p.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.push(p);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let params = vec![vec![1.0f32, -2.5, 3e-7], vec![], vec![0.0; 100]];
        let path = std::env::temp_dir().join(format!("misa_ckpt_{}.bin", std::process::id()));
        save(&path, &params).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(params, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("misa_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"nope").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_oversized_declared_length() {
        // magic + count=1 + a param declaring 2^40 elements backed by
        // 8 actual bytes: load must error out without attempting the
        // multi-GiB allocation.
        let path =
            std::env::temp_dir().join(format!("misa_oversize_{}.bin", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MISA");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt or truncated"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_overflowing_declared_length() {
        // u64::MAX elements: n * 4 overflows u64; must be caught by the
        // checked multiply, not wrap around.
        let path =
            std::env::temp_dir().join(format!("misa_overflow_{}.bin", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MISA");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_overdeclared_param_count() {
        // count says 1000 params but the file ends after the header
        let path =
            std::env::temp_dir().join(format!("misa_count_{}.bin", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MISA");
        bytes.extend_from_slice(&1000u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("1000 params"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_data_region_errors() {
        // well-formed header, param declares 100 elements, only 10 bytes
        let path =
            std::env::temp_dir().join(format!("misa_trunc_{}.bin", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MISA");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&100u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 10]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
