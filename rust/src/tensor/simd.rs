//! Explicit-SIMD inner microkernel for the blocked GEMM cores.
//!
//! One primitive does all the work: [`axpy`] — `o[j] += a * b[j]` over
//! a packed panel row. Every core's inner loop is axpy-shaped (the NT
//! core packs a transposed panel to get there), so vectorizing this
//! single kernel covers the whole tensor layer.
//!
//! ## Why SIMD here is bit-exact
//!
//! The determinism contract says each output element accumulates over
//! its reduction dimension in strictly ascending index order. [`axpy`]
//! vectorizes across **independent output columns** `j` — lanes never
//! share an accumulator, so no reduction is reordered. Each lane
//! performs exactly the scalar operation sequence: one IEEE-754
//! rounding for the multiply (`_mm256_mul_ps`), one for the add
//! (`_mm256_add_ps`). FMA is deliberately **not** used — a fused
//! multiply-add rounds once instead of twice and would diverge from
//! the scalar path in the last bit — and Rust never auto-contracts
//! `a * b + c` into FMA, so the scalar reference is stable too. SSE/AVX
//! have no flush-to-zero or denormals-are-zero behavior unless MXCSR is
//! reconfigured, which this codebase never does. Hence
//! `SIMD ≡ scalar ≡ naive` **bitwise**, at every thread width —
//! `tests/pool.rs` pins it.
//!
//! ## Dispatch
//!
//! AVX2 is selected at runtime via `is_x86_feature_detected!` (so
//! the binary still runs on pre-AVX2 hardware) and can be forced off
//! with `MISA_SIMD=0` or [`set_simd`]`(Some(false))` — CI runs the
//! full suite forced-scalar to keep the fallback honest. Non-x86_64
//! builds compile to the scalar path only.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// 0 = auto (env + CPU detection), 1 = forced scalar, 2 = allow SIMD
/// (still subject to CPU detection).
static MODE: AtomicU8 = AtomicU8::new(0);

/// `MISA_SIMD`, read once: anything except `"0"` (or unset) allows SIMD.
fn env_allows() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("MISA_SIMD").map_or(true, |v| v.trim() != "0"))
}

#[cfg(target_arch = "x86_64")]
fn cpu_has_avx2() -> bool {
    static CPU: OnceLock<bool> = OnceLock::new();
    *CPU.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
fn cpu_has_avx2() -> bool {
    false
}

/// Whether the vector microkernel is active (mode, env, and CPU all
/// permitting). Purely informational — results are bit-identical
/// either way.
pub fn simd_enabled() -> bool {
    let allowed = match MODE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => env_allows(),
    };
    allowed && cpu_has_avx2()
}

/// Override the SIMD policy: `Some(false)` forces the scalar
/// microkernel, `Some(true)` allows SIMD (still subject to CPU
/// feature detection), `None` restores the `MISA_SIMD` environment
/// default.
pub fn set_simd(allow: Option<bool>) {
    let mode = match allow {
        Some(false) => 1,
        Some(true) => 2,
        None => 0,
    };
    MODE.store(mode, Ordering::Relaxed);
}

/// `"avx2"` or `"scalar"` — the active microkernel, for bench records
/// and log lines.
pub fn simd_label() -> &'static str {
    if simd_enabled() {
        "avx2"
    } else {
        "scalar"
    }
}

/// `o[j] += a * b[j]` for all `j` (separate mul then add — see the
/// module docs for why this is bitwise-stable). Panics if lengths
/// differ only in debug; the scalar path's `zip` truncates, so callers
/// must pass equal lengths.
#[inline]
pub fn axpy(a: f32, b: &[f32], o: &mut [f32]) {
    debug_assert_eq!(b.len(), o.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: simd_enabled() returns true only after
        // is_x86_feature_detected!("avx2") confirmed support.
        unsafe { axpy_avx2(a, b, o) };
        return;
    }
    axpy_scalar(a, b, o);
}

#[inline]
fn axpy_scalar(a: f32, b: &[f32], o: &mut [f32]) {
    for (ov, &bv) in o.iter_mut().zip(b) {
        *ov += a * bv;
    }
}

/// AVX2 axpy: 8 independent output columns per step, `mul_ps` then
/// `add_ps` (never FMA), scalar remainder. Unaligned loads/stores —
/// panel rows have arbitrary alignment.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(a: f32, b: &[f32], o: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = b.len().min(o.len());
    let bp = b.as_ptr();
    let op = o.as_mut_ptr();
    // SAFETY (all blocks): j + 8 <= n, so every 8-lane load/store is
    // in bounds for both slices; overlap is impossible (&/&mut).
    let av = unsafe { _mm256_set1_ps(a) };
    let mut j = 0usize;
    while j + 8 <= n {
        unsafe {
            let bv = _mm256_loadu_ps(bp.add(j));
            let ov = _mm256_loadu_ps(op.add(j));
            let prod = _mm256_mul_ps(av, bv); // one rounding, like scalar
            let sum = _mm256_add_ps(ov, prod); // one rounding, like scalar
            _mm256_storeu_ps(op.add(j), sum);
        }
        j += 8;
    }
    while j < n {
        unsafe {
            *op.add(j) += a * *bp.add(j);
        }
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic PRNG (xorshift) so the parity check sweeps
    /// awkward values without a rand dependency.
    fn fill(seed: &mut u64, v: &mut [f32]) {
        for x in v.iter_mut() {
            *seed ^= *seed << 13;
            *seed ^= *seed >> 7;
            *seed ^= *seed << 17;
            // mix in zeros and subnormal-adjacent magnitudes
            let u = (*seed >> 40) as u32;
            *x = if u % 11 == 0 {
                0.0
            } else {
                (u as f32 / 65536.0 - 128.0) * 1.0e-3
            };
        }
    }

    #[test]
    fn avx2_axpy_matches_scalar_bitwise_at_every_length() {
        if !cpu_has_avx2() {
            return; // nothing to compare on this host
        }
        let mut seed = 0x9e3779b97f4a7c15u64;
        for len in 0..40 {
            let mut b = vec![0.0f32; len];
            let mut o1 = vec![0.0f32; len];
            fill(&mut seed, &mut b);
            fill(&mut seed, &mut o1);
            let mut o2 = o1.clone();
            let a = 1.7182818f32;
            axpy_scalar(a, &b, &mut o1);
            unsafe { axpy_avx2(a, &b, &mut o2) };
            for (x, y) in o1.iter().zip(&o2) {
                assert_eq!(x.to_bits(), y.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn set_simd_overrides_and_restores() {
        set_simd(Some(false));
        assert!(!simd_enabled());
        assert_eq!(simd_label(), "scalar");
        set_simd(Some(true));
        assert_eq!(simd_enabled(), cpu_has_avx2());
        set_simd(None); // back to the env default for other tests
    }
}
