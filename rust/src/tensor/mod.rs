//! Host linear algebra for coordinator-side math.
//!
//! The heavy compute (transformer fwd/bwd, fused optimizer updates) runs
//! through the AOT XLA artifacts; this module covers the small dense
//! pieces the baselines do *outside* the graph: LoRA/DoRA adapter
//! projections, GaLore's low-rank range finder, column norms, and the
//! householder-free QR used for subspace orthonormalization.
//!
//! The GEMM variants come in two layers: slice cores
//! ([`gemm_nn`], [`gemm_tn_acc`], [`gemm_nt`]) that work on flat
//! row-major buffers — the single matmul implementation shared with the
//! `HostBackend` transformer — and thin [`Mat`] wrappers
//! ([`matmul`], [`matmul_tn`], [`matmul_nt`]) for coordinator code that
//! carries shapes around.

use crate::util::Rng;

// ---------------------------------------------------------------------------
// Slice-level GEMM cores over flat row-major buffers.
//
// These are THE matmul kernels of the repo: the HostBackend forward,
// backward and serving paths and the `Mat` wrappers below all route
// through them, so there is exactly one implementation to optimize.
// The zero-skip in the accumulation loops is load-bearing for sparse
// gradients (masked positions produce all-zero rows).
// ---------------------------------------------------------------------------

/// `out[m, n] = a[m, k] @ b[k, n]` (cache-friendly i-k-j loop with an
/// accumulation row).
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

/// `out[k, n] += a[m, k]^T @ b[m, n]` — weight-gradient accumulation
/// without materializing the transpose.
pub fn gemm_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m, k] = a[m, n] @ b[k, n]^T` — input gradients through a weight,
/// without materializing the transpose.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix with given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Frobenius norm squared.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Per-column L2 norms (DoRA's magnitude decomposition).
    pub fn col_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (c, &x) in row.iter().enumerate() {
                out[c] += (x as f64) * (x as f64);
            }
        }
        out.into_iter().map(|v| v.sqrt() as f32).collect()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }
}

/// C = A @ B ([`gemm_nn`] slice core behind `Mat` shapes).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    Mat::from_vec(a.rows, b.cols, gemm_nn(&a.data, &b.data, a.rows, a.cols, b.cols))
}

/// C = A^T @ B without materializing A^T ([`gemm_tn_acc`] core).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let mut c = Mat::zeros(a.cols, b.cols);
    gemm_tn_acc(&a.data, &b.data, a.rows, a.cols, b.cols, &mut c.data);
    c
}

/// C = A @ B^T without materializing B^T ([`gemm_nt`] core).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    Mat::from_vec(a.rows, b.rows, gemm_nt(&a.data, &b.data, a.rows, a.cols, b.rows))
}

/// In-place modified Gram–Schmidt: orthonormalize the columns of `m`.
/// Columns with negligible residual norm are replaced by random unit
/// vectors re-orthogonalized against the previous ones (keeps the basis
/// full rank even when the input is rank-deficient).
pub fn orthonormalize_cols(m: &mut Mat, rng: &mut Rng) {
    let (rows, cols) = (m.rows, m.cols);
    for c in 0..cols {
        // original column norm: the degeneracy test below must be
        // *relative* — normalizing a residual that is pure fp noise
        // amplifies its spurious correlation with earlier columns
        let orig_norm: f64 = (0..rows)
            .map(|r| (m.at(r, c) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        for prev in 0..c {
            let mut dot = 0.0f64;
            for r in 0..rows {
                dot += m.at(r, prev) as f64 * m.at(r, c) as f64;
            }
            for r in 0..rows {
                *m.at_mut(r, c) -= (dot as f32) * m.at(r, prev);
            }
        }
        let mut norm = 0.0f64;
        for r in 0..rows {
            norm += (m.at(r, c) as f64).powi(2);
        }
        let mut norm = norm.sqrt();
        if norm < 1e-4 * orig_norm.max(1e-30) {
            // degenerate column: re-draw
            for r in 0..rows {
                *m.at_mut(r, c) = rng.normal() as f32;
            }
            for prev in 0..c {
                let mut dot = 0.0f64;
                for r in 0..rows {
                    dot += m.at(r, prev) as f64 * m.at(r, c) as f64;
                }
                for r in 0..rows {
                    *m.at_mut(r, c) -= (dot as f32) * m.at(r, prev);
                }
            }
            norm = (0..rows).map(|r| (m.at(r, c) as f64).powi(2)).sum::<f64>().sqrt();
        }
        let inv = (1.0 / norm) as f32;
        for r in 0..rows {
            *m.at_mut(r, c) *= inv;
        }
    }
}

/// Randomized range finder (Halko et al.): an orthonormal `rows x rank`
/// basis approximating the column space of `g`. This is the SVD-free
/// subspace computation our GaLore substitute uses (DESIGN.md Sec. 4);
/// one extra power iteration sharpens the spectrum.
pub fn range_finder(g: &Mat, rank: usize, rng: &mut Rng) -> Mat {
    let rank = rank.min(g.rows).min(g.cols);
    let omega = Mat::randn(g.cols, rank, 1.0, rng);
    let mut y = matmul(g, &omega); // [rows, rank]
    orthonormalize_cols(&mut y, rng);
    // one power iteration: Y = G (G^T Y)
    let z = matmul_tn(g, &y); // [cols, rank]
    let mut y = matmul(g, &z);
    orthonormalize_cols(&mut y, rng);
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        crate::prop!("matmul", |rng| {
            let (m, k, n) = (rng.range(1, 12), rng.range(1, 12), rng.range(1, 12));
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
        });
    }

    #[test]
    fn matmul_tn_and_nt_match_transpose() {
        crate::prop!("matmul_t", |rng| {
            let (m, k, n) = (rng.range(1, 10), rng.range(1, 10), rng.range(1, 10));
            let a = Mat::randn(k, m, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
            let a2 = Mat::randn(m, k, 1.0, rng);
            let b2 = Mat::randn(n, k, 1.0, rng);
            assert_close(&matmul_nt(&a2, &b2), &matmul(&a2, &b2.transpose()), 1e-4);
        });
    }

    #[test]
    fn slice_cores_match_naive_and_accumulate() {
        let mut rng = Rng::new(29);
        let (m, k, n) = (5, 7, 4);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = naive_matmul(&a, &b);
        let got = gemm_nn(&a.data, &b.data, m, k, n);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
        // gemm_nt(a, b_nk) == a @ b_nk^T
        let b_nk = Mat::randn(n, k, 1.0, &mut rng);
        let want2 = naive_matmul(&a, &b_nk.transpose());
        let got2 = gemm_nt(&a.data, &b_nk.data, m, k, n);
        for (x, y) in got2.iter().zip(&want2.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        // gemm_tn_acc ACCUMULATES a^T @ c on top of the existing buffer
        let c = Mat::randn(m, n, 1.0, &mut rng);
        let want3 = naive_matmul(&a.transpose(), &c);
        let mut got3 = vec![1.0f32; k * n];
        gemm_tn_acc(&a.data, &c.data, m, k, n, &mut got3);
        for (x, y) in got3.iter().zip(&want3.data) {
            assert!((x - (y + 1.0)).abs() < 1e-4, "{x} vs {}", y + 1.0);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(7, 3, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn col_norms_match_definition() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 2.0]);
        let n = m.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn orthonormalize_produces_orthonormal_basis() {
        crate::prop!("qr", |rng| {
            let rows = rng.range(4, 20);
            let cols = rng.range(1, rows.min(8) + 1);
            let mut m = Mat::randn(rows, cols, 1.0, rng);
            orthonormalize_cols(&mut m, rng);
            let gram = matmul_tn(&m, &m);
            for i in 0..cols {
                for j in 0..cols {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((gram.at(i, j) - want).abs() < 1e-3,
                            "gram[{i},{j}]={}", gram.at(i, j));
                }
            }
        });
    }

    #[test]
    fn orthonormalize_handles_rank_deficiency() {
        let mut rng = Rng::new(17);
        // two identical columns
        let mut m = Mat::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        orthonormalize_cols(&mut m, &mut rng);
        let gram = matmul_tn(&m, &m);
        assert!((gram.at(0, 0) - 1.0).abs() < 1e-4);
        assert!((gram.at(1, 1) - 1.0).abs() < 1e-4);
        assert!(gram.at(0, 1).abs() < 1e-4);
    }

    #[test]
    fn range_finder_captures_low_rank_matrix() {
        // G = U V with rank 3: the basis must reconstruct G almost exactly
        let mut rng = Rng::new(23);
        let u = Mat::randn(20, 3, 1.0, &mut rng);
        let v = Mat::randn(3, 15, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let p = range_finder(&g, 3, &mut rng);
        // reconstruction P P^T G
        let ptg = matmul_tn(&p, &g);
        let rec = matmul(&p, &ptg);
        let mut err = 0.0f64;
        for (a, b) in rec.data.iter().zip(&g.data) {
            err += ((a - b) as f64).powi(2);
        }
        let rel = err / g.sq_norm();
        assert!(rel < 1e-6, "relative reconstruction error {rel}");
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }
}
