//! Host linear algebra for coordinator-side math.
//!
//! The heavy compute (transformer fwd/bwd, fused optimizer updates) runs
//! through the AOT XLA artifacts; this module covers the small dense
//! pieces the baselines do *outside* the graph: LoRA/DoRA adapter
//! projections, GaLore's low-rank range finder, column norms, and the
//! householder-free QR used for subspace orthonormalization.
//!
//! The GEMM variants come in three layers: blocked slice cores
//! ([`gemm_nn`], [`gemm_tn_acc`], [`gemm_nt`] and their `_into`
//! variants) that work on flat row-major buffers — the single matmul
//! implementation shared with the `HostBackend` transformer — the
//! worker-pool scheduling in [`par`] that fans large cores out over
//! output-row blocks, and thin [`Mat`] wrappers ([`matmul`],
//! [`matmul_tn`], [`matmul_nt`]) for coordinator code that carries
//! shapes around.

pub mod par;
pub mod simd;

pub use par::{set_threads, threads};
pub use simd::{set_simd, simd_enabled, simd_label};

use crate::util::Rng;

// ---------------------------------------------------------------------------
// Slice-level GEMM cores over flat row-major buffers.
//
// These are THE matmul kernels of the repo: the HostBackend forward,
// backward and serving paths and the `Mat` wrappers below all route
// through them, so there is exactly one implementation to optimize.
// Each core is cache-blocked (tiled over its M/N/K analogues, with the
// hot B panel packed contiguous) and parallelized over contiguous
// output-row blocks via `par::par_out_rows`.
//
// Two invariants the rest of the repo leans on:
// - The zero-skip in the accumulation loops is load-bearing for sparse
//   gradients (masked positions produce all-zero rows). Skipping a
//   zero multiplier is itself bit-exact: `out` buffers start at +0.0
//   and an accumulator can never become -0.0 (x + -x rounds to +0.0,
//   and +0.0 + -0.0 = +0.0 in round-to-nearest), so adding the ±0.0
//   product would never change a single bit.
// - Every output element accumulates over its reduction dimension in
//   strictly ascending index order, and each output row belongs to one
//   task: results are bit-identical at every thread count, and
//   bit-identical to the pre-blocking naive kernels.
//
// All three inner loops are the same axpy shape — `orow += aik *
// panel_row` — dispatched through `simd::axpy`, which vectorizes
// across independent output columns with separate mul-then-add so the
// SIMD path is also bit-identical to scalar (see `simd` module docs).
// ---------------------------------------------------------------------------

/// Reduction-dimension tile: rows of the packed B panel in
/// [`gemm_nn_into`], dot-product segment elsewhere.
const KC: usize = 64;

/// Output-column tile: columns of the packed B panel. `KC * NC` f32s =
/// 32 KiB — the panel lives in L1 while a row block streams past it.
const NC: usize = 128;

/// `out[m, n] = a[m, k] @ b[k, n]` into a caller-owned buffer
/// (workspace reuse on the decode hot path).
pub fn gemm_nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // timer before the span: the roofline attributes this call's
    // 2·m·k·n FLOPs to the *enclosing* module span
    let _kt = crate::obs::profile::kernel_timer("gemm_nn", (m * k * n) as u64);
    let _sp = crate::span!("gemm_nn", "tensor");
    let workers = par::plan_workers(m, m * k * n);
    par::par_out_rows(out, m, n, workers, |row0, ochunk| {
        let rows = ochunk.len() / n;
        gemm_nn_rows(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, ochunk);
    });
}

thread_local! {
    /// Per-thread B-panel scratch for [`gemm_nn_rows`]. Thread-local
    /// (not per-call) so the serial decode hot path — 8 GEMMs per
    /// layer per token, all on the caller thread — packs into one warm
    /// 32 KiB buffer instead of reallocating it every call. Pool
    /// workers are persistent now, so each keeps its own warm panel
    /// across jobs for free.
    static NN_PANEL: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// One row block of [`gemm_nn_into`]: tile over N then K, pack the
/// `kb x nb` B panel once, and stream the block's A rows over it. The
/// (jc outer, pc inner) loop order keeps each output element's
/// accumulation in ascending-k order.
fn gemm_nn_rows(a: &[f32], b: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    NN_PANEL.with(|cell| {
        let mut panel = cell.borrow_mut();
        panel.resize(KC * NC, 0.0);
        gemm_nn_rows_packed(a, b, rows, k, n, out, &mut panel[..]);
    });
}

fn gemm_nn_rows_packed(a: &[f32], b: &[f32], rows: usize, k: usize, n: usize,
                       out: &mut [f32], panel: &mut [f32]) {
    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kb = KC.min(k - pc);
            for (kk, prow) in panel.chunks_mut(nb).take(kb).enumerate() {
                let src = (pc + kk) * n + jc;
                prow.copy_from_slice(&b[src..src + nb]);
            }
            for i in 0..rows {
                let arow = &a[i * k + pc..i * k + pc + kb];
                let orow = &mut out[i * n + jc..i * n + jc + nb];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    simd::axpy(aik, &panel[kk * nb..(kk + 1) * nb], orow);
                }
            }
            pc += kb;
        }
        jc += nb;
    }
}

/// `out[m, n] = a[m, k] @ b[k, n]`.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    gemm_nn_into(a, b, m, k, n, &mut out);
    out
}

/// `out[k, n] += a[m, k]^T @ b[m, n]` — weight-gradient accumulation
/// without materializing the transpose. Parallel over blocks of the
/// `k` output rows; within a block, tiled over N with the `m` reduction
/// streamed in ascending order (the order backward-pass accumulation
/// committed to before blocking).
pub fn gemm_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    let _kt = crate::obs::profile::kernel_timer("gemm_tn", (m * k * n) as u64);
    let _sp = crate::span!("gemm_tn", "tensor");
    let workers = par::plan_workers(k, m * k * n);
    par::par_out_rows(out, k, n, workers, |kk0, ochunk| {
        let krows = ochunk.len() / n;
        let mut jc = 0;
        while jc < n {
            let nb = NC.min(n - jc);
            for i in 0..m {
                let arow = &a[i * k + kk0..i * k + kk0 + krows];
                let brow = &b[i * n + jc..i * n + jc + nb];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    simd::axpy(av, brow, &mut ochunk[kk * n + jc..kk * n + jc + nb]);
                }
            }
            jc += nb;
        }
    });
}

thread_local! {
    /// Per-thread transposed-B-panel scratch for [`gemm_nt_into`]
    /// (`KC x JC` = 16 KiB), same warm-reuse rationale as `NN_PANEL`.
    static NT_PANEL: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// `out[m, k] = a[m, n] @ b[k, n]^T` into a caller-owned buffer —
/// input gradients through a weight, without materializing the
/// transpose. Parallel over blocks of the `m` output rows; within a
/// block, tiled over the B rows and the `n` reduction with the `nb x
/// jb` B patch packed *transposed*, which turns the inner loop from a
/// strided dot product into the same contiguous axpy the other cores
/// use (`orow += a[i, pc+t] * panel_row_t`). Per output element the
/// operation sequence is unchanged: one mul and one add per reduction
/// index, ascending in `n`, flushed through `out` between tiles — so
/// this restructure (and its new zero-skip, see the invariants above)
/// is bit-identical to the previous dot-product form.
pub fn gemm_nt_into(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    out.fill(0.0);
    if m == 0 || k == 0 {
        return;
    }
    let _kt = crate::obs::profile::kernel_timer("gemm_nt", (m * n * k) as u64);
    let _sp = crate::span!("gemm_nt", "tensor");
    // B-row tile (output-column tile) of the nt core.
    const JC: usize = 64;
    let workers = par::plan_workers(m, m * k * n);
    par::par_out_rows(out, m, k, workers, |row0, ochunk| {
        NT_PANEL.with(|cell| {
            let mut panel = cell.borrow_mut();
            panel.resize(KC * JC, 0.0);
            let rows = ochunk.len() / k;
            let mut jc = 0;
            while jc < k {
                let jb = JC.min(k - jc);
                let mut pc = 0;
                while pc < n {
                    let nb = KC.min(n - pc);
                    // pack the patch transposed: panel[t][j] = b[jc+j][pc+t]
                    for j in 0..jb {
                        let brow = &b[(jc + j) * n + pc..(jc + j) * n + pc + nb];
                        for (t, &bv) in brow.iter().enumerate() {
                            panel[t * jb + j] = bv;
                        }
                    }
                    for i in 0..rows {
                        let arow = &a[(row0 + i) * n + pc..(row0 + i) * n + pc + nb];
                        let orow = &mut ochunk[i * k + jc..i * k + jc + jb];
                        for (t, &av) in arow.iter().enumerate() {
                            if av == 0.0 {
                                continue;
                            }
                            simd::axpy(av, &panel[t * jb..(t + 1) * jb], orow);
                        }
                    }
                    pc += nb;
                }
                jc += jb;
            }
        });
    });
}

/// `out[m, k] = a[m, n] @ b[k, n]^T`.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    gemm_nt_into(a, b, m, n, k, &mut out);
    out
}

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix with given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Frobenius norm squared.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Per-column L2 norms (DoRA's magnitude decomposition).
    pub fn col_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (c, &x) in row.iter().enumerate() {
                out[c] += (x as f64) * (x as f64);
            }
        }
        out.into_iter().map(|v| v.sqrt() as f32).collect()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }
}

/// C = A @ B ([`gemm_nn`] slice core behind `Mat` shapes).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    Mat::from_vec(a.rows, b.cols, gemm_nn(&a.data, &b.data, a.rows, a.cols, b.cols))
}

/// C = A^T @ B without materializing A^T ([`gemm_tn_acc`] core).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let mut c = Mat::zeros(a.cols, b.cols);
    gemm_tn_acc(&a.data, &b.data, a.rows, a.cols, b.cols, &mut c.data);
    c
}

/// C = A @ B^T without materializing B^T ([`gemm_nt`] core).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    Mat::from_vec(a.rows, b.rows, gemm_nt(&a.data, &b.data, a.rows, a.cols, b.rows))
}

/// In-place modified Gram–Schmidt: orthonormalize the columns of `m`.
/// Columns with negligible residual norm are replaced by random unit
/// vectors re-orthogonalized against the previous ones (keeps the basis
/// full rank even when the input is rank-deficient).
pub fn orthonormalize_cols(m: &mut Mat, rng: &mut Rng) {
    let (rows, cols) = (m.rows, m.cols);
    for c in 0..cols {
        // original column norm: the degeneracy test below must be
        // *relative* — normalizing a residual that is pure fp noise
        // amplifies its spurious correlation with earlier columns
        let orig_norm: f64 = (0..rows)
            .map(|r| (m.at(r, c) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        for prev in 0..c {
            let mut dot = 0.0f64;
            for r in 0..rows {
                dot += m.at(r, prev) as f64 * m.at(r, c) as f64;
            }
            for r in 0..rows {
                *m.at_mut(r, c) -= (dot as f32) * m.at(r, prev);
            }
        }
        let mut norm = 0.0f64;
        for r in 0..rows {
            norm += (m.at(r, c) as f64).powi(2);
        }
        let mut norm = norm.sqrt();
        if norm < 1e-4 * orig_norm.max(1e-30) {
            // degenerate column: re-draw
            for r in 0..rows {
                *m.at_mut(r, c) = rng.normal() as f32;
            }
            for prev in 0..c {
                let mut dot = 0.0f64;
                for r in 0..rows {
                    dot += m.at(r, prev) as f64 * m.at(r, c) as f64;
                }
                for r in 0..rows {
                    *m.at_mut(r, c) -= (dot as f32) * m.at(r, prev);
                }
            }
            norm = (0..rows).map(|r| (m.at(r, c) as f64).powi(2)).sum::<f64>().sqrt();
        }
        let inv = (1.0 / norm) as f32;
        for r in 0..rows {
            *m.at_mut(r, c) *= inv;
        }
    }
}

/// Randomized range finder (Halko et al.): an orthonormal `rows x rank`
/// basis approximating the column space of `g`. This is the SVD-free
/// subspace computation our GaLore substitute uses (DESIGN.md Sec. 4);
/// one extra power iteration sharpens the spectrum.
pub fn range_finder(g: &Mat, rank: usize, rng: &mut Rng) -> Mat {
    let rank = rank.min(g.rows).min(g.cols);
    let omega = Mat::randn(g.cols, rank, 1.0, rng);
    let mut y = matmul(g, &omega); // [rows, rank]
    orthonormalize_cols(&mut y, rng);
    // one power iteration: Y = G (G^T Y)
    let z = matmul_tn(g, &y); // [cols, rank]
    let mut y = matmul(g, &z);
    orthonormalize_cols(&mut y, rng);
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        crate::prop!("matmul", |rng| {
            let (m, k, n) = (rng.range(1, 12), rng.range(1, 12), rng.range(1, 12));
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
        });
    }

    #[test]
    fn matmul_tn_and_nt_match_transpose() {
        crate::prop!("matmul_t", |rng| {
            let (m, k, n) = (rng.range(1, 10), rng.range(1, 10), rng.range(1, 10));
            let a = Mat::randn(k, m, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
            let a2 = Mat::randn(m, k, 1.0, rng);
            let b2 = Mat::randn(n, k, 1.0, rng);
            assert_close(&matmul_nt(&a2, &b2), &matmul(&a2, &b2.transpose()), 1e-4);
        });
    }

    #[test]
    fn slice_cores_match_naive_and_accumulate() {
        let mut rng = Rng::new(29);
        let (m, k, n) = (5, 7, 4);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let want = naive_matmul(&a, &b);
        let got = gemm_nn(&a.data, &b.data, m, k, n);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
        // gemm_nt(a, b_nk) == a @ b_nk^T
        let b_nk = Mat::randn(n, k, 1.0, &mut rng);
        let want2 = naive_matmul(&a, &b_nk.transpose());
        let got2 = gemm_nt(&a.data, &b_nk.data, m, k, n);
        for (x, y) in got2.iter().zip(&want2.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        // gemm_tn_acc ACCUMULATES a^T @ c on top of the existing buffer
        let c = Mat::randn(m, n, 1.0, &mut rng);
        let want3 = naive_matmul(&a.transpose(), &c);
        let mut got3 = vec![1.0f32; k * n];
        gemm_tn_acc(&a.data, &c.data, m, k, n, &mut got3);
        for (x, y) in got3.iter().zip(&want3.data) {
            assert!((x - (y + 1.0)).abs() < 1e-4, "{x} vs {}", y + 1.0);
        }
    }

    /// Blocked cores vs the naive triple-loop oracle on ragged shapes:
    /// m, k, n straddling the KC=64 / NC=128 tile edges (not multiples
    /// of either), plus sub-tile and single-row/column degenerates.
    #[test]
    fn blocked_cores_match_oracle_on_ragged_shapes() {
        let mut rng = Rng::new(31);
        for &(m, k, n) in
            &[(65, 63, 129), (1, 130, 7), (67, 1, 131), (3, 5, 1), (70, 129, 65)]
        {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = naive_matmul(&a, &b);
            let got = gemm_nn(&a.data, &b.data, m, k, n);
            for (x, y) in got.iter().zip(&want.data) {
                assert!((x - y).abs() < 2e-3, "nn {m}x{k}x{n}: {x} vs {y}");
            }
            // nt: a[m,n'] @ b[k',n']^T with n'=k, k'=n reuses the shapes
            let bt = b.transpose(); // [n, k]
            let want_nt = naive_matmul(&a, &b);
            let got_nt = gemm_nt(&a.data, &bt.data, m, k, n);
            for (x, y) in got_nt.iter().zip(&want_nt.data) {
                assert!((x - y).abs() < 2e-3, "nt {m}x{k}x{n}: {x} vs {y}");
            }
            // tn: a[m,k]^T @ c[m,n] accumulates on top of existing data
            let c = Mat::randn(m, n, 1.0, &mut rng);
            let want_tn = naive_matmul(&a.transpose(), &c);
            let mut got_tn = vec![0.5f32; k * n];
            gemm_tn_acc(&a.data, &c.data, m, k, n, &mut got_tn);
            for (x, y) in got_tn.iter().zip(&want_tn.data) {
                assert!((x - (y + 0.5)).abs() < 2e-3, "tn {m}x{k}x{n}: {x} vs {}", y + 0.5);
            }
        }
    }

    /// The reduction order we commit to (ascending reduction index, one
    /// worker per output row) makes every core bit-identical across
    /// thread counts — not merely close. Large enough shapes to clear
    /// the parallel work floor, ragged against the tiles.
    #[test]
    fn cores_are_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(47);
        let (m, k, n) = (97, 161, 133);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let c = Mat::randn(m, n, 1.0, &mut rng);
        let run = |t: usize| {
            set_threads(t);
            let nn = gemm_nn(&a.data, &b.data, m, k, n);
            let nt = gemm_nt(&a.data, &bt.data, m, k, n);
            let mut tn = vec![0.25f32; k * n];
            gemm_tn_acc(&a.data, &c.data, m, k, n, &mut tn);
            set_threads(0);
            (nn, nt, tn)
        };
        let (nn1, nt1, tn1) = run(1);
        for t in [2usize, 4] {
            let (nn, nt, tn) = run(t);
            assert!(
                nn.iter().zip(&nn1).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gemm_nn differs at {t} threads"
            );
            assert!(
                nt.iter().zip(&nt1).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gemm_nt differs at {t} threads"
            );
            assert!(
                tn.iter().zip(&tn1).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gemm_tn_acc differs at {t} threads"
            );
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(7, 3, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn col_norms_match_definition() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 2.0]);
        let n = m.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn orthonormalize_produces_orthonormal_basis() {
        crate::prop!("qr", |rng| {
            let rows = rng.range(4, 20);
            let cols = rng.range(1, rows.min(8) + 1);
            let mut m = Mat::randn(rows, cols, 1.0, rng);
            orthonormalize_cols(&mut m, rng);
            let gram = matmul_tn(&m, &m);
            for i in 0..cols {
                for j in 0..cols {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((gram.at(i, j) - want).abs() < 1e-3,
                            "gram[{i},{j}]={}", gram.at(i, j));
                }
            }
        });
    }

    #[test]
    fn orthonormalize_handles_rank_deficiency() {
        let mut rng = Rng::new(17);
        // two identical columns
        let mut m = Mat::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        orthonormalize_cols(&mut m, &mut rng);
        let gram = matmul_tn(&m, &m);
        assert!((gram.at(0, 0) - 1.0).abs() < 1e-4);
        assert!((gram.at(1, 1) - 1.0).abs() < 1e-4);
        assert!(gram.at(0, 1).abs() < 1e-4);
    }

    #[test]
    fn range_finder_captures_low_rank_matrix() {
        // G = U V with rank 3: the basis must reconstruct G almost exactly
        let mut rng = Rng::new(23);
        let u = Mat::randn(20, 3, 1.0, &mut rng);
        let v = Mat::randn(3, 15, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let p = range_finder(&g, 3, &mut rng);
        // reconstruction P P^T G
        let ptg = matmul_tn(&p, &g);
        let rec = matmul(&p, &ptg);
        let mut err = 0.0f64;
        for (a, b) in rec.data.iter().zip(&g.data) {
            err += ((a - b) as f64).powi(2);
        }
        let rel = err / g.sq_norm();
        assert!(rel < 1e-6, "relative reconstruction error {rel}");
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }
}
