//! Worker-pool parallelism for the GEMM slice cores.
//!
//! rayon is not vendorable offline (same constraint that hand-rolled
//! the PRNG and the TOML parser), so the pool is built on
//! `std::thread::scope`: each sufficiently large kernel invocation
//! partitions its *output rows* into contiguous blocks, spawns one
//! scoped worker per extra block, and runs the first block on the
//! calling thread. Scoped threads make the borrow story trivially safe
//! — no lifetime erasure, no channels, no unsafe.
//!
//! ## The reduction order we commit to
//!
//! Every core accumulates each output element over its reduction
//! dimension in strictly ascending index order, and each output row is
//! owned by exactly one worker. Partitioning therefore never reorders
//! a single floating-point addition: results are **bit-identical at
//! every thread count**, including `threads = 1` versus the pre-blocking
//! naive kernels. `tensor::tests` pins this invariant.
//!
//! ## The knob
//!
//! Thread count resolves as: [`set_threads`] (the `--threads N` CLI
//! flag) if called with `n >= 1`, else the `MISA_THREADS` environment
//! variable, else 1. `set_threads(0)` drops back to the environment
//! default. Small kernels stay serial regardless — see
//! `plan_workers` — so the knob never pessimizes tiny shapes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// 0 = "unset, use the environment default".
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// `MISA_THREADS`, read once; anything unparsable or zero means 1.
fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("MISA_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    })
}

/// Worker-pool width the GEMM cores may use (>= 1).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Override the worker-pool width (the `--threads` flag). `0` resets
/// to the `MISA_THREADS` environment default.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Minimum multiply-accumulates each *extra* worker must bring; below
/// this, thread spawn + join overhead outweighs the parallel win and
/// the kernel stays serial (decode-sized GEMMs take this path).
const MIN_MACS_PER_WORKER: usize = 128 * 1024;

/// How many workers a kernel with `rows` independent output rows and
/// `macs` total multiply-accumulates should use.
pub(crate) fn plan_workers(rows: usize, macs: usize) -> usize {
    plan_workers_at(threads(), rows, macs)
}

/// [`plan_workers`] at an explicit pool width (pure; unit-testable
/// without touching the process-global knob).
fn plan_workers_at(t: usize, rows: usize, macs: usize) -> usize {
    if t <= 1 || rows < 2 {
        return 1;
    }
    t.min(rows).min((macs / MIN_MACS_PER_WORKER).max(1))
}

/// Run `body(row0, out_chunk)` over `out` split into `workers`
/// contiguous row blocks (`out.len() == rows * stride`). Blocks after
/// the first run on scoped worker threads; the first runs on the
/// caller so a `workers`-wide plan occupies exactly `workers` cores.
pub(crate) fn par_out_rows<F>(out: &mut [f32], rows: usize, stride: usize, workers: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * stride);
    if workers <= 1 || rows < 2 {
        body(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    // Scoped threads don't inherit the caller's span stack: capture
    // the enclosing span here, hand it to each worker span explicitly
    // so the trace tree stays connected across the fan-out.
    let parent = crate::obs::span::current();
    std::thread::scope(|s| {
        let body = &body;
        let mut rest = out;
        let mut row0 = 0usize;
        let mut first: Option<(usize, &mut [f32])> = None;
        while row0 < rows {
            let take = chunk_rows.min(rows - row0);
            let tail = std::mem::take(&mut rest);
            let (chunk, remainder) = tail.split_at_mut(take * stride);
            rest = remainder;
            if first.is_none() {
                // deferred: the caller's own share, run after spawning
                first = Some((row0, chunk));
            } else {
                s.spawn(move || {
                    let _sp = crate::obs::span::span_child("gemm_worker", "tensor", parent);
                    body(row0, chunk)
                });
            }
            row0 += take;
        }
        if let Some((r0, chunk)) = first {
            body(r0, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_respects_knob_rows_and_work_floor() {
        // plenty of rows and work: full width
        assert_eq!(plan_workers_at(4, 1024, 64 * MIN_MACS_PER_WORKER), 4);
        // fewer rows than threads: one worker per row at most
        assert_eq!(plan_workers_at(4, 2, 64 * MIN_MACS_PER_WORKER), 2);
        // small kernels stay serial no matter the knob
        assert_eq!(plan_workers_at(4, 1024, MIN_MACS_PER_WORKER / 2), 1);
        // width 1 always serial
        assert_eq!(plan_workers_at(1, 1024, 64 * MIN_MACS_PER_WORKER), 1);
        // the resolved global knob is always at least 1
        assert!(threads() >= 1);
    }

    #[test]
    fn partition_covers_every_row_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rows = 37;
        let stride = 3;
        let mut out = vec![0.0f32; rows * stride];
        let calls = AtomicUsize::new(0);
        par_out_rows(&mut out, rows, stride, 4, |row0, chunk| {
            calls.fetch_add(1, Ordering::Relaxed);
            for (r, row) in chunk.chunks_mut(stride).enumerate() {
                for x in row.iter_mut() {
                    *x += (row0 + r) as f32;
                }
            }
        });
        assert_eq!(calls.load(Ordering::Relaxed), 4);
        for (r, row) in out.chunks(stride).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r} misassigned: {row:?}");
        }
    }
}
