//! Persistent work-stealing worker pool for the GEMM slice cores and
//! the `HostBackend` fan-outs.
//!
//! rayon is not vendorable offline (same constraint that hand-rolled
//! the PRNG and the TOML parser), so the pool is built directly on
//! `std::thread` + condvars. Earlier revisions rebuilt a scoped pool
//! with `std::thread::scope` on **every** kernel invocation; spawn +
//! join cost put a 128 Ki-MAC floor under parallelism and decode-sized
//! kernels always ran serial. The pool is now **persistent**: workers
//! are spawned lazily at the first large kernel, park on a condvar
//! between jobs, are resized when `set_threads` / `MISA_THREADS`
//! changes the knob, and retire cleanly on [`Pool::shutdown`] (which
//! [`Pool`]'s `Drop` runs too).
//!
//! ## Work stealing
//!
//! A job is an index range of independent tasks (row blocks, slots).
//! The range is split into one contiguous sub-range per participant,
//! each packed into a single `AtomicU64` (`lo << 32 | hi`) that acts
//! as a deque: the owner pops from the front with a CAS, idle
//! participants steal the back half of the richest victim with a CAS.
//! Ragged shapes therefore load-balance instead of waiting on the
//! slowest static chunk. The submitting thread is always participant
//! 0 — correctness never depends on a worker waking up.
//!
//! ABA on the packed ranges is structurally impossible: a task index
//! is claimed by exactly one successful CAS transition, ranges only
//! shrink (pop/steal) or move to the thief's own empty slot, so a
//! previously observed `(lo, hi)` packing can never reappear with any
//! of its tasks still unclaimed.
//!
//! ## The reduction order we commit to
//!
//! Every core accumulates each output element over its reduction
//! dimension in strictly ascending index order, and each output row is
//! owned by exactly one task. Task partitioning and stealing move
//! *which thread* computes a row, never the order of a single
//! floating-point addition: results are **bit-identical at every
//! thread count** — including `threads = 1` versus the pre-blocking
//! naive kernels — and identical whichever participant steals what.
//! `tensor::tests` and `tests/pool.rs` pin this invariant.
//!
//! ## Observability
//!
//! Each parallel run publishes to the global [`crate::obs::metrics`]
//! registry once (batched — the registry mutex is never touched from
//! the task hot loop): `pool.tasks`, `pool.steals`, `pool.busy_us`,
//! `pool.parks`, `pool.unparks` counters, the `pool.workers` and
//! `pool.utilization` gauges (busy time over participants × dispatch
//! span), and one `pool.park_wait_us` histogram sample (worker time
//! parked since the previous dispatch). A `pool` event also lands in
//! the flight recorder when it is on.
//! Every task opens a `pool_task` span parented to the span that was
//! open on the submitting thread, so Perfetto traces stay connected
//! across the fan-out even though the workers are long-lived.
//!
//! ## The knob
//!
//! Thread count resolves as: [`set_threads`] (the `--threads N` CLI
//! flag) if called with `n >= 1`, else the `MISA_THREADS` environment
//! variable, else 1. `set_threads(0)` drops back to the environment
//! default. Small kernels stay serial regardless — see
//! `plan_workers` — so the knob never pessimizes tiny shapes.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// 0 = "unset, use the environment default".
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// `MISA_THREADS`, read once; anything unparsable or zero means 1.
fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("MISA_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    })
}

/// Worker-pool width the GEMM cores may use (>= 1).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Override the worker-pool width (the `--threads` flag). `0` resets
/// to the `MISA_THREADS` environment default. The global pool
/// reconciles its resident worker count at the next parallel dispatch.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Minimum multiply-accumulates each *extra* worker must bring; below
/// this the kernel stays serial. The persistent pool dropped the
/// per-call spawn/join cost that used to set this floor at 128 Ki —
/// waking a parked worker is ~µs, so kernels a quarter that size now
/// profit (decode-sized projections at batch >= 4 cross this line).
const MIN_MACS_PER_WORKER: usize = 32 * 1024;

/// How many workers a kernel with `rows` independent output rows and
/// `macs` total multiply-accumulates should use.
pub(crate) fn plan_workers(rows: usize, macs: usize) -> usize {
    plan_workers_at(threads(), rows, macs)
}

/// [`plan_workers`] at an explicit pool width (pure; unit-testable
/// without touching the process-global knob).
fn plan_workers_at(t: usize, rows: usize, macs: usize) -> usize {
    if t <= 1 || rows < 2 {
        return 1;
    }
    t.min(rows).min((macs / MIN_MACS_PER_WORKER).max(1))
}

// ---------------------------------------------------------------------------
// Packed task ranges: one AtomicU64 per participant, (lo << 32) | hi.
// ---------------------------------------------------------------------------

#[inline]
fn pack(lo: u32, hi: u32) -> u64 {
    ((lo as u64) << 32) | hi as u64
}

#[inline]
fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// Pop the front task of a range (owner side of the deque).
fn pop_front(range: &AtomicU64) -> Option<usize> {
    let mut cur = range.load(Ordering::Acquire);
    loop {
        let (lo, hi) = unpack(cur);
        if lo >= hi {
            return None;
        }
        match range.compare_exchange_weak(
            cur,
            pack(lo + 1, hi),
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => return Some(lo as usize),
            Err(v) => cur = v,
        }
    }
}

/// Steal the back half (rounded up) of the richest victim range.
/// Returns the stolen `[lo, hi)` interval, or `None` once every range
/// is empty.
fn steal_half(ranges: &[AtomicU64], me: usize) -> Option<(u32, u32)> {
    loop {
        let mut best: Option<(usize, u64, u32)> = None;
        for (i, r) in ranges.iter().enumerate() {
            if i == me {
                continue;
            }
            let cur = r.load(Ordering::Acquire);
            let (lo, hi) = unpack(cur);
            let len = hi.saturating_sub(lo);
            if len > 0 && best.map_or(true, |(_, _, blen)| len > blen) {
                best = Some((i, cur, len));
            }
        }
        let (i, cur, len) = best?;
        let (lo, hi) = unpack(cur);
        let take = len.div_ceil(2);
        let new_hi = hi - take;
        if ranges[i]
            .compare_exchange(cur, pack(lo, new_hi), Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            return Some((new_hi, hi));
        }
        // lost the race — rescan; ranges only shrink, so this terminates
    }
}

/// Split `0..n_tasks` into `participants` contiguous packed ranges.
fn build_ranges(participants: usize, n_tasks: usize) -> Vec<AtomicU64> {
    debug_assert!(n_tasks < u32::MAX as usize);
    let base = n_tasks / participants;
    let rem = n_tasks % participants;
    let mut lo = 0u32;
    (0..participants)
        .map(|p| {
            let len = (base + usize::from(p < rem)) as u32;
            let r = AtomicU64::new(pack(lo, lo + len));
            lo += len;
            r
        })
        .collect()
}

// ---------------------------------------------------------------------------
// The pool.
// ---------------------------------------------------------------------------

/// One in-flight job. `data`/`call` are a type-erased borrow of the
/// submitter's closure — valid for the whole job because the submitter
/// blocks until `remaining == 0` before returning (and tasks are only
/// ever claimed while `remaining > 0`).
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    /// per-participant task deques, packed `(lo << 32) | hi`
    ranges: Vec<AtomicU64>,
    /// participant slots claimed in wake order; slot 0 is the caller
    next_slot: AtomicUsize,
    /// tasks not yet finished executing
    remaining: AtomicUsize,
    steals: AtomicU64,
    busy_ns: AtomicU64,
    /// span open on the submitting thread, re-parented onto every task
    parent: Option<&'static str>,
    /// first panic payload out of any task; re-raised on the submitter
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: `data` is dereferenced only through `call` (which requires
// the closure to be `Sync`, enforced by `Pool::run`'s bound) and only
// while the submitting frame is alive (see the `Job` doc comment).
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    /// bumped once per submitted job; tells a parked worker "new job"
    epoch: u64,
    /// the in-flight job, if any
    job: Option<Arc<Job>>,
    /// desired resident worker count
    target: usize,
    /// live worker threads
    alive: usize,
}

struct Inner {
    state: Mutex<State>,
    /// workers park here between jobs
    work_cv: Condvar,
    /// submitters wait here for `remaining == 0`; `shutdown` for
    /// `alive == 0`
    done_cv: Condvar,
    /// park/unpark transitions, drained into the metrics registry once
    /// per run (never from the task hot loop)
    parks: AtomicU64,
    unparks: AtomicU64,
    /// nanoseconds workers spent parked on `work_cv`, drained into the
    /// `pool.park_wait_us` histogram once per run — the profiler's
    /// idle-thread samples cross-check against this counter
    park_wait_ns: AtomicU64,
}

thread_local! {
    /// True while this thread is executing pool tasks: nested `run`
    /// calls from inside a task execute inline, so a task body may
    /// freely call back into parallel kernels without self-deadlock.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// A persistent worker pool. The process-global instance behind
/// [`run_tasks`] serves every kernel; tests build private instances to
/// exercise resize/shutdown/drop without touching global state.
pub struct Pool {
    inner: Arc<Inner>,
    /// every worker ever spawned; drained + joined on shutdown
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// serializes submissions: one job in flight per pool
    submit: Mutex<()>,
}

impl Default for Pool {
    fn default() -> Self {
        Self::new()
    }
}

impl Pool {
    /// An empty pool: no threads until [`Pool::resize`] (the global
    /// pool resizes lazily at the first large kernel).
    pub fn new() -> Self {
        Pool {
            inner: Arc::new(Inner {
                state: Mutex::new(State { epoch: 0, job: None, target: 0, alive: 0 }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                parks: AtomicU64::new(0),
                unparks: AtomicU64::new(0),
                park_wait_ns: AtomicU64::new(0),
            }),
            handles: Mutex::new(Vec::new()),
            submit: Mutex::new(()),
        }
    }

    /// Live resident workers (diagnostics/tests; the caller thread is
    /// not counted).
    pub fn workers(&self) -> usize {
        self.inner.state.lock().unwrap_or_else(|e| e.into_inner()).alive
    }

    /// Set the resident worker count. Growing spawns immediately;
    /// shrinking wakes the parked excess so it retires (workers mid-job
    /// retire at their next park). Concurrent `resize` calls race on
    /// last-writer-wins; the global pool only resizes under its submit
    /// serialization.
    pub fn resize(&self, workers: usize) {
        let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        st.target = workers;
        if st.alive < workers {
            let spawn = workers - st.alive;
            st.alive = workers;
            drop(st);
            let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
            for _ in 0..spawn {
                let inner = Arc::clone(&self.inner);
                handles.push(
                    std::thread::Builder::new()
                        .name("misa-pool".to_string())
                        .spawn(move || worker_loop(inner))
                        .expect("spawning pool worker"),
                );
            }
        } else if st.alive > workers {
            drop(st);
            self.inner.work_cv.notify_all();
        }
    }

    /// Retire every worker and join it. Reusable afterwards — the next
    /// [`Pool::resize`] respawns; a `run` on a shut-down pool executes
    /// entirely on the caller.
    pub fn shutdown(&self) {
        let _g = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        self.resize(0);
        {
            let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
            while st.alive > 0 {
                st = self.inner.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        let handles: Vec<_> = {
            let mut h = self.handles.lock().unwrap_or_else(|e| e.into_inner());
            h.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Execute `f(i)` for every `i in 0..n_tasks` across up to
    /// `participants` threads (the caller plus claimed workers). Tasks
    /// must be independent — any two may run concurrently. Blocks until
    /// every task has finished; a panicking task is captured and
    /// re-raised here after the job drains, so the pool survives.
    /// Nested calls from inside a task run inline.
    pub fn run<F: Fn(usize) + Sync>(&self, participants: usize, n_tasks: usize, f: F) {
        if n_tasks == 0 {
            return;
        }
        let participants = participants.clamp(1, n_tasks);
        if participants <= 1 || IN_POOL.with(|c| c.get()) {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        unsafe fn call_thunk<F: Fn(usize)>(data: *const (), i: usize) {
            unsafe { (*(data as *const F))(i) }
        }
        let job = Arc::new(Job {
            data: &f as *const F as *const (),
            call: call_thunk::<F>,
            ranges: build_ranges(participants, n_tasks),
            next_slot: AtomicUsize::new(1),
            remaining: AtomicUsize::new(n_tasks),
            steals: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            parent: crate::obs::span::current(),
            panic: Mutex::new(None),
        });
        let t0 = Instant::now();
        let submit = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(Arc::clone(&job));
            let wake = (participants - 1).min(st.alive);
            drop(st);
            // busy workers that miss these wakeups still catch the new
            // epoch when they next re-check; the caller drains whatever
            // nobody claims
            for _ in 0..wake {
                self.inner.work_cv.notify_one();
            }
        }
        participate(&self.inner, &job, 0);
        {
            let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
            while job.remaining.load(Ordering::Acquire) != 0 {
                st = self.inner.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
        }
        drop(submit);
        self.publish_metrics(&job, n_tasks, t0.elapsed());
        if let Some(p) = job.panic.lock().unwrap_or_else(|e| e.into_inner()).take() {
            std::panic::resume_unwind(p);
        }
    }

    /// One batched registry update per parallel run — the counters the
    /// pool exposes (`pool.*`) without ever locking the registry from
    /// the task hot loop. `wall` is the dispatch span (submit →
    /// completion), the denominator of the utilization gauge.
    fn publish_metrics(&self, job: &Job, n_tasks: usize, wall: std::time::Duration) {
        use crate::obs::metrics;
        metrics::counter_add("pool.tasks", n_tasks as u64);
        let steals = job.steals.load(Ordering::Relaxed);
        if steals > 0 {
            metrics::counter_add("pool.steals", steals);
        }
        let busy_ns = job.busy_ns.load(Ordering::Relaxed);
        metrics::counter_add("pool.busy_us", busy_ns / 1_000);
        let parks = self.inner.parks.swap(0, Ordering::Relaxed);
        if parks > 0 {
            metrics::counter_add("pool.parks", parks);
        }
        let unparks = self.inner.unparks.swap(0, Ordering::Relaxed);
        if unparks > 0 {
            metrics::counter_add("pool.unparks", unparks);
        }
        // park-wait since the last dispatch, one histogram sample per
        // dispatch: long waits mean an under-fed pool, near-zero waits
        // with high utilization mean a saturated one
        let park_wait_ns = self.inner.park_wait_ns.swap(0, Ordering::Relaxed);
        if park_wait_ns > 0 {
            metrics::observe("pool.park_wait_us", park_wait_ns as f64 / 1e3);
        }
        // fraction of the dispatch's participant-time actually spent in
        // `participate` (clamped: a straggler finishing its bookkeeping
        // after the job drains can nudge the ratio past 1)
        let participants = job.ranges.len() as f64;
        let wall_ns = (wall.as_nanos() as u64).max(1) as f64;
        metrics::gauge_set(
            "pool.utilization",
            (busy_ns as f64 / (participants * wall_ns)).min(1.0),
        );
        metrics::gauge_set("pool.workers", self.workers() as f64);
        crate::obs::flight::record("pool", "dispatch", n_tasks as u64, busy_ns / 1_000);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One participant's share of a job: drain the own deque from the
/// front, then steal back halves from the richest victim until every
/// range is empty. Accumulates busy time; panics are captured so
/// `remaining` always reaches zero.
fn participate(inner: &Inner, job: &Job, slot: usize) {
    let t0 = Instant::now();
    let was_in_pool = IN_POOL.with(|c| c.replace(true));
    loop {
        let task = pop_front(&job.ranges[slot]).or_else(|| {
            let (lo, hi) = steal_half(&job.ranges, slot)?;
            job.steals.fetch_add(1, Ordering::Relaxed);
            // republish the tail under our own (empty) deque so other
            // idle participants can steal it back; nobody else ever
            // writes another participant's slot, so a plain store races
            // only with thieves, which the CAS pops tolerate
            job.ranges[slot].store(pack(lo + 1, hi), Ordering::Release);
            Some(lo as usize)
        });
        let Some(i) = task else { break };
        run_task(inner, job, i);
    }
    IN_POOL.with(|c| c.set(was_in_pool));
    job.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
}

fn run_task(inner: &Inner, job: &Job, i: usize) {
    {
        // per-task span, parented to the submitter's span: persistent
        // workers have no inherited stack, and one worker serves many
        // differently-parented jobs over its lifetime — spawn-time
        // capture (the scoped-pool scheme) can no longer work
        let _sp = crate::obs::span::span_child("pool_task", "pool", job.parent);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.data, i)
        }));
        if let Err(p) = r {
            let mut first = job.panic.lock().unwrap_or_else(|e| e.into_inner());
            if first.is_none() {
                *first = Some(p);
            }
        }
    }
    if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // last task: wake the submitter. Taking the state lock before
        // notifying means the wakeup cannot slip between the
        // submitter's predicate check and its wait.
        let _st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
        inner.done_cv.notify_all();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    let mut seen = u64::MAX; // sentinel: any installed job is new to us
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.alive > st.target {
                    st.alive -= 1;
                    drop(st);
                    inner.done_cv.notify_all();
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(job) = &st.job {
                        break Arc::clone(job);
                    }
                    continue;
                }
                inner.parks.fetch_add(1, Ordering::Relaxed);
                let parked = Instant::now();
                st = inner.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
                inner
                    .park_wait_ns
                    .fetch_add(parked.elapsed().as_nanos() as u64, Ordering::Relaxed);
                inner.unparks.fetch_add(1, Ordering::Relaxed);
            }
        };
        // claim a participant slot; late wakers past the last slot sit
        // this job out (the plan capped its parallelism deliberately)
        let slot = job.next_slot.fetch_add(1, Ordering::Relaxed);
        if slot < job.ranges.len() {
            participate(&inner, &job, slot);
        }
    }
}

fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(Pool::new)
}

/// Fan `f(0..n_tasks)` out over the process-global pool with up to
/// `width` participants (the caller plus `width - 1` workers). The
/// pool reconciles its resident worker count to `threads() - 1` here —
/// lazily, at the first large kernel — so `set_threads` /
/// `MISA_THREADS` changes take effect at the next dispatch.
pub(crate) fn run_tasks<F: Fn(usize) + Sync>(width: usize, n_tasks: usize, f: F) {
    let pool = global();
    let resident = threads().saturating_sub(1);
    if pool.workers() != resident {
        pool.resize(resident);
    }
    pool.run(width, n_tasks, f);
}

/// Raw-pointer wrapper asserting that cross-thread use is externally
/// synchronized: pool tasks dereference disjoint regions only.
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A table of raw pointers (one per task) with the same contract as
/// [`SendPtr`]: task `i` dereferences entry `i` only.
pub(crate) struct SendPtrs<T>(pub Vec<*mut T>);
unsafe impl<T> Send for SendPtrs<T> {}
unsafe impl<T> Sync for SendPtrs<T> {}

/// Aim for this many tasks per participant so stolen work rebalances
/// ragged shapes instead of waiting on the slowest static chunk.
const TASKS_PER_WORKER: usize = 4;

/// ...but keep row-block tasks at least this tall: each GEMM task
/// repacks its B panel stream, a `k*n` cost amortized over the block's
/// rows, so blocks below ~16 rows start paying measurable pack tax.
const MIN_TASK_ROWS: usize = 16;

/// Run `body(row0, out_chunk)` over `out` split into contiguous
/// row-block tasks (`out.len() == rows * stride`), executed across up
/// to `workers` pool participants with stealing. Each row belongs to
/// exactly one task, so the split never reorders an accumulation.
pub(crate) fn par_out_rows<F>(out: &mut [f32], rows: usize, stride: usize, workers: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * stride);
    if workers <= 1 || rows < 2 {
        body(0, out);
        return;
    }
    let chunk = rows
        .div_ceil(workers * TASKS_PER_WORKER)
        .max(MIN_TASK_ROWS)
        .min(rows);
    let n_tasks = rows.div_ceil(chunk);
    let base = SendPtr(out.as_mut_ptr());
    run_tasks(workers.min(n_tasks), n_tasks, |t| {
        let row0 = t * chunk;
        let take = chunk.min(rows - row0);
        // SAFETY: task `t` owns rows [row0, row0 + take) — the blocks
        // are disjoint and cover `out` exactly — and `out` outlives
        // the dispatch because the submitter blocks until every task
        // completes.
        let chunk_out =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(row0 * stride), take * stride) };
        body(row0, chunk_out);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_respects_knob_rows_and_work_floor() {
        // plenty of rows and work: full width
        assert_eq!(plan_workers_at(4, 1024, 64 * MIN_MACS_PER_WORKER), 4);
        // fewer rows than threads: one worker per row at most
        assert_eq!(plan_workers_at(4, 2, 64 * MIN_MACS_PER_WORKER), 2);
        // small kernels stay serial no matter the knob
        assert_eq!(plan_workers_at(4, 1024, MIN_MACS_PER_WORKER / 2), 1);
        // width 1 always serial
        assert_eq!(plan_workers_at(1, 1024, 64 * MIN_MACS_PER_WORKER), 1);
        // the retuned floor: a 4x256x128 projection (131 Ki MACs) was
        // serial under the scoped pool's 128 Ki-per-worker floor and
        // fans out at full width now that spawn cost is gone
        assert_eq!(MIN_MACS_PER_WORKER, 32 * 1024);
        assert_eq!(plan_workers_at(4, 4, 4 * 256 * 128), 4);
        // the resolved global knob is always at least 1
        assert!(threads() >= 1);
    }

    #[test]
    fn ranges_pack_and_partition_exactly() {
        assert_eq!(unpack(pack(3, 17)), (3, 17));
        for (p, n) in [(1usize, 5usize), (3, 10), (4, 3), (7, 7), (2, 1)] {
            let ranges = build_ranges(p, n);
            assert_eq!(ranges.len(), p);
            let mut seen = vec![false; n];
            let mut prev_hi = 0u32;
            for r in &ranges {
                let (lo, hi) = unpack(r.load(Ordering::Relaxed));
                assert_eq!(lo, prev_hi, "ranges must be contiguous");
                for i in lo..hi {
                    assert!(!seen[i as usize]);
                    seen[i as usize] = true;
                }
                prev_hi = hi;
            }
            assert_eq!(prev_hi as usize, n);
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn pop_and_steal_claim_each_task_once() {
        let ranges = build_ranges(2, 10);
        // owner pops the front of its own deque
        assert_eq!(pop_front(&ranges[0]), Some(0));
        assert_eq!(pop_front(&ranges[0]), Some(1));
        // thief takes the back half (rounded up) of the richest victim
        let (lo, hi) = steal_half(&ranges, 1).unwrap();
        assert_eq!((lo, hi), (3, 5), "victim kept [2,3), thief got [3,5)");
        assert_eq!(pop_front(&ranges[0]), Some(2));
        assert_eq!(pop_front(&ranges[0]), None);
        // draining everything leaves nothing to steal
        while pop_front(&ranges[1]).is_some() {}
        assert!(steal_half(&ranges, 0).is_none());
    }

    #[test]
    fn partition_covers_every_row_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rows = 67;
        let stride = 3;
        let mut out = vec![0.0f32; rows * stride];
        let calls = AtomicUsize::new(0);
        par_out_rows(&mut out, rows, stride, 4, |row0, chunk| {
            calls.fetch_add(1, Ordering::Relaxed);
            for (r, row) in chunk.chunks_mut(stride).enumerate() {
                for x in row.iter_mut() {
                    *x += (row0 + r) as f32;
                }
            }
        });
        // row-block granularity: ceil(67 / max(ceil(67/16), 16)) tasks
        assert_eq!(calls.load(Ordering::Relaxed), rows.div_ceil(MIN_TASK_ROWS));
        for (r, row) in out.chunks(stride).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r} misassigned: {row:?}");
        }
    }

    #[test]
    fn private_pool_runs_resizes_and_shuts_down() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::new();
        // no workers yet: the caller drains everything
        let hits = AtomicUsize::new(0);
        pool.run(4, 10, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        assert_eq!(pool.workers(), 0);
        pool.resize(3);
        assert_eq!(pool.workers(), 3);
        let counts: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, 100, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
        pool.shutdown();
        assert_eq!(pool.workers(), 0);
        // still usable after shutdown (inline on the caller)
        let hits = AtomicUsize::new(0);
        pool.run(4, 7, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 7);
        pool.resize(2);
        pool.run(2, 5, |_| {});
        // Drop joins the respawned workers
    }
}
