//! # MISA — Memory-Efficient LLMs Optimization with Module-wise Importance Sampling
//!
//! Full-system reproduction of the NeurIPS 2025 paper. This crate is the
//! Layer-3 **Rust coordinator**: it owns the training event loop, the
//! module-wise importance sampler (the paper's contribution), every
//! baseline optimizer the paper compares against, the analytical memory
//! model of Appendix E, the synthetic data substrate, and a pluggable
//! **execution-backend subsystem** that runs the compute graphs.
//!
//! Two backends implement the execution ABI (`runtime::backend`):
//!
//! - **host** (default) — the transformer forward/backward, loss,
//!   per-parameter gradient norms and fused optimizer updates in pure
//!   Rust. Trains end-to-end offline: no Python, no artifacts, no
//!   compiled-graph sidecar.
//! - **pjrt** (cargo feature `pjrt`) — the AOT path: PJRT client
//!   executing the XLA/Pallas graphs lowered by `python/compile`
//!   (`make artifacts`), with device-resident parameters.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`util`] — PRNG, metrics JSONL, mini property-test harness.
//! - [`obs`] — observability: scoped spans with Chrome-trace export,
//!   counter/gauge/histogram registry with Prometheus-style dump,
//!   per-request latency timelines, leveled logging.
//! - [`tensor`] — host linear algebra for adapter/projection math.
//! - [`modelspec`] — the parameter/module registry (the L2 ABI) +
//!   the builtin model registry (artifact-free mirror of configs.py).
//! - [`memory`] — Appendix-E analytical peak-memory model + simulated
//!   device allocator.
//! - [`data`] — synthetic corpus + task families + dataloaders.
//! - [`runtime`] — `Engine`/`Session` + the `runtime::backend`
//!   subsystem (`Backend` trait, `HostBackend`, feature-gated
//!   `PjrtBackend`).
//! - [`optim`] — MISA (Algorithm 1/2/3) and all baselines: Adam, BAdam,
//!   LISA, LoRA, DoRA, GaLore, LoRA+MISA.
//! - [`coordinator`] — trainer orchestration, evaluation, experiments.
//! - [`serve`] — inference serving: KV-cache incremental decode, token
//!   samplers, single-stream generation, prefix-sharing prompt cache,
//!   continuous-batching scheduler with batched prefill admission.
//! - [`fuzz`] — seed-replayable differential fuzzer over the serving
//!   cores (KV cache, prompt trie, scheduler), each checked against a
//!   naive reference model after every op.
//! - [`config`] — TOML-subset run configuration.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod fuzz;
pub mod memory;
pub mod modelspec;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
