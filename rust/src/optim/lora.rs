//! LoRA and DoRA baselines.
//!
//! LoRA (Hu et al., 2021): `W_eff = W0 + (α/r)·A·B` with `A ∈ R^{in×r}`
//! Gaussian-init, `B ∈ R^{r×out}` zero-init. The coordinator derives the
//! adapter gradients from the full weight gradient the fwd/bwd graph
//! already produces:
//!
//! ```text
//! dA = (α/r) · dW · Bᵀ,   dB = (α/r) · Aᵀ · dW
//! ```
//!
//! (chain rule through W_eff — no second backward pass needed), runs
//! host Adam on the adapters, re-merges W_eff and uploads only the
//! target modules.
//!
//! DoRA (Liu et al., 2024): weight-decomposed LoRA. `V = W0 + (α/r)AB`,
//! `W_eff = mag ⊙ V / ||V||_col` with the column norm **detached** (the
//! DoRA paper's practical gradient trick):
//!
//! ```text
//! dV ≈ (mag/||V||_col) ⊙ dW,   dmag_j = Σ_i dW_ij · V_ij/||V_j||
//! ```

use std::collections::HashMap;

use anyhow::Result;

use crate::modelspec::{ModelSpec, ModuleKind};
use crate::optim::adam::{AdamHyper, AdamState};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};
use crate::tensor::{matmul, matmul_nt, matmul_tn, Mat};
use crate::util::Rng;

/// Default LoRA target modules (paper Table 17: W_q, W_k, W_v, W_up,
/// W_down; Table 21 adds the rest — configurable).
pub fn default_targets() -> Vec<ModuleKind> {
    vec![
        ModuleKind::Wq,
        ModuleKind::Wk,
        ModuleKind::Wv,
        ModuleKind::Wup,
        ModuleKind::Wdown,
    ]
}

/// One adapted module.
pub struct Adapter {
    /// frozen base weight
    pub w0: Mat,
    pub a: Mat,
    pub b: Mat,
    pub state_a: AdamState,
    pub state_b: AdamState,
    /// DoRA magnitude vector + its state (None for plain LoRA)
    pub mag: Option<(Vec<f32>, AdamState)>,
}

pub struct Lora {
    pub rank: usize,
    pub alpha: f32,
    dora: bool,
    hyper: AdamHyper,
    /// param index -> adapter
    pub adapters: HashMap<usize, Adapter>,
    /// stable iteration order
    order: Vec<usize>,
}

impl Lora {
    pub fn new(spec: &ModelSpec, sess_host: &[Vec<f32>], rank: usize, alpha: f32,
               targets: &[ModuleKind], seed: u64) -> Self {
        Self::build(spec, sess_host, rank, alpha, targets, seed, false)
    }

    fn build(spec: &ModelSpec, sess_host: &[Vec<f32>], rank: usize, alpha: f32,
             targets: &[ModuleKind], seed: u64, dora: bool) -> Self {
        let mut rng = Rng::new(seed ^ 0x4C6F5241);
        let mut adapters = HashMap::new();
        let mut order = Vec::new();
        for (i, p) in spec.params.iter().enumerate() {
            if p.shape.len() == 2 && targets.contains(&p.kind) {
                let (rows, cols) = (p.shape[0], p.shape[1]);
                let w0 = Mat::from_vec(rows, cols, sess_host[i].clone());
                let a = Mat::randn(rows, rank, (rows as f32).powf(-0.5), &mut rng);
                let b = Mat::zeros(rank, cols);
                let mag = if dora {
                    let norms = w0.col_norms();
                    let n = norms.len();
                    Some((norms, AdamState::zeros(n)))
                } else {
                    None
                };
                adapters.insert(
                    i,
                    Adapter {
                        w0,
                        state_a: AdamState::zeros(rows * rank),
                        state_b: AdamState::zeros(rank * cols),
                        a,
                        b,
                        mag,
                    },
                );
                order.push(i);
            }
        }
        Lora { rank, alpha, dora, hyper: AdamHyper::default(), adapters, order }
    }

    pub fn scale(&self) -> f32 {
        self.alpha / self.rank as f32
    }

    /// Effective weight of one adapter: LoRA merge (+ DoRA magnitude).
    pub fn effective_weight(&self, idx: usize) -> Mat {
        let ad = &self.adapters[&idx];
        let mut w = ad.w0.clone();
        let delta = matmul(&ad.a, &ad.b);
        w.axpy(self.scale(), &delta);
        if let Some((mag, _)) = &ad.mag {
            let norms = w.col_norms();
            for r in 0..w.rows {
                for c in 0..w.cols {
                    let n = norms[c].max(1e-8);
                    *w.at_mut(r, c) *= mag[c] / n;
                }
            }
        }
        w
    }

    pub fn trainable_elems(&self) -> u64 {
        self.adapters
            .values()
            .map(|a| {
                (a.a.data.len() + a.b.data.len()
                    + a.mag.as_ref().map_or(0, |(m, _)| m.len())) as u64
            })
            .sum()
    }

    pub fn adapter_order(&self) -> &[usize] {
        &self.order
    }

    /// Apply one adapter update from the full-weight gradient; returns
    /// the new effective weight. Exposed for LoRA+MISA (Appendix B.2).
    pub fn update_adapter(&mut self, idx: usize, dw_full: &[f32], lr: f32) -> Mat {
        let scale = self.scale();
        let hyper = self.hyper;
        let ad = self.adapters.get_mut(&idx).unwrap();
        let (rows, cols) = (ad.w0.rows, ad.w0.cols);
        let mut dw = Mat::from_vec(rows, cols, dw_full.to_vec());
        if let Some((mag, mag_state)) = &mut ad.mag {
            // DoRA: gradient w.r.t. magnitude + rescaled direction grad
            let mut v = ad.w0.clone();
            let delta = matmul(&ad.a, &ad.b);
            v.axpy(scale, &delta);
            let norms = v.col_norms();
            let mut dmag = vec![0.0f32; cols];
            for r in 0..rows {
                for c in 0..cols {
                    let n = norms[c].max(1e-8);
                    dmag[c] += dw.at(r, c) * v.at(r, c) / n;
                }
            }
            for r in 0..rows {
                for c in 0..cols {
                    let n = norms[c].max(1e-8);
                    *dw.at_mut(r, c) *= mag[c] / n;
                }
            }
            let mut m = std::mem::take(mag);
            mag_state.step(&mut m, &dmag, lr, hyper);
            *mag = m;
        }
        // dA = scale * dW @ B^T ; dB = scale * A^T @ dW
        let mut da = matmul_nt(&dw, &ad.b);
        da.scale(scale);
        let mut db = matmul_tn(&ad.a, &dw);
        db.scale(scale);
        ad.state_a.step(&mut ad.a.data, &da.data, lr, hyper);
        ad.state_b.step(&mut ad.b.data, &db.data, lr, hyper);
        self.effective_weight(idx)
    }
}

impl Optimizer for Lora {
    fn name(&self) -> String {
        format!("{}(r={})", if self.dora { "DoRA" } else { "LoRA" }, self.rank)
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        for idx in self.order.clone() {
            let w_eff = self.update_adapter(idx, &out.grads[idx], lr);
            sess.set_param(idx, w_eff.data)?;
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let adapters = self.trainable_elems();
        MemProfile {
            grad_elems: adapters,
            optim_elems: 2 * adapters,
            adapter_elems: adapters,
            active_indices: self.order.clone(),
        }
    }
}

/// DoRA constructor (Weight-Decomposed LoRA).
pub struct Dora;

impl Dora {
    pub fn new(spec: &ModelSpec, sess_host: &[Vec<f32>], rank: usize, alpha: f32,
               targets: &[ModuleKind], seed: u64) -> Lora {
        Lora::build(spec, sess_host, rank, alpha, targets, seed, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::Manifest;
    use std::path::Path;

    fn spec() -> ModelSpec {
        let text = "\
version 1
config t
  field vocab 64
  field dim 8
  field n_layers 1
  field n_heads 2
  field n_kv_heads 1
  field ffn_dim 16
  field seq_len 8
  field batch 2
  param layers.0.wq wq 0 2 8 8
  param layers.0.wo wo 0 2 8 8
  param layers.0.wup wup 0 2 8 16
  param embed embed -1 2 64 8
";
        Manifest::parse(Path::new("/tmp"), text).unwrap().models[0].clone()
    }

    fn host(spec: &ModelSpec, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        spec.params
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.numel()];
                rng.fill_normal(&mut v, 0.1);
                v
            })
            .collect()
    }

    #[test]
    fn zero_b_init_means_identity_at_start() {
        let s = spec();
        let h = host(&s, 1);
        let lora = Lora::new(&s, &h, 4, 8.0, &default_targets(), 0);
        // W_eff == W0 before any update (B = 0)
        for (&idx, ad) in &lora.adapters {
            let w = lora.effective_weight(idx);
            assert_eq!(w.data, ad.w0.data, "module {idx}");
        }
    }

    #[test]
    fn targets_respected() {
        let s = spec();
        let h = host(&s, 1);
        let lora = Lora::new(&s, &h, 4, 8.0, &[ModuleKind::Wq], 0);
        assert_eq!(lora.adapter_order(), &[0]);
    }

    #[test]
    fn update_moves_effective_weight_against_gradient() {
        let s = spec();
        let h = host(&s, 2);
        let mut lora = Lora::new(&s, &h, 4, 8.0, &default_targets(), 0);
        // two updates with the same dW: after the first, B != 0, so the
        // second must move W_eff opposite to dW on average
        let dw = vec![1.0f32; 64];
        lora.update_adapter(0, &dw, 0.01);
        let w1 = lora.effective_weight(0);
        lora.update_adapter(0, &dw, 0.01);
        let w2 = lora.effective_weight(0);
        let drift: f32 = w2.data.iter().zip(&w1.data).map(|(a, b)| a - b).sum();
        assert!(drift < 0.0, "drift {drift} should be negative (descent)");
    }

    #[test]
    fn lora_gradient_matches_finite_difference() {
        // loss = <dW, W_eff> is linear, so dL/dA = scale * dW @ B^T
        // exactly; check one entry numerically.
        let s = spec();
        let h = host(&s, 3);
        let mut lora = Lora::new(&s, &h, 2, 2.0, &[ModuleKind::Wq], 0);
        // push B away from zero first
        let mut rng = Rng::new(9);
        {
            let ad = lora.adapters.get_mut(&0).unwrap();
            rng.fill_normal(&mut ad.b.data, 0.3);
        }
        let dw: Vec<f32> = (0..64).map(|i| ((i % 5) as f32 - 2.0) * 0.1).collect();
        let scale = lora.scale();
        let ad = &lora.adapters[&0];
        let dwm = Mat::from_vec(8, 8, dw.clone());
        let da = matmul_nt(&dwm, &ad.b); // analytic (pre-scale)
        // finite difference on A[0,0]: d<dW, W0 + s A B>/dA00 = s (dW B^T)[0,0]
        let eps = 1e-3f32;
        let mut a_plus = ad.a.clone();
        *a_plus.at_mut(0, 0) += eps;
        let loss = |a: &Mat| {
            let mut w = ad.w0.clone();
            w.axpy(scale, &matmul(a, &ad.b));
            w.data.iter().zip(&dw).map(|(x, g)| x * g).sum::<f32>()
        };
        let fd = (loss(&a_plus) - loss(&ad.a)) / eps;
        let analytic = scale * da.at(0, 0);
        assert!((fd - analytic).abs() < 1e-2, "fd {fd} vs {analytic}");
    }

    #[test]
    fn dora_effective_weight_has_magnitude_column_norms() {
        let s = spec();
        let h = host(&s, 4);
        let dora = Dora::new(&s, &h, 4, 8.0, &[ModuleKind::Wq], 0);
        let w = dora.effective_weight(0);
        let (mag, _) = dora.adapters[&0].mag.as_ref().unwrap();
        let norms = w.col_norms();
        for (n, m) in norms.iter().zip(mag) {
            assert!((n - m).abs() < 1e-4, "col norm {n} vs mag {m}");
        }
    }

    #[test]
    fn trainable_elems_counts_adapters() {
        let s = spec();
        let h = host(&s, 5);
        let lora = Lora::new(&s, &h, 4, 8.0, &[ModuleKind::Wq, ModuleKind::Wup], 0);
        // wq: 8x4 + 4x8 = 64; wup: 8x4 + 4x16 = 96
        assert_eq!(lora.trainable_elems(), 64 + 96);
    }
}
