//! LISA baseline — Layerwise Importance Sampled AdamW (Pan et al.,
//! 2024). Uniform random layer selection every `T` steps, with the
//! embedding and LM-head **always active** (their skewed weight norms
//! motivated LISA; also exactly why its memory exceeds BAdam's in the
//! paper's tables — see `memory::lisa_embed_head_opt`).

use anyhow::Result;

use crate::modelspec::{ModelSpec, ModuleKind};
use crate::optim::adam::{AdamHyper, AdamState};
use crate::optim::sampler::{SamplerTelemetry, SamplingUnit};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};
use crate::util::Rng;

pub struct Lisa {
    hyper: AdamHyper,
    layers: Vec<Vec<usize>>,
    /// total params per layer (telemetry read-out)
    layer_numel: Vec<u64>,
    /// embed + head indices (always active)
    dense: Vec<(usize, AdamState)>,
    active_layer: usize,
    states: Vec<AdamState>,
    /// number of simultaneously-active layers γ (paper uses 1-2)
    t_inner: usize,
    inner_t: usize,
    use_kernel: bool,
    rng: Rng,
    /// times each layer has been drawn (telemetry; counting reads the
    /// draw the optimizer already made — no extra RNG calls)
    counts: Vec<u64>,
    /// layer draws so far (1 at construction + one per switch)
    rounds: u64,
}

impl Lisa {
    pub fn new(spec: &ModelSpec, t_inner: usize, use_kernel: bool, seed: u64) -> Self {
        let n_layers = spec.config.n_layers;
        let mut layers = vec![Vec::new(); n_layers];
        let mut layer_numel = vec![0u64; n_layers];
        let mut dense = Vec::new();
        for (i, p) in spec.params.iter().enumerate() {
            if p.layer >= 0 {
                layers[p.layer as usize].push(i);
                layer_numel[p.layer as usize] += p.numel() as u64;
            } else if matches!(p.kind, ModuleKind::Embed | ModuleKind::Head) {
                dense.push((i, AdamState::zeros(p.numel())));
            }
        }
        let mut rng = Rng::new(seed ^ 0x4C495341); // "LISA"
        let active_layer = rng.below(n_layers);
        let mut counts = vec![0u64; n_layers];
        counts[active_layer] = 1;
        Lisa {
            hyper: AdamHyper::default(),
            layers,
            layer_numel,
            dense,
            active_layer,
            states: Vec::new(),
            t_inner,
            inner_t: 0,
            use_kernel,
            rng,
            counts,
            rounds: 1,
        }
    }
}

impl Optimizer for Lisa {
    fn name(&self) -> String {
        format!("LISA(T={})", self.t_inner)
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        if self.states.is_empty() {
            self.states = self.layers[self.active_layer]
                .iter()
                .map(|&i| AdamState::zeros(sess.spec.params[i].numel()))
                .collect();
        }
        let indices = self.layers[self.active_layer].clone();
        for (slot, &idx) in indices.iter().enumerate() {
            let g = &out.grads[idx];
            if self.use_kernel && sess.spec.params[idx].shape.len() == 2 {
                let st = &self.states[slot];
                let (m, v, _) = sess.adam_update(idx, g, &st.m, &st.v, lr)?;
                self.states[slot].m = m;
                self.states[slot].v = v;
            } else {
                let mut p = std::mem::take(&mut sess.host[idx]);
                self.states[slot].step(&mut p, g, lr, self.hyper);
                sess.set_param(idx, p)?;
            }
        }
        // embedding + head always trained (dense Adam, persistent states)
        for (idx, st) in &mut self.dense {
            let mut p = std::mem::take(&mut sess.host[*idx]);
            st.step(&mut p, &out.grads[*idx], lr, self.hyper);
            sess.set_param(*idx, p)?;
        }
        self.inner_t += 1;
        if self.inner_t >= self.t_inner {
            self.active_layer = self.rng.below(self.layers.len());
            self.counts[self.active_layer] += 1;
            self.rounds += 1;
            self.states.clear();
            self.inner_t = 0;
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let layer_opt: u64 = self.states.iter().map(|s| s.elems()).sum();
        let dense_opt: u64 = self.dense.iter().map(|(_, s)| s.elems()).sum();
        MemProfile {
            grad_elems: (layer_opt + dense_opt) / 2,
            optim_elems: layer_opt + dense_opt,
            adapter_elems: 0,
            active_indices: {
                let mut v = self.layers[self.active_layer].clone();
                v.extend(self.dense.iter().map(|(i, _)| *i));
                v
            },
        }
    }

    fn sampling_counts(&self) -> Option<Vec<(usize, u64)>> {
        // per-layer counts keyed by the layer's first param index
        Some(
            self.layers
                .iter()
                .zip(&self.counts)
                .filter_map(|(ps, &c)| ps.first().map(|&i| (i, c)))
                .collect(),
        )
    }

    fn telemetry(&self) -> Option<&dyn SamplerTelemetry> {
        Some(self)
    }
}

impl SamplerTelemetry for Lisa {
    fn sampler_label(&self) -> &'static str {
        "lisa"
    }

    fn rounds(&self) -> u64 {
        self.rounds
    }

    fn units(&self) -> Vec<SamplingUnit> {
        // one unit per transformer layer, drawn uniformly; embed/head
        // are always-on dense parameters, not sampling units
        let l = self.layers.len().max(1) as f64;
        self.layers
            .iter()
            .enumerate()
            .map(|(i, params)| SamplingUnit {
                name: format!("layer.{i}"),
                params: params.clone(),
                layer: i as i32,
                score: 0.0, // LISA keeps no importance scores
                prob: 1.0 / l,
                count: self.counts[i],
                numel: self.layer_numel[i],
                active: i == self.active_layer,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::Manifest;
    use std::path::Path;

    fn spec() -> ModelSpec {
        let text = "\
version 1
config t
  field vocab 64
  field dim 8
  field n_layers 4
  field n_heads 2
  field n_kv_heads 1
  field ffn_dim 16
  field seq_len 8
  field batch 2
  param layers.0.wq wq 0 2 8 8
  param layers.1.wq wq 1 2 8 8
  param layers.2.wq wq 2 2 8 8
  param layers.3.wq wq 3 2 8 8
  param embed embed -1 2 64 8
  param head head -1 2 8 64
";
        Manifest::parse(Path::new("/tmp"), text).unwrap().models[0].clone()
    }

    #[test]
    fn embed_and_head_always_active() {
        let l = Lisa::new(&spec(), 10, false, 1);
        assert_eq!(l.dense.len(), 2);
        let prof = l.mem_profile();
        assert!(prof.active_indices.contains(&4));
        assert!(prof.active_indices.contains(&5));
    }

    #[test]
    fn layer_choice_is_uniform_ish() {
        // over many constructions each layer gets picked sometimes
        let mut seen = [false; 4];
        for seed in 0..64 {
            let l = Lisa::new(&spec(), 10, false, seed);
            seen[l.active_layer] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }
}
