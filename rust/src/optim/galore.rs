//! GaLore baseline (Zhao et al., 2024) — gradient low-rank projection.
//!
//! For each target matrix the gradient is projected into an `r`-dim
//! subspace, Adam runs in the subspace (the memory saving: moments are
//! `r×cols` instead of `rows×cols`), and the update is projected back:
//!
//! ```text
//! R = Pᵀ G          (rows ≥ cols projects the left side)
//! W ← W − lr · P · Adam(R)
//! ```
//!
//! The subspace `P` refreshes every `update_freq` steps. The paper's
//! GaLore uses an SVD; offline we use the randomized range finder with a
//! power iteration (`tensor::range_finder`) — the standard
//! memory-equivalent substitution (DESIGN.md Sec. 4), and the reason the
//! paper's Table 8 shows GaLore's optimizer step dominating its runtime
//! is reproduced by our periodic refresh cost.

use std::collections::HashMap;

use anyhow::Result;

use crate::modelspec::ModelSpec;
use crate::optim::adam::{AdamHyper, AdamState};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};
use crate::tensor::{matmul, matmul_tn, range_finder, Mat};
use crate::util::Rng;

struct Projected {
    /// orthonormal subspace [rows, r] (or [cols, r] for wide matrices)
    p: Mat,
    /// true when projecting the left side (rows >= cols)
    left: bool,
    state: AdamState,
    refreshed_at: u64,
}

pub struct Galore {
    pub rank: usize,
    pub update_freq: u64,
    /// GaLore scale α
    pub scale: f32,
    hyper: AdamHyper,
    targets: Vec<usize>,
    proj: HashMap<usize, Projected>,
    /// dense Adam for non-matrix params in pre-training mode
    dense: Vec<(usize, AdamState)>,
    step_no: u64,
    rng: Rng,
    /// SVD/range-finder refreshes performed (Table 8 cost accounting)
    pub refreshes: u64,
}

impl Galore {
    pub fn new(spec: &ModelSpec, rank: usize, update_freq: u64, scale: f32,
               pretrain: bool, seed: u64) -> Self {
        let targets = spec.matrix_module_indices();
        let dense = if pretrain {
            spec.params
                .iter()
                .enumerate()
                .filter(|(_, p)| !p.kind.is_matrix_module())
                .map(|(i, p)| (i, AdamState::zeros(p.numel())))
                .collect()
        } else {
            Vec::new()
        };
        Galore {
            rank,
            update_freq,
            scale,
            hyper: AdamHyper::default(),
            targets,
            proj: HashMap::new(),
            dense,
            step_no: 0,
            rng: Rng::new(seed ^ 0x47614C6F),
            refreshes: 0,
        }
    }

    fn ensure_projection(&mut self, idx: usize, grad: &Mat) {
        let due = match self.proj.get(&idx) {
            None => true,
            Some(p) => self.step_no.saturating_sub(p.refreshed_at) >= self.update_freq,
        };
        if !due {
            return;
        }
        let left = grad.rows >= grad.cols;
        let r = self.rank.min(grad.rows).min(grad.cols);
        let p = if left {
            range_finder(grad, r, &mut self.rng) // [rows, r]
        } else {
            let gt = grad.transpose();
            range_finder(&gt, r, &mut self.rng) // [cols, r]
        };
        let state_len = if left { r * grad.cols } else { grad.rows * r };
        self.proj.insert(
            idx,
            Projected {
                p,
                left,
                state: AdamState::zeros(state_len),
                refreshed_at: self.step_no,
            },
        );
        self.refreshes += 1;
    }
}

impl Optimizer for Galore {
    fn name(&self) -> String {
        format!("GaLore(r={})", self.rank)
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        for idx in self.targets.clone() {
            let spec_shape = sess.spec.params[idx].shape.clone();
            let g = Mat::from_vec(spec_shape[0], spec_shape[1], out.grads[idx].clone());
            self.ensure_projection(idx, &g);
            let pr = self.proj.get_mut(&idx).unwrap();
            // project, Adam in subspace, back-project
            let update = if pr.left {
                let mut low = matmul_tn(&pr.p, &g); // [r, cols]
                pr.state.step_like(&mut low.data, lr, self.hyper);
                matmul(&pr.p, &low) // [rows, cols]
            } else {
                let mut low = matmul(&g, &pr.p); // [rows, r]
                pr.state.step_like(&mut low.data, lr, self.hyper);
                crate::tensor::matmul_nt(&low, &pr.p) // [rows, cols]
            };
            let p_host = &mut sess.host[idx];
            for (w, u) in p_host.iter_mut().zip(&update.data) {
                *w -= lr * self.scale * u;
            }
            let taken = std::mem::take(&mut sess.host[idx]);
            sess.set_param(idx, taken)?;
        }
        for (idx, st) in &mut self.dense {
            let mut p = std::mem::take(&mut sess.host[*idx]);
            st.step(&mut p, &out.grads[*idx], lr, self.hyper);
            sess.set_param(*idx, p)?;
        }
        self.step_no += 1;
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let proj_elems: u64 = self
            .proj
            .values()
            .map(|p| (p.p.data.len() + p.state.m.len() + p.state.v.len()) as u64)
            .sum();
        let dense_opt: u64 = self.dense.iter().map(|(_, s)| s.elems()).sum();
        MemProfile {
            grad_elems: 0, // GaLore consumes grads layer-by-layer
            optim_elems: proj_elems + dense_opt,
            adapter_elems: 0,
            active_indices: self.targets.clone(),
        }
    }
}

impl AdamState {
    /// Adam transform applied *to the gradient buffer in place*: after
    /// the call, `g` holds `m'/(sqrt(v')+eps)` — GaLore's subspace step.
    pub fn step_like(&mut self, g: &mut [f32], _lr: f32, h: AdamHyper) {
        for i in 0..g.len() {
            let gi = g[i];
            let mi = h.beta1 * self.m[i] + (1.0 - h.beta1) * gi;
            let vi = h.beta2 * self.v[i] + (1.0 - h.beta2) * gi * gi;
            self.m[i] = mi;
            self.v[i] = vi;
            g[i] = mi / (vi.sqrt() + h.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_like_matches_adam_direction() {
        let mut st = AdamState::zeros(2);
        let mut g = vec![2.0f32, -3.0];
        st.step_like(&mut g, 0.1, AdamHyper::default());
        // first step: m = 0.1 g0, v = 0.001 g0^2 → m/sqrt(v) ≈ sign * 3.16
        assert!(g[0] > 0.0 && g[1] < 0.0);
        assert!((g[0].abs() - g[1].abs()).abs() < 1e-3);
    }

    #[test]
    fn projection_reduces_state_memory() {
        // the Adam state in the subspace must be r×cols ≪ rows×cols
        let mut rng = Rng::new(1);
        let g = Mat::randn(64, 32, 1.0, &mut rng);
        let p = range_finder(&g, 4, &mut rng);
        assert_eq!(p.rows, 64);
        assert_eq!(p.cols, 4);
        let low = matmul_tn(&p, &g);
        assert_eq!((low.rows, low.cols), (4, 32));
    }
}
