//! Optimizers: MISA (the paper's method) and every baseline it is
//! evaluated against.
//!
//! | paper method | module |
//! |---|---|
//! | MISA (Alg. 1/2/3)       | [`misa`] |
//! | full fine-tuning (Adam) | [`adam`] |
//! | BAdam (cyclic layers)   | [`badam`] |
//! | LISA (random layers)    | [`lisa`] |
//! | LoRA                    | [`lora`] |
//! | DoRA                    | [`lora`] (magnitude variant) |
//! | GaLore                  | [`galore`] |
//! | LoRA+MISA (App. B.2)    | [`lora_misa`] |
//!
//! All optimizers speak the same [`Optimizer`] interface: the trainer
//! runs fwd/bwd through the runtime, hands over grads + Pallas-computed
//! squared norms, and the optimizer mutates the session parameters
//! (through the fused-Adam kernel executables where shapes allow) and
//! reports its memory profile for the simulated allocator.

pub mod adam;
pub mod badam;
pub mod galore;
pub mod lisa;
pub mod lora;
pub mod lora_misa;
pub mod misa;
pub mod sampler;

pub use adam::{AdamHyper, AdamState, FullAdam};
pub use badam::BAdam;
pub use galore::Galore;
pub use lisa::Lisa;
pub use lora::{Dora, Lora};
pub use lora_misa::LoraMisa;
pub use misa::{Misa, MisaConfig};
pub use sampler::{
    ImportanceSampler, SamplerConfig, SamplerTelemetry, SamplingUnit, ScoreFn, Strategy,
};

use anyhow::Result;

use crate::runtime::{Session, StepOutput};

/// What a method keeps resident, in f32 elements — consumed by the
/// simulated allocator and the Mem columns.
#[derive(Clone, Debug, Default)]
pub struct MemProfile {
    /// parameters whose gradients must be stored this step
    pub grad_elems: u64,
    /// optimizer state (m, v, projections, …)
    pub optim_elems: u64,
    /// extra trainable structures (LoRA adapters, magnitudes)
    pub adapter_elems: u64,
    /// indices of currently-active modules (activation surcharge)
    pub active_indices: Vec<usize>,
}

/// The common optimizer interface.
pub trait Optimizer {
    fn name(&self) -> String;

    /// Apply one update given the step output. `lr` comes from the
    /// trainer's schedule. Must keep `sess.host` and the device buffers
    /// coherent (use `sess.adam_update` / `sess.set_param`).
    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()>;

    /// Current memory profile (post-step), for the allocator ledger.
    fn mem_profile(&self) -> MemProfile;

    /// Per-module sampling counts (Fig. 11), if the method samples.
    fn sampling_counts(&self) -> Option<Vec<(usize, u64)>> {
        None
    }

    /// Telemetry read-out for sampler-backed optimizers (MISA / LISA /
    /// BAdam); `None` for methods with nothing to sample. Strictly
    /// observational — see [`SamplerTelemetry`].
    fn telemetry(&self) -> Option<&dyn SamplerTelemetry> {
        None
    }
}

/// Scaled squared gradient norm of parameter `i` (Appendix A.2):
/// ||g||_F^2 / |m| — computed from the Pallas sq-norm by-product.
pub fn scaled_sq_norm(out: &StepOutput, numel: usize, i: usize) -> f64 {
    out.sq_norms[i] as f64 / numel as f64
}
