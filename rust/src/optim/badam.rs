//! BAdam baseline — block coordinate descent with **cyclic layer-wise**
//! Adam (Luo et al., 2024; paper's closest layer-wise competitor).
//!
//! Every `T` steps the active transformer layer advances cyclically;
//! all parameters of the active layer (the 7 matrices + its norms) are
//! updated by Adam while everything else stays frozen. Optimizer states
//! are cleared on switch, matching the paper's memory accounting
//! (layer-wise row of Table 14).

use anyhow::Result;

use crate::modelspec::ModelSpec;
use crate::optim::adam::{AdamHyper, AdamState};
use crate::optim::sampler::{SamplerTelemetry, SamplingUnit};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};

pub struct BAdam {
    hyper: AdamHyper,
    /// param indices grouped by layer
    layers: Vec<Vec<usize>>,
    /// total params per layer (telemetry read-out)
    layer_numel: Vec<u64>,
    active_layer: usize,
    states: Vec<AdamState>,
    t_inner: usize,
    inner_t: usize,
    use_kernel: bool,
    switches: u64,
    /// times each layer has been active (telemetry; the cycle is
    /// deterministic, counting reads it — nothing random to perturb)
    counts: Vec<u64>,
}

impl BAdam {
    pub fn new(spec: &ModelSpec, t_inner: usize, use_kernel: bool) -> Self {
        let n_layers = spec.config.n_layers;
        let mut layers = vec![Vec::new(); n_layers];
        let mut layer_numel = vec![0u64; n_layers];
        for (i, p) in spec.params.iter().enumerate() {
            if p.layer >= 0 {
                layers[p.layer as usize].push(i);
                layer_numel[p.layer as usize] += p.numel() as u64;
            }
        }
        let mut counts = vec![0u64; n_layers];
        counts[0] = 1; // layer 0 is active from construction
        let mut me = BAdam {
            hyper: AdamHyper::default(),
            layers,
            layer_numel,
            active_layer: 0,
            states: Vec::new(),
            t_inner,
            inner_t: 0,
            use_kernel,
            switches: 0,
            counts,
        };
        me.states = Vec::new();
        me
    }

    fn ensure_states(&mut self, spec: &ModelSpec) {
        if self.states.is_empty() {
            self.states = self.layers[self.active_layer]
                .iter()
                .map(|&i| AdamState::zeros(spec.params[i].numel()))
                .collect();
        }
    }

    pub fn active_layer(&self) -> usize {
        self.active_layer
    }
}

impl Optimizer for BAdam {
    fn name(&self) -> String {
        format!("BAdam(T={})", self.t_inner)
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        self.ensure_states(&sess.spec.clone());
        let indices = self.layers[self.active_layer].clone();
        for (slot, &idx) in indices.iter().enumerate() {
            let g = &out.grads[idx];
            if self.use_kernel && sess.spec.params[idx].shape.len() == 2 {
                let st = &self.states[slot];
                let (m, v, _) = sess.adam_update(idx, g, &st.m, &st.v, lr)?;
                self.states[slot].m = m;
                self.states[slot].v = v;
            } else {
                let mut p = std::mem::take(&mut sess.host[idx]);
                self.states[slot].step(&mut p, g, lr, self.hyper);
                sess.set_param(idx, p)?;
            }
        }
        self.inner_t += 1;
        if self.inner_t >= self.t_inner {
            // cyclic switch + state clear
            self.active_layer = (self.active_layer + 1) % self.layers.len();
            self.states.clear();
            self.inner_t = 0;
            self.switches += 1;
            self.counts[self.active_layer] += 1;
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let optim: u64 = self.states.iter().map(|s| s.elems()).sum();
        MemProfile {
            grad_elems: optim / 2,
            optim_elems: optim,
            adapter_elems: 0,
            active_indices: self.layers[self.active_layer].clone(),
        }
    }

    fn sampling_counts(&self) -> Option<Vec<(usize, u64)>> {
        // per-layer counts keyed by the layer's first param index
        Some(
            self.layers
                .iter()
                .zip(&self.counts)
                .filter_map(|(ps, &c)| ps.first().map(|&i| (i, c)))
                .collect(),
        )
    }

    fn telemetry(&self) -> Option<&dyn SamplerTelemetry> {
        Some(self)
    }
}

impl SamplerTelemetry for BAdam {
    fn sampler_label(&self) -> &'static str {
        "badam"
    }

    fn rounds(&self) -> u64 {
        self.switches + 1 // the construction-time activation counts
    }

    fn units(&self) -> Vec<SamplingUnit> {
        // one unit per layer; the cycle visits each in turn, which in
        // expectation matches the uniform layer-wise distribution
        let l = self.layers.len().max(1) as f64;
        self.layers
            .iter()
            .enumerate()
            .map(|(i, params)| SamplingUnit {
                name: format!("layer.{i}"),
                params: params.clone(),
                layer: i as i32,
                score: 0.0, // BAdam keeps no importance scores
                prob: 1.0 / l,
                count: self.counts[i],
                numel: self.layer_numel[i],
                active: i == self.active_layer,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::{Manifest, ModelSpec};
    use std::path::Path;

    fn spec() -> ModelSpec {
        let text = "\
version 1
config t
  field vocab 64
  field dim 8
  field n_layers 3
  field n_heads 2
  field n_kv_heads 1
  field ffn_dim 16
  field seq_len 8
  field batch 2
  param layers.0.wq wq 0 2 8 8
  param layers.0.attn_norm norm 0 1 8
  param layers.1.wq wq 1 2 8 8
  param layers.2.wq wq 2 2 8 8
  param embed embed -1 2 64 8
";
        Manifest::parse(Path::new("/tmp"), text).unwrap().models[0].clone()
    }

    #[test]
    fn layers_grouped_correctly() {
        let b = BAdam::new(&spec(), 10, false);
        assert_eq!(b.layers.len(), 3);
        assert_eq!(b.layers[0], vec![0, 1]);
        assert_eq!(b.layers[1], vec![2]);
        // embed (layer -1) belongs to no BCD block
        assert!(b.layers.iter().all(|l| !l.contains(&4)));
    }

    #[test]
    fn cycle_order_is_deterministic() {
        let mut b = BAdam::new(&spec(), 1, false);
        // simulate switches without a session by driving the counter
        assert_eq!(b.active_layer(), 0);
        b.inner_t = 1;
        b.active_layer = (b.active_layer + 1) % b.layers.len();
        assert_eq!(b.active_layer(), 1);
    }
}
