//! The module-wise importance sampler — the paper's core contribution.
//!
//! * Eq. 4: per-module EMA of the scaled squared gradient norm,
//!   `G_b^n = β G_b^{n-1} + (1-β) (1/T) Σ_t ||g_b^{n,t}||²_scaled`,
//!   updated only for sampled modules.
//! * Prop. 1 / Eq. 3: sampling distribution `p_b ∝ exp(η G_b)` — the
//!   closed-form solution of the KL-regularized importance-sampling
//!   objective (exploitation ↔ exploration dial η).
//! * Algorithm 2: greedy δ-budget selection — draw modules without
//!   replacement by `p`, keep those that fit the trainable-parameter
//!   budget `δ · n_model`, until the pool is exhausted.
//! * Ablations: Uniform / Top-K / Bottom-K strategies (Table 10) and
//!   weight-norm / param-count scoring (Table 11).

use crate::util::Rng;

/// What to score modules by (paper Table 11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreFn {
    /// Eq. 4 scaled gradient norm EMA (MISA default)
    GradNorm,
    /// ||W||_F / sqrt(|m|)
    WeightNorm,
    /// |m| (parameter count)
    ParamCount,
}

/// How to turn scores into an active set (paper Table 10).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// Prop. 1 softmax sampling with temperature η + Alg. 2 budget
    Importance { eta: f64 },
    /// uniform random without importance
    Uniform,
    /// highest scores first, deterministic
    TopK,
    /// lowest scores first, deterministic (the paper's negative control)
    BottomK,
}

/// Sampler configuration.
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    pub strategy: Strategy,
    pub score_fn: ScoreFn,
    /// EMA coefficient β of Eq. 4
    pub beta: f64,
    /// trainable-parameter ratio δ
    pub delta: f64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            strategy: Strategy::Importance { eta: 1.0 },
            score_fn: ScoreFn::GradNorm,
            beta: 0.9,
            delta: 0.03,
        }
    }
}

/// Importance sampler over `B` modules with parameter counts `numel`.
#[derive(Clone, Debug)]
pub struct ImportanceSampler {
    pub cfg: SamplerConfig,
    /// per-module smoothed importance G_b (Eq. 4)
    pub scores: Vec<f64>,
    /// parameter count per module
    numel: Vec<u64>,
    /// total model parameters (δ budget base)
    n_model: u64,
    /// times each module was sampled (Fig. 11)
    pub counts: Vec<u64>,
    /// whether a module has ever been scored (cold-start exploration)
    seen: Vec<bool>,
    rounds: u64,
}

impl ImportanceSampler {
    pub fn new(cfg: SamplerConfig, numel: Vec<u64>, n_model: u64) -> Self {
        let b = numel.len();
        assert!(b > 0);
        ImportanceSampler {
            cfg,
            scores: vec![0.0; b],
            counts: vec![0; b],
            seen: vec![false; b],
            numel,
            n_model,
            rounds: 0,
        }
    }

    pub fn n_modules(&self) -> usize {
        self.numel.len()
    }

    /// Eq. 4 EMA update for one sampled module: `avg` is the inner-loop
    /// average of the scaled squared gradient norm.
    pub fn update_score(&mut self, module: usize, avg: f64) {
        let b = self.cfg.beta;
        if self.seen[module] {
            self.scores[module] = b * self.scores[module] + (1.0 - b) * avg;
        } else {
            // first observation seeds the EMA directly (G^0 = 0 in the
            // paper; seeding avoids the cold-start bias toward 0)
            self.scores[module] = avg;
            self.seen[module] = true;
        }
    }

    /// Inject non-gradient scores (WeightNorm / ParamCount ablations).
    pub fn set_static_scores(&mut self, scores: Vec<f64>) {
        assert_eq!(scores.len(), self.scores.len());
        self.scores = scores;
        self.seen.fill(true);
    }

    /// Prop. 1 sampling probabilities: softmax(η · G) (numerically
    /// stable host implementation; the Pallas `probs` artifact computes
    /// the identical expression on the kernel path).
    pub fn probabilities(&self) -> Vec<f64> {
        let eta = match self.cfg.strategy {
            Strategy::Importance { eta } => eta,
            // uniform = η → 0 limit (paper Sec. 3.2)
            _ => 0.0,
        };
        softmax_tempered(&self.scores, eta)
    }

    /// Select the active set for the next block epoch (Algorithm 2 for
    /// the sampling strategies; deterministic sweeps for Top-K/Bottom-K).
    pub fn select(&mut self, rng: &mut Rng) -> Vec<usize> {
        let budget = (self.cfg.delta * self.n_model as f64).max(1.0) as u64;
        let order: Vec<usize> = match self.cfg.strategy {
            Strategy::Importance { .. } | Strategy::Uniform => {
                self.draw_without_replacement(rng)
            }
            Strategy::TopK => {
                let mut idx: Vec<usize> = (0..self.n_modules()).collect();
                idx.sort_by(|&a, &b| self.scores[b].total_cmp(&self.scores[a]));
                idx
            }
            Strategy::BottomK => {
                let mut idx: Vec<usize> = (0..self.n_modules()).collect();
                idx.sort_by(|&a, &b| self.scores[a].total_cmp(&self.scores[b]));
                idx
            }
        };
        // Algorithm 2: walk the draw order, admit while the budget holds.
        let mut active = Vec::new();
        let mut used = 0u64;
        for i in order {
            if used + self.numel[i] <= budget {
                used += self.numel[i];
                active.push(i);
            }
        }
        if active.is_empty() {
            // δ smaller than every module: activate the single smallest
            // (the paper guarantees ≥1 active module per epoch)
            let smallest = (0..self.n_modules())
                .min_by_key(|&i| self.numel[i])
                .unwrap();
            active.push(smallest);
        }
        for &i in &active {
            self.counts[i] += 1;
        }
        self.rounds += 1;
        active
    }

    /// Weighted draw of ALL modules without replacement (Alg. 2 line 3),
    /// using the Prop. 1 probabilities (or uniform).
    fn draw_without_replacement(&self, rng: &mut Rng) -> Vec<usize> {
        let mut probs = self.probabilities();
        let mut remaining: Vec<usize> = (0..self.n_modules()).collect();
        let mut order = Vec::with_capacity(remaining.len());
        while !remaining.is_empty() {
            let weights: Vec<f64> = remaining.iter().map(|&i| probs[i].max(1e-300)).collect();
            let pick = rng.weighted(&weights);
            order.push(remaining.swap_remove(pick));
            // note: probs renormalize implicitly through `weighted`
            let _ = &mut probs;
        }
        order
    }

    /// Budget actually used by an active set (params).
    pub fn active_params(&self, active: &[usize]) -> u64 {
        active.iter().map(|&i| self.numel[i]).sum()
    }

    /// Selection rounds completed so far (one per block epoch).
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Per-module parameter counts, pool order.
    pub fn numels(&self) -> &[u64] {
        &self.numel
    }

    /// Corollary 1 lower bound: with bounded scores, every probability
    /// is ≥ 1/(B e^{η π*}).
    pub fn probability_lower_bound(&self) -> f64 {
        let eta = match self.cfg.strategy {
            Strategy::Importance { eta } => eta,
            _ => 0.0,
        };
        let max_score = self.scores.iter().cloned().fold(0.0f64, f64::max);
        1.0 / (self.n_modules() as f64 * (eta * max_score).exp())
    }
}

/// One sampling unit as seen by the telemetry layer: a module for MISA,
/// a whole layer for LISA/BAdam. Everything here is a *read-out* of
/// state the optimizer already tracks — building the snapshot never
/// touches an RNG stream or the computation.
#[derive(Clone, Debug)]
pub struct SamplingUnit {
    /// Human-readable unit name (param name, or `layer.{i}`).
    pub name: String,
    /// Registry parameter indices the unit covers.
    pub params: Vec<usize>,
    /// Transformer layer the unit lives in (−1 for embed/head/norm-style
    /// layerless parameters).
    pub layer: i32,
    /// Current importance score (Eq. 4 EMA; 0.0 for score-free samplers).
    pub score: f64,
    /// Target sampling probability under the sampler's own distribution.
    pub prob: f64,
    /// Times this unit has been active so far.
    pub count: u64,
    /// Total parameters in the unit.
    pub numel: u64,
    /// Whether the unit is active in the current block epoch.
    pub active: bool,
}

/// Telemetry read-out every sampler-backed optimizer exposes. The
/// contract is strictly observational: implementations only *copy*
/// scores, probabilities, and counters they already maintain, so
/// snapshotting is deterministic-by-construction and can never perturb
/// training (bit-parity with telemetry on is test-pinned).
pub trait SamplerTelemetry {
    /// Short stable label for metric names ("misa" / "lisa" / "badam").
    fn sampler_label(&self) -> &'static str;

    /// Selection rounds completed (block epochs / layer switches).
    fn rounds(&self) -> u64;

    /// Snapshot of every sampling unit: scores, target probabilities,
    /// empirical counts, and the current active set.
    fn units(&self) -> Vec<SamplingUnit>;
}

/// Numerically stable tempered softmax: p_i ∝ exp(eta * s_i).
pub fn softmax_tempered(scores: &[f64], eta: f64) -> Vec<f64> {
    let mx = scores
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = scores.iter().map(|&s| (eta * (s - mx)).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Proposition 2 objective: Σ p_i s_i — used by tests to verify that
/// module-wise sampling dominates layer-wise sampling.
pub fn importance_objective(probs: &[f64], scores: &[f64]) -> f64 {
    probs.iter().zip(scores).map(|(p, s)| p * s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler(b: usize, delta: f64, eta: f64) -> ImportanceSampler {
        let numel: Vec<u64> = (0..b).map(|i| 100 + (i as u64 % 7) * 50).collect();
        let n_model: u64 = numel.iter().sum::<u64>() * 3; // modules ≈ third of model
        ImportanceSampler::new(
            SamplerConfig {
                strategy: Strategy::Importance { eta },
                score_fn: ScoreFn::GradNorm,
                beta: 0.9,
                delta,
            },
            numel,
            n_model,
        )
    }

    #[test]
    fn probabilities_form_simplex() {
        crate::prop!("simplex", |rng| {
            let mut s = sampler(rng.range(1, 60), 0.1, rng.f64() * 10.0);
            for i in 0..s.n_modules() {
                s.update_score(i, rng.f64() * 5.0);
            }
            let p = s.probabilities();
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn corollary1_probability_lower_bound_holds() {
        crate::prop!("cor1", |rng| {
            let mut s = sampler(rng.range(2, 40), 0.1, rng.f64() * 3.0);
            for i in 0..s.n_modules() {
                s.update_score(i, rng.f64() * 2.0);
            }
            let bound = s.probability_lower_bound();
            for &p in &s.probabilities() {
                assert!(p >= bound - 1e-12, "p {p} < bound {bound}");
            }
        });
    }

    #[test]
    fn algorithm2_budget_never_exceeded() {
        crate::prop!("alg2_budget", |rng| {
            let delta = 0.01 + rng.f64() * 0.3;
            let mut s = sampler(rng.range(2, 80), delta, 1.0);
            for i in 0..s.n_modules() {
                s.update_score(i, rng.f64());
            }
            let active = s.select(rng);
            assert!(!active.is_empty());
            let budget = (delta * (s.n_model as f64)) as u64;
            let used = s.active_params(&active);
            // either within budget, or the single-smallest fallback fired
            assert!(
                used <= budget || active.len() == 1,
                "used {used} > budget {budget}"
            );
            // no duplicates
            let mut sorted = active.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), active.len());
        });
    }

    #[test]
    fn algorithm2_fills_budget_greedily() {
        // with plenty of equal modules the greedy walk should pack close
        // to the budget
        let mut rng = Rng::new(1);
        let numel = vec![100u64; 50];
        let mut s = ImportanceSampler::new(
            SamplerConfig { delta: 0.1, ..Default::default() },
            numel,
            50 * 100,
        );
        let active = s.select(&mut rng);
        assert_eq!(s.active_params(&active), 500); // exactly δ·n
    }

    #[test]
    fn eta_zero_is_uniform_and_large_eta_concentrates() {
        let mut s = sampler(10, 0.5, 0.0);
        for i in 0..10 {
            s.update_score(i, i as f64);
        }
        let p = s.probabilities();
        for &x in &p {
            assert!((x - 0.1).abs() < 1e-12);
        }
        s.cfg.strategy = Strategy::Importance { eta: 50.0 };
        let p = s.probabilities();
        assert!(p[9] > 0.99);
    }

    #[test]
    fn ema_update_follows_eq4() {
        let mut s = sampler(3, 0.5, 1.0);
        s.update_score(0, 4.0); // first observation seeds
        assert!((s.scores[0] - 4.0).abs() < 1e-12);
        s.update_score(0, 2.0); // then EMA: 0.9*4 + 0.1*2 = 3.8
        assert!((s.scores[0] - 3.8).abs() < 1e-12);
        // unsampled modules keep their score (Eq. 4 "otherwise" branch)
        assert_eq!(s.scores[1], 0.0);
    }

    #[test]
    fn importance_sampling_prefers_high_scores() {
        let mut rng = Rng::new(3);
        let mut s = sampler(20, 0.05, 5.0);
        for i in 0..20 {
            s.update_score(i, if i == 7 { 10.0 } else { 0.1 });
        }
        let mut hits7 = 0;
        for _ in 0..200 {
            if s.select(&mut rng).contains(&7) {
                hits7 += 1;
            }
        }
        assert!(hits7 > 150, "module 7 sampled only {hits7}/200");
    }

    #[test]
    fn but_low_scores_still_explored() {
        // the KL term keeps exploration alive: every module must appear
        // eventually (paper Table 10's critique of Top-K)
        let mut rng = Rng::new(4);
        let mut s = sampler(10, 0.15, 1.0);
        for i in 0..10 {
            s.update_score(i, if i == 0 { 5.0 } else { 0.1 });
        }
        for _ in 0..400 {
            s.select(&mut rng);
        }
        for (i, &c) in s.counts.iter().enumerate() {
            assert!(c > 0, "module {i} never sampled");
        }
    }

    #[test]
    fn topk_is_deterministic_and_bottomk_opposite() {
        let mut rng = Rng::new(5);
        let numel = vec![100u64; 10];
        let mk = |strategy| {
            let mut s = ImportanceSampler::new(
                SamplerConfig { strategy, delta: 0.07, ..Default::default() },
                numel.clone(),
                3000,
            );
            for i in 0..10 {
                s.update_score(i, i as f64);
            }
            s
        };
        let mut top = mk(Strategy::TopK);
        let a = top.select(&mut rng);
        assert_eq!(a, vec![9, 8]); // 2 × 100 ≤ 210 budget
        let mut bot = mk(Strategy::BottomK);
        let b = bot.select(&mut rng);
        assert_eq!(b, vec![0, 1]);
    }

    #[test]
    fn proposition2_module_beats_layer_sampling() {
        // Prop. 2: the optimal module-wise distribution achieves an
        // objective ≥ any layer-wise distribution split uniformly over
        // its modules.
        crate::prop!("prop2", |rng| {
            let layers = rng.range(1, 6);
            let k = rng.range(1, 5); // modules per layer
            let scores: Vec<f64> = (0..layers * k).map(|_| rng.f64() * 3.0).collect();
            let eta = 0.5 + rng.f64() * 2.0;
            // layer-wise: probabilities over layer sums, split uniformly
            let layer_scores: Vec<f64> = (0..layers)
                .map(|l| scores[l * k..(l + 1) * k].iter().sum::<f64>() / k as f64)
                .collect();
            let layer_probs = softmax_tempered(&layer_scores, eta);
            let spread: Vec<f64> = (0..layers * k)
                .map(|i| layer_probs[i / k] / k as f64)
                .collect();
            // module-wise: direct softmax over module scores
            let module_probs = softmax_tempered(&scores, eta);
            let lw = importance_objective(&spread, &scores);
            let mw = importance_objective(&module_probs, &scores);
            assert!(mw >= lw - 1e-9, "module {mw} < layer {lw}");
        });
    }

    #[test]
    fn fallback_when_delta_below_smallest_module() {
        let mut rng = Rng::new(6);
        let numel = vec![1000u64, 2000, 500];
        let mut s = ImportanceSampler::new(
            SamplerConfig { delta: 1e-6, ..Default::default() },
            numel,
            1_000_000,
        );
        let active = s.select(&mut rng);
        assert_eq!(active, vec![2]); // smallest module
    }

    #[test]
    fn counts_accumulate() {
        let mut rng = Rng::new(7);
        let mut s = sampler(5, 0.5, 0.0);
        for _ in 0..50 {
            s.select(&mut rng);
        }
        let total: u64 = s.counts.iter().sum();
        assert!(total >= 50);
    }
}
