//! Adam — the paper's update rule (Algorithm 1 lines 9-11, no bias
//! correction) as reusable per-module state, plus the dense full-model
//! fine-tuning baseline ("FT" rows of Tables 1/3).
//!
//! Two execution paths exist and must agree bit-for-bit in tests:
//! the host path (plain Rust loops, used for adapter matrices that have
//! no AOT artifact) and the kernel path (the fused-Adam Pallas
//! executable on the session).

use anyhow::Result;

use crate::modelspec::ModelSpec;
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};

/// Adam hyper-parameters (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamHyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamHyper {
    fn default() -> Self {
        AdamHyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-parameter Adam moments.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl AdamState {
    pub fn zeros(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Host Adam step: p <- p - lr * m' / (sqrt(v') + eps).
    pub fn step(&mut self, p: &mut [f32], g: &[f32], lr: f32, h: AdamHyper) {
        debug_assert_eq!(p.len(), g.len());
        debug_assert_eq!(p.len(), self.m.len());
        for i in 0..p.len() {
            let gi = g[i];
            let mi = h.beta1 * self.m[i] + (1.0 - h.beta1) * gi;
            let vi = h.beta2 * self.v[i] + (1.0 - h.beta2) * gi * gi;
            self.m[i] = mi;
            self.v[i] = vi;
            p[i] -= lr * mi / (vi.sqrt() + h.eps);
        }
    }

    /// AMSGrad-type step of the paper's analytical view (Algorithm 3
    /// lines 11-15): the effective second moment is
    /// `ṽ_t = max(v_t, ||ṽ_{t-1}||_max)` — elementwise max against the
    /// running *scalar* max — and the update divides by `sqrt(ṽ_t)+eps`.
    /// `vmax` carries `||ṽ||_max` across calls (and, via the caller,
    /// across block epochs: the second-order momentum inheritance
    /// `v^{n,0} = ||ṽ^{n-1,T}||_max · I` that Lemma 1 needs).
    pub fn step_amsgrad(&mut self, p: &mut [f32], g: &[f32], lr: f32,
                        h: AdamHyper, vmax: &mut f32) {
        debug_assert_eq!(p.len(), g.len());
        let prev_max = *vmax;
        let mut new_max = prev_max;
        for i in 0..p.len() {
            let gi = g[i];
            let mi = h.beta1 * self.m[i] + (1.0 - h.beta1) * gi;
            let vi = h.beta2 * self.v[i] + (1.0 - h.beta2) * gi * gi;
            self.m[i] = mi;
            self.v[i] = vi;
            let vt = vi.max(prev_max);
            new_max = new_max.max(vt);
            p[i] -= lr * mi / (vt.sqrt() + h.eps);
        }
        *vmax = new_max;
    }

    /// The additional momentum step (Alg. 1 line 16), host path.
    pub fn momentum_tail(&self, p: &mut [f32], lr: f32, h: AdamHyper) {
        let c1 = h.beta1 / (1.0 - h.beta1);
        for i in 0..p.len() {
            p[i] -= lr * c1 * self.m[i] / (self.v[i].sqrt() + h.eps);
        }
    }

    pub fn elems(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64
    }
}

/// Dense full-parameter Adam — the "FT" baseline. Updates every
/// trainable parameter every step through the fused-Adam kernel
/// executables (host fallback for shapes without one).
pub struct FullAdam {
    hyper: AdamHyper,
    trainable: Vec<usize>,
    states: Vec<AdamState>,
    use_kernel: bool,
}

impl FullAdam {
    pub fn new(spec: &ModelSpec, pretrain: bool, use_kernel: bool) -> Self {
        let trainable = spec.trainable_indices(pretrain);
        let states = trainable
            .iter()
            .map(|&i| AdamState::zeros(spec.params[i].numel()))
            .collect();
        FullAdam { hyper: AdamHyper::default(), trainable, states, use_kernel }
    }
}

impl Optimizer for FullAdam {
    fn name(&self) -> String {
        "FT(Adam)".into()
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        for (slot, &idx) in self.trainable.clone().iter().enumerate() {
            let g = &out.grads[idx];
            if self.use_kernel {
                let st = &self.states[slot];
                let (m, v, _sq) = sess.adam_update(idx, g, &st.m, &st.v, lr)?;
                self.states[slot].m = m;
                self.states[slot].v = v;
            } else {
                let mut p = std::mem::take(&mut sess.host[idx]);
                self.states[slot].step(&mut p, g, lr, self.hyper);
                sess.set_param(idx, p)?;
            }
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let optim: u64 = self.states.iter().map(|s| s.elems()).sum();
        MemProfile {
            grad_elems: optim / 2,
            optim_elems: optim,
            adapter_elems: 0,
            active_indices: self.trainable.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn host_adam_matches_reference_formula() {
        let mut st = AdamState::zeros(3);
        let mut p = vec![1.0f32, 2.0, 3.0];
        let g = vec![0.1f32, -0.2, 0.3];
        let h = AdamHyper::default();
        st.step(&mut p, &g, 0.01, h);
        // m = 0.1*g, v = 0.001*g^2, p -= lr*m/(sqrt(v)+eps)
        for i in 0..3 {
            let m = 0.1 * g[i];
            let v = 0.001 * g[i] * g[i];
            let want = [1.0, 2.0, 3.0][i] - 0.01 * m / (v.sqrt() + 1e-8);
            assert!((p[i] - want).abs() < 1e-6, "{} vs {}", p[i], want);
        }
    }

    #[test]
    fn adam_is_scale_invariant_ish() {
        // with constant gradient, steady-state step size approaches lr
        let mut st = AdamState::zeros(1);
        let mut p = vec![0.0f32];
        let g = vec![5.0f32];
        let h = AdamHyper::default();
        let mut prev = p[0];
        // no bias correction (paper Alg. 1): v's time constant is
        // 1/(1-beta2) = 1000 steps, so run well past it
        for _ in 0..10_000 {
            prev = p[0];
            st.step(&mut p, &g, 0.01, h);
        }
        let step = (prev - p[0]).abs();
        assert!((step - 0.01).abs() < 1e-3, "step {step}");
    }

    #[test]
    fn momentum_tail_moves_param_along_momentum() {
        let mut st = AdamState::zeros(2);
        let mut p = vec![0.0f32, 0.0];
        st.step(&mut p, &[1.0, -1.0], 0.1, AdamHyper::default());
        let before = p.clone();
        st.momentum_tail(&mut p, 0.1, AdamHyper::default());
        // tail step continues in the same direction as the last update
        assert!(p[0] < before[0]);
        assert!(p[1] > before[1]);
    }

    #[test]
    fn amsgrad_vmax_monotone_and_step_bounded() {
        // Algorithm 3: ||ṽ||_max never decreases, and because ṽ ≥ v the
        // AMSGrad step never exceeds the plain-Adam step in magnitude.
        let mut rng = Rng::new(5);
        let n = 32;
        let mut st_a = AdamState::zeros(n);
        let mut st_b = AdamState::zeros(n);
        let mut p_a = vec![0.0f32; n];
        let mut p_b = vec![0.0f32; n];
        let h = AdamHyper::default();
        let mut vmax = 0.0f32;
        let mut prev_vmax = 0.0f32;
        for _ in 0..200 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let before_a = p_a.clone();
            let before_b = p_b.clone();
            st_a.step_amsgrad(&mut p_a, &g, 0.01, h, &mut vmax);
            st_b.step(&mut p_b, &g, 0.01, h);
            assert!(vmax >= prev_vmax, "vmax decreased");
            prev_vmax = vmax;
            for i in 0..n {
                let da = (p_a[i] - before_a[i]).abs();
                let db = (p_b[i] - before_b[i]).abs();
                assert!(da <= db + 1e-7, "amsgrad step larger: {da} > {db}");
            }
        }
        assert!(vmax > 0.0);
    }

    #[test]
    fn amsgrad_inheritance_dampens_fresh_state_spike() {
        // clearing Adam states makes the first post-switch steps large
        // (v starts at 0); Alg. 3's inheritance v^{n,0} = ||ṽ||_max
        // bounds them — simulate a block switch and compare first-step
        // magnitudes.
        let h = AdamHyper::default();
        let g = vec![1.0f32; 4];
        // plain cleared state: first step ≈ lr * 0.1g / sqrt(0.001 g²)
        let mut fresh = AdamState::zeros(4);
        let mut p1 = vec![0.0f32; 4];
        fresh.step(&mut p1, &g, 0.01, h);
        // inherited: same clear but vmax carried from a previous epoch
        let mut inh = AdamState::zeros(4);
        let mut p2 = vec![0.0f32; 4];
        let mut vmax = 1.0f32; // previous epoch saw ||ṽ||_max = 1
        inh.step_amsgrad(&mut p2, &g, 0.01, h, &mut vmax);
        assert!(p2[0].abs() < p1[0].abs(),
                "inheritance did not dampen: {} vs {}", p2[0], p1[0]);
    }

    #[test]
    fn property_adam_descends_quadratic() {
        // minimizing 0.5*||x - c||^2: Adam must reduce distance to c
        crate::prop!("adam_quadratic", |rng| {
            let n = rng.range(1, 20);
            let c: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut x = vec![0.0f32; n];
            let mut st = AdamState::zeros(n);
            let h = AdamHyper::default();
            let d0: f32 = c.iter().zip(&x).map(|(a, b)| (a - b).powi(2)).sum();
            for _ in 0..200 {
                let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
                st.step(&mut x, &g, 0.05, h);
            }
            let d1: f32 = c.iter().zip(&x).map(|(a, b)| (a - b).powi(2)).sum();
            assert!(d1 < d0 * 0.5 || d0 < 1e-3, "d0 {d0} d1 {d1}");
        });
    }
}
