//! LoRA + MISA hybrid (paper Appendix B.2).
//!
//! The LoRA adapters become the module pool: MISA's importance sampler
//! activates a subset of adapters each round under `δ · n_LoRA` (the
//! budget is over **adapter** parameters, not model parameters), while
//! the base weights stay frozen. Per the paper, optimizer states are
//! *retained* across rounds here — adapters are tiny, so clearing buys
//! nothing (Fig. 6 keeps full-LoRA quality at δ ≈ 30% with ~8% less
//! memory).

use anyhow::Result;

use crate::modelspec::{ModelSpec, ModuleKind};
use crate::optim::lora::Lora;
use crate::optim::sampler::{ImportanceSampler, SamplerConfig};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};
use crate::util::Rng;

pub struct LoraMisa {
    lora: Lora,
    sampler: ImportanceSampler,
    /// pool: adapter param indices (model registry indices)
    pool: Vec<usize>,
    active: Vec<usize>,
    accum: Vec<f64>,
    t_inner: usize,
    inner_t: usize,
    rng: Rng,
}

impl LoraMisa {
    pub fn new(spec: &ModelSpec, sess_host: &[Vec<f32>], rank: usize, alpha: f32,
               targets: &[ModuleKind], delta: f64, eta: f64, t_inner: usize,
               seed: u64) -> Self {
        let lora = Lora::new(spec, sess_host, rank, alpha, targets, seed);
        let pool: Vec<usize> = lora.adapter_order().to_vec();
        // module sizes = adapter sizes; budget base = total LoRA params
        let numel: Vec<u64> = pool
            .iter()
            .map(|&i| {
                let ad = &lora.adapters[&i];
                (ad.a.data.len() + ad.b.data.len()) as u64
            })
            .collect();
        let n_lora: u64 = numel.iter().sum();
        let sampler = ImportanceSampler::new(
            SamplerConfig {
                strategy: crate::optim::sampler::Strategy::Importance { eta },
                score_fn: crate::optim::sampler::ScoreFn::GradNorm,
                beta: 0.9,
                delta,
            },
            numel,
            n_lora,
        );
        LoraMisa {
            lora,
            sampler,
            pool,
            active: Vec::new(),
            accum: Vec::new(),
            t_inner,
            inner_t: 0,
            rng: Rng::new(seed ^ 0x4C4D4953),
        }
    }

    pub fn active_adapter_params(&self) -> u64 {
        self.active
            .iter()
            .map(|&a| {
                let ad = &self.lora.adapters[&self.pool[a]];
                (ad.a.data.len() + ad.b.data.len()) as u64
            })
            .sum()
    }
}

impl Optimizer for LoraMisa {
    fn name(&self) -> String {
        format!(
            "LoRA+MISA(r={},d={:.0}%)",
            self.lora.rank,
            self.sampler.cfg.delta * 100.0
        )
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        if self.inner_t == 0 {
            self.active = self.sampler.select(&mut self.rng);
            self.accum = vec![0.0; self.active.len()];
        }
        for (slot, &a) in self.active.clone().iter().enumerate() {
            let idx = self.pool[a];
            let g = &out.grads[idx];
            self.accum[slot] += out.sq_norms[idx] as f64 / g.len() as f64;
            let w_eff = self.lora.update_adapter(idx, g, lr);
            sess.set_param(idx, w_eff.data)?;
        }
        self.inner_t += 1;
        if self.inner_t >= self.t_inner {
            for (slot, &a) in self.active.iter().enumerate() {
                self.sampler
                    .update_score(a, self.accum[slot] / self.t_inner as f64);
            }
            self.inner_t = 0;
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        // adapters + ALL optimizer states (retained — Appendix B.2), but
        // grads only for the active subset
        let all = self.lora.trainable_elems();
        MemProfile {
            grad_elems: self.active_adapter_params(),
            optim_elems: 2 * all,
            adapter_elems: all,
            active_indices: self.active.iter().map(|&a| self.pool[a]).collect(),
        }
    }

    fn sampling_counts(&self) -> Option<Vec<(usize, u64)>> {
        Some(
            self.pool
                .iter()
                .zip(&self.sampler.counts)
                .map(|(&i, &c)| (i, c))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::Manifest;
    use std::path::Path;

    fn spec() -> ModelSpec {
        let text = "\
version 1
config t
  field vocab 64
  field dim 8
  field n_layers 2
  field n_heads 2
  field n_kv_heads 1
  field ffn_dim 16
  field seq_len 8
  field batch 2
  param layers.0.wq wq 0 2 8 8
  param layers.0.wup wup 0 2 8 16
  param layers.1.wq wq 1 2 8 8
  param layers.1.wup wup 1 2 8 16
  param embed embed -1 2 64 8
";
        Manifest::parse(Path::new("/tmp"), text).unwrap().models[0].clone()
    }

    #[test]
    fn budget_is_over_adapter_params() {
        let s = spec();
        let mut rng = Rng::new(0);
        let host: Vec<Vec<f32>> = s
            .params
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.numel()];
                rng.fill_normal(&mut v, 0.1);
                v
            })
            .collect();
        let mut lm = LoraMisa::new(
            &s, &host, 2, 4.0,
            &[ModuleKind::Wq, ModuleKind::Wup],
            0.5, 1.0, 10, 0,
        );
        let total: u64 = lm
            .pool
            .iter()
            .map(|&i| {
                let ad = &lm.lora.adapters[&i];
                (ad.a.data.len() + ad.b.data.len()) as u64
            })
            .sum();
        lm.active = lm.sampler.select(&mut lm.rng);
        assert!(lm.active_adapter_params() <= total / 2 + 1);
        assert!(!lm.active.is_empty());
    }
}
