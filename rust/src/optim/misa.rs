//! MISA — Module-wise Importance Sampling (paper Algorithm 1).
//!
//! Double loop: every `T` inner Adam steps the sampler draws a fresh
//! module set under the δ budget (Algorithm 2), the finished modules get
//! the additional momentum step (line 16), their optimizer states are
//! cleared (line 17 — the memory contribution), and the Eq. 4 EMA +
//! Prop. 1 softmax are refreshed from the Pallas-computed gradient
//! norms accumulated over the inner loop.
//!
//! In pre-training mode the embedding/head/norm parameters are trained
//! by ordinary dense Adam alongside (paper Sec. 5.4); in fine-tuning
//! they stay frozen (Table 2 footnote).

use std::collections::HashMap;

use anyhow::Result;

use crate::modelspec::ModelSpec;
use crate::optim::adam::{AdamHyper, AdamState};
use crate::optim::sampler::{
    ImportanceSampler, SamplerConfig, SamplerTelemetry, SamplingUnit, ScoreFn,
};
use crate::optim::{MemProfile, Optimizer};
use crate::runtime::{Session, StepOutput};
use crate::util::Rng;

/// MISA configuration (paper Table 18/20/22 hyper-parameters).
#[derive(Clone, Debug)]
pub struct MisaConfig {
    pub sampler: SamplerConfig,
    /// inner-loop length T (Adam steps per sampled block)
    pub t_inner: usize,
    /// pre-training mode: dense-Adam embed/head/norms (Sec. 5.4)
    pub pretrain: bool,
    /// Alg. 1 line 17 — clear optimizer states at block switch.
    /// `false` reproduces the "MISA w/ preserve optim." ablation (Fig. 7)
    pub clear_states: bool,
    /// apply the additional momentum step (Alg. 1 line 16)
    pub momentum_tail: bool,
    /// Algorithm 3 (analytical view): AMSGrad-type normalization with
    /// second-order momentum inheritance across block epochs. Host-path
    /// only (the fused kernel implements the practical Algorithm 1).
    pub amsgrad: bool,
    /// run module updates through the fused-Adam Pallas executables
    /// (false = host loops; both paths are numerically identical)
    pub use_kernel: bool,
    /// kernel-dispatch threshold: modules smaller than this run the
    /// host loop even when `use_kernel` — on the CPU PJRT backend the
    /// executable dispatch + literal copies cost ~1.6 ms while a host
    /// pass over a 44k-element module costs ~22 µs (see
    /// EXPERIMENTS.md §Perf); large modules amortize the dispatch.
    pub kernel_min_elems: usize,
}

impl Default for MisaConfig {
    fn default() -> Self {
        MisaConfig {
            sampler: SamplerConfig::default(),
            t_inner: 50,
            pretrain: false,
            clear_states: true,
            momentum_tail: true,
            amsgrad: false,
            use_kernel: true,
            kernel_min_elems: 1 << 17,
        }
    }
}

pub struct Misa {
    cfg: MisaConfig,
    hyper: AdamHyper,
    /// module pool: global param indices the sampler draws from
    pool: Vec<usize>,
    /// param names of the pool (telemetry labels), pool order
    unit_names: Vec<String>,
    /// transformer layer per pool module (telemetry grouping)
    unit_layers: Vec<i32>,
    /// sampler over the pool (local indices)
    pub sampler: ImportanceSampler,
    /// currently active pool-local indices
    active: Vec<usize>,
    /// Adam states of active modules, keyed by pool-local index
    states: HashMap<usize, AdamState>,
    /// inner-loop accumulator: Σ_t scaled ||g||² per active module
    accum: HashMap<usize, f64>,
    /// dense-Adam states for embed/head/norms in pre-training
    dense: Vec<(usize, AdamState)>,
    inner_t: usize,
    rng: Rng,
    /// retained (module, state) pairs when clear_states=false
    preserved: HashMap<usize, AdamState>,
    /// Algorithm 3: running ||ṽ||_max inherited across block epochs
    vmax: f32,
}

impl Misa {
    pub fn new(spec: &ModelSpec, cfg: MisaConfig, seed: u64) -> Self {
        let pool = spec.matrix_module_indices();
        let numel: Vec<u64> = pool.iter().map(|&i| spec.params[i].numel() as u64).collect();
        // δ is defined over the whole model's parameters (paper Alg. 2)
        let n_model = spec.total_params() as u64;
        let mut sampler = ImportanceSampler::new(cfg.sampler.clone(), numel, n_model);
        match cfg.sampler.score_fn {
            ScoreFn::GradNorm => {}
            ScoreFn::WeightNorm => {
                // seeded at construction from the initial weights; the
                // trainer refreshes these each round via set_static_scores
            }
            ScoreFn::ParamCount => {
                let scores: Vec<f64> = pool
                    .iter()
                    .map(|&i| spec.params[i].numel() as f64)
                    .collect();
                let mx = scores.iter().cloned().fold(1.0, f64::max);
                sampler.set_static_scores(scores.iter().map(|s| s / mx).collect());
            }
        }
        let dense = if cfg.pretrain {
            spec.params
                .iter()
                .enumerate()
                .filter(|(_, p)| !p.kind.is_matrix_module())
                .map(|(i, p)| (i, AdamState::zeros(p.numel())))
                .collect()
        } else {
            Vec::new()
        };
        let unit_names = pool.iter().map(|&i| spec.params[i].name.clone()).collect();
        let unit_layers = pool.iter().map(|&i| spec.params[i].layer).collect();
        Misa {
            cfg,
            hyper: AdamHyper::default(),
            pool,
            unit_names,
            unit_layers,
            sampler,
            active: Vec::new(),
            states: HashMap::new(),
            accum: HashMap::new(),
            dense,
            inner_t: 0,
            rng: Rng::new(seed ^ 0x4D495341), // "MISA"
            preserved: HashMap::new(),
            vmax: 0.0,
        }
    }

    /// Restrict the sampler pool to the given module kinds (the per-
    /// module ablation of Table 12 / Fig. 10).
    pub fn restrict_pool(spec: &ModelSpec, cfg: MisaConfig, seed: u64,
                         kinds: &[crate::modelspec::ModuleKind]) -> Self {
        let mut me = Self::new(spec, cfg, seed);
        let filtered: Vec<usize> = me
            .pool
            .iter()
            .copied()
            .filter(|&i| kinds.contains(&spec.params[i].kind))
            .collect();
        let numel: Vec<u64> = filtered
            .iter()
            .map(|&i| spec.params[i].numel() as u64)
            .collect();
        me.sampler = ImportanceSampler::new(
            me.cfg.sampler.clone(),
            numel,
            spec.total_params() as u64,
        );
        me.unit_names = filtered
            .iter()
            .map(|&i| spec.params[i].name.clone())
            .collect();
        me.unit_layers = filtered.iter().map(|&i| spec.params[i].layer).collect();
        me.pool = filtered;
        me
    }

    /// Begin a block epoch: sample the active set, set up states.
    fn begin_round(&mut self, sess: &Session) {
        if self.cfg.sampler.score_fn == ScoreFn::WeightNorm {
            // refresh weight-norm scores from the live parameters
            let scores: Vec<f64> = self
                .pool
                .iter()
                .map(|&i| {
                    let w = &sess.host[i];
                    let sq: f64 = w.iter().map(|&x| (x as f64) * (x as f64)).sum();
                    (sq / w.len() as f64).sqrt()
                })
                .collect();
            self.sampler.set_static_scores(scores);
        }
        self.active = self.sampler.select(&mut self.rng);
        self.states.clear();
        self.accum.clear();
        for &a in &self.active {
            let n = sess.spec.params[self.pool[a]].numel();
            let st = if self.cfg.clear_states {
                AdamState::zeros(n)
            } else {
                self.preserved
                    .get(&a)
                    .cloned()
                    .unwrap_or_else(|| AdamState::zeros(n))
            };
            self.states.insert(a, st);
            self.accum.insert(a, 0.0);
        }
        self.inner_t = 0;
    }

    /// End a block epoch: momentum tail, Eq. 4 EMA refresh, clear states.
    fn end_round(&mut self, sess: &mut Session, lr: f32) -> Result<()> {
        for &a in &self.active.clone() {
            let idx = self.pool[a];
            if self.cfg.momentum_tail {
                let st = self.states.get(&a).unwrap();
                if self.cfg.use_kernel && st.m.len() >= self.cfg.kernel_min_elems {
                    sess.tail_update(idx, &st.m, &st.v, lr)?;
                } else {
                    let mut p = std::mem::take(&mut sess.host[idx]);
                    st.momentum_tail(&mut p, lr, self.hyper);
                    sess.set_param(idx, p)?;
                }
            }
            let avg = self.accum[&a] / self.cfg.t_inner.max(1) as f64;
            if self.cfg.sampler.score_fn == ScoreFn::GradNorm {
                self.sampler.update_score(a, avg);
            }
            if self.cfg.clear_states {
                self.states.remove(&a); // Alg. 1 line 17
            } else if let Some(st) = self.states.remove(&a) {
                self.preserved.insert(a, st); // Fig. 7 ablation
            }
        }
        Ok(())
    }
}

impl Optimizer for Misa {
    fn name(&self) -> String {
        format!(
            "MISA(d={:.0}%,T={})",
            self.cfg.sampler.delta * 100.0,
            self.cfg.t_inner
        )
    }

    fn step(&mut self, sess: &mut Session, out: &StepOutput, lr: f32) -> Result<()> {
        if self.inner_t == 0 {
            self.begin_round(sess);
        }
        // inner Adam step on each active module
        for &a in &self.active.clone() {
            let idx = self.pool[a];
            let g = &out.grads[idx];
            let numel = g.len() as f64;
            // scaled squared norm from the Pallas by-product (App. A.2)
            *self.accum.get_mut(&a).unwrap() += out.sq_norms[idx] as f64 / numel;
            let st = self.states.get_mut(&a).unwrap();
            if self.cfg.amsgrad {
                // Algorithm 3 path: AMSGrad normalization + inheritance
                let mut p = std::mem::take(&mut sess.host[idx]);
                let mut vmax = self.vmax;
                st.step_amsgrad(&mut p, g, lr, self.hyper, &mut vmax);
                self.vmax = vmax;
                sess.set_param(idx, p)?;
            } else if self.cfg.use_kernel && g.len() >= self.cfg.kernel_min_elems {
                let (m, v, _sq) = sess.adam_update(idx, g, &st.m, &st.v, lr)?;
                st.m = m;
                st.v = v;
            } else {
                let mut p = std::mem::take(&mut sess.host[idx]);
                st.step(&mut p, g, lr, self.hyper);
                sess.set_param(idx, p)?;
            }
        }
        // dense Adam on embed/head/norms in pre-training
        for (idx, st) in &mut self.dense {
            let mut p = std::mem::take(&mut sess.host[*idx]);
            st.step(&mut p, &out.grads[*idx], lr, self.hyper);
            sess.set_param(*idx, p)?;
        }
        self.inner_t += 1;
        if self.inner_t >= self.cfg.t_inner {
            self.end_round(sess, lr)?;
            self.inner_t = 0;
        }
        Ok(())
    }

    fn mem_profile(&self) -> MemProfile {
        let active_elems: u64 = self
            .states
            .values()
            .map(|s| s.elems() / 2)
            .sum();
        let dense_elems: u64 = self.dense.iter().map(|(_, s)| s.elems() / 2).sum();
        let preserved: u64 = if self.cfg.clear_states {
            0
        } else {
            self.preserved.values().map(|s| s.elems()).sum()
        };
        MemProfile {
            grad_elems: active_elems + dense_elems,
            optim_elems: 2 * (active_elems + dense_elems) + preserved
                + self.sampler.n_modules() as u64 * 2, // G_b + p_b indicators
            adapter_elems: 0,
            active_indices: self.active.iter().map(|&a| self.pool[a]).collect(),
        }
    }

    fn sampling_counts(&self) -> Option<Vec<(usize, u64)>> {
        Some(
            self.pool
                .iter()
                .zip(&self.sampler.counts)
                .map(|(&idx, &c)| (idx, c))
                .collect(),
        )
    }

    fn telemetry(&self) -> Option<&dyn SamplerTelemetry> {
        Some(self)
    }
}

impl SamplerTelemetry for Misa {
    fn sampler_label(&self) -> &'static str {
        "misa"
    }

    fn rounds(&self) -> u64 {
        self.sampler.rounds()
    }

    fn units(&self) -> Vec<SamplingUnit> {
        let probs = self.sampler.probabilities();
        let numels = self.sampler.numels();
        (0..self.pool.len())
            .map(|a| SamplingUnit {
                name: self.unit_names[a].clone(),
                params: vec![self.pool[a]],
                layer: self.unit_layers[a],
                score: self.sampler.scores[a],
                prob: probs[a],
                count: self.sampler.counts[a],
                numel: numels[a],
                active: self.active.contains(&a),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_match_paper() {
        let c = MisaConfig::default();
        assert_eq!(c.t_inner, 50); // paper Tables 18/20/22: T = 50
        assert!(c.clear_states); // Alg. 1 line 17
        assert!(c.momentum_tail); // Alg. 1 line 16
        assert!((c.sampler.delta - 0.03).abs() < 1e-12);
    }
}
