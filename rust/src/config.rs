//! Run configuration: a TOML-subset parser (offline build — no serde)
//! and the typed training configuration the launcher consumes.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"…"`), float, integer, and boolean values, `#` comments.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::TaskKind;
use crate::optim::sampler::{SamplerConfig, ScoreFn, Strategy};
use crate::optim::MisaConfig;

/// Parsed config document: section -> key -> raw value.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    sections: HashMap<String, HashMap<String, String>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: bad section header {raw:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                let mut val = line[eq + 1..].trim().to_string();
                if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                    val = val[1..val.len() - 1].to_string();
                }
                doc.sections.entry(section.clone()).or_default().insert(key, val);
            } else {
                bail!("line {}: expected key = value, got {raw:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<Doc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Doc::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("{section}.{key}: bad float {v:?}")),
        }
    }

    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> Result<u64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("{section}.{key}: bad int {v:?}")),
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => bail!("{section}.{key}: bad bool {v:?}"),
        }
    }
}

/// Which optimizer to run (the `method` table of a run config).
#[derive(Clone, Debug)]
pub enum MethodSpec {
    Misa(MisaConfig),
    FullAdam,
    BAdam { t_inner: usize },
    Lisa { t_inner: usize },
    Lora { rank: usize, alpha: f32 },
    Dora { rank: usize, alpha: f32 },
    Galore { rank: usize, update_freq: u64, scale: f32 },
    LoraMisa { rank: usize, alpha: f32, delta: f64, eta: f64, t_inner: usize },
}

impl MethodSpec {
    pub fn label(&self) -> String {
        match self {
            MethodSpec::Misa(c) => format!("MISA(d={:.0}%)", c.sampler.delta * 100.0),
            MethodSpec::FullAdam => "FT".into(),
            MethodSpec::BAdam { .. } => "BAdam".into(),
            MethodSpec::Lisa { .. } => "LISA".into(),
            MethodSpec::Lora { rank, .. } => format!("LoRA(r={rank})"),
            MethodSpec::Dora { rank, .. } => format!("DoRA(r={rank})"),
            MethodSpec::Galore { rank, .. } => format!("GaLore(r={rank})"),
            MethodSpec::LoraMisa { delta, .. } => {
                format!("LoRA+MISA(d={:.0}%)", delta * 100.0)
            }
        }
    }
}

/// Data selection for a run.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSpec {
    /// Zipf-Markov LM stream (pre-training)
    Lm,
    /// commonsense task suite
    Commonsense,
    /// math task suite
    Math,
    /// instruction mixture (all 12 families)
    Instruction,
}

impl DataSpec {
    pub fn kinds(&self) -> Vec<TaskKind> {
        match self {
            DataSpec::Lm => vec![],
            DataSpec::Commonsense => TaskKind::COMMONSENSE.to_vec(),
            DataSpec::Math => TaskKind::MATH.to_vec(),
            DataSpec::Instruction => TaskKind::ALL.to_vec(),
        }
    }
}

/// A full training-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub method: MethodSpec,
    pub data: DataSpec,
    pub lr: f32,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub log_every: u64,
    pub seed: u64,
    pub pretrain: bool,
    pub use_kernel: bool,
    pub out_dir: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "small".into(),
            method: MethodSpec::Misa(MisaConfig::default()),
            data: DataSpec::Instruction,
            lr: 1e-3,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            log_every: 10,
            seed: 0,
            pretrain: false,
            use_kernel: true,
            out_dir: None,
        }
    }
}

impl RunConfig {
    /// Build from a parsed TOML-subset document.
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        let mut rc = RunConfig::default();
        rc.model = doc.str_or("run", "model", &rc.model);
        rc.lr = doc.f64_or("run", "lr", rc.lr as f64)? as f32;
        rc.steps = doc.u64_or("run", "steps", rc.steps)?;
        rc.eval_every = doc.u64_or("run", "eval_every", rc.eval_every)?;
        rc.eval_batches = doc.u64_or("run", "eval_batches", rc.eval_batches as u64)? as usize;
        rc.log_every = doc.u64_or("run", "log_every", rc.log_every)?;
        rc.seed = doc.u64_or("run", "seed", rc.seed)?;
        rc.pretrain = doc.bool_or("run", "pretrain", rc.pretrain)?;
        rc.use_kernel = doc.bool_or("run", "use_kernel", rc.use_kernel)?;
        rc.out_dir = doc.get("run", "out_dir").map(|s| s.to_string());
        rc.data = match doc.str_or("run", "data", "instruction").as_str() {
            "lm" => DataSpec::Lm,
            "commonsense" => DataSpec::Commonsense,
            "math" => DataSpec::Math,
            "instruction" => DataSpec::Instruction,
            other => bail!("unknown data spec {other:?}"),
        };
        let t_inner = doc.u64_or("method", "t_inner", 50)? as usize;
        let rank = doc.u64_or("method", "rank", 16)? as usize;
        let alpha = doc.f64_or("method", "alpha", 32.0)? as f32;
        let delta = doc.f64_or("method", "delta", 0.03)?;
        let eta = doc.f64_or("method", "eta", 1.0)?;
        rc.method = match doc.str_or("method", "name", "misa").as_str() {
            "misa" => {
                let strategy = match doc.str_or("method", "strategy", "importance").as_str() {
                    "importance" => Strategy::Importance { eta },
                    "uniform" => Strategy::Uniform,
                    "topk" => Strategy::TopK,
                    "bottomk" => Strategy::BottomK,
                    other => bail!("unknown strategy {other:?}"),
                };
                let score_fn = match doc.str_or("method", "score", "grad_norm").as_str() {
                    "grad_norm" => ScoreFn::GradNorm,
                    "weight_norm" => ScoreFn::WeightNorm,
                    "param_count" => ScoreFn::ParamCount,
                    other => bail!("unknown score fn {other:?}"),
                };
                MethodSpec::Misa(MisaConfig {
                    sampler: SamplerConfig {
                        strategy,
                        score_fn,
                        beta: doc.f64_or("method", "beta", 0.9)?,
                        delta,
                    },
                    t_inner,
                    pretrain: rc.pretrain,
                    clear_states: doc.bool_or("method", "clear_states", true)?,
                    momentum_tail: doc.bool_or("method", "momentum_tail", true)?,
                    amsgrad: doc.bool_or("method", "amsgrad", false)?,
                    use_kernel: rc.use_kernel,
                    kernel_min_elems: doc.u64_or("method", "kernel_min_elems", 1 << 17)? as usize,
                })
            }
            "ft" | "adam" => MethodSpec::FullAdam,
            "badam" => MethodSpec::BAdam { t_inner },
            "lisa" => MethodSpec::Lisa { t_inner },
            "lora" => MethodSpec::Lora { rank, alpha },
            "dora" => MethodSpec::Dora { rank, alpha },
            "galore" => MethodSpec::Galore {
                rank,
                update_freq: doc.u64_or("method", "update_freq", 200)?,
                scale: doc.f64_or("method", "scale", 0.25)? as f32,
            },
            "lora_misa" => MethodSpec::LoraMisa { rank, alpha, delta, eta, t_inner },
            other => bail!("unknown method {other:?}"),
        };
        Ok(rc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# quickstart run
[run]
model = "small"
lr = 0.001
steps = 100
pretrain = false
data = "math"

[method]
name = "misa"
delta = 0.05
eta = 0.5
t_inner = 25
"#;

    #[test]
    fn parses_sample() {
        let doc = Doc::parse(SAMPLE).unwrap();
        let rc = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(rc.model, "small");
        assert_eq!(rc.steps, 100);
        assert_eq!(rc.data, DataSpec::Math);
        match rc.method {
            MethodSpec::Misa(c) => {
                assert!((c.sampler.delta - 0.05).abs() < 1e-12);
                assert_eq!(c.t_inner, 25);
                match c.sampler.strategy {
                    Strategy::Importance { eta } => assert!((eta - 0.5).abs() < 1e-12),
                    _ => panic!("wrong strategy"),
                }
            }
            _ => panic!("wrong method"),
        }
    }

    #[test]
    fn strings_and_comments() {
        let doc = Doc::parse("[a]\nx = \"hi there\" # trailing\n").unwrap();
        assert_eq!(doc.get("a", "x"), Some("hi there"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("not a kv line").is_err());
        assert!(Doc::parse("[unclosed\n").is_err());
    }

    #[test]
    fn unknown_method_is_error() {
        let doc = Doc::parse("[method]\nname = \"sgd\"\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn every_method_parses() {
        for m in ["misa", "ft", "badam", "lisa", "lora", "dora", "galore", "lora_misa"] {
            let text = format!("[method]\nname = \"{m}\"\n");
            let doc = Doc::parse(&text).unwrap();
            assert!(RunConfig::from_doc(&doc).is_ok(), "{m}");
        }
    }
}
