//! Analytical peak-memory model — paper Appendix E, implemented verbatim.
//!
//! All closed forms below are in **f32 element counts** (multiply by 4
//! for bytes); `h` = hidden dim, `L` = layers, `a` = heads, `s` = seq
//! len, `b` = batch, `r` = adapter/projection rank, `v` = vocab.
//!
//! The paper's analysis (Tables 13-16, Eq. 14, Lemmas 4-6) covers the
//! transformer trunk with frozen embedding/LM-head. For the evaluation
//! tables we additionally account for the embedding/head parameters and
//! the logits activation (`extras`), and for LISA the embed+head
//! optimizer states — that surcharge is exactly why LISA's measured
//! memory exceeds BAdam's in paper Tables 1/3/5.
//!
//! This model regenerates Fig. 2, Fig. 5 and every "Mem.(GB)" column at
//! the paper's own architecture constants — no GPU required (the paper's
//! appendix is itself analytical).

pub mod allocator;

pub use allocator::{Allocator, Category};

/// Architecture constants (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct Arch {
    /// hidden dim h
    pub h: u64,
    /// transformer layers L
    pub l: u64,
    /// attention heads a
    pub a: u64,
    /// vocabulary size v
    pub v: u64,
}

impl Arch {
    /// LLaMA3-8B trunk constants used throughout the paper's Sec. 3.5.
    pub fn llama3_8b() -> Self {
        Arch { h: 4096, l: 32, a: 32, v: 128_256 }
    }

    /// LLaMA3-70B (Fig. 5).
    pub fn llama3_70b() -> Self {
        Arch { h: 8192, l: 80, a: 64, v: 128_256 }
    }

    /// Qwen2.5-7B-shaped trunk (Table 3).
    pub fn qwen25_7b() -> Self {
        Arch { h: 3584, l: 28, a: 28, v: 152_064 }
    }

    /// LLaMA2-7B (Table 5).
    pub fn llama2_7b() -> Self {
        Arch { h: 4096, l: 32, a: 32, v: 32_000 }
    }

    /// TinyLLaMA-1.1B (Table 5).
    pub fn tinyllama() -> Self {
        Arch { h: 2048, l: 22, a: 32, v: 32_000 }
    }

    /// Mistral-7B (Table 5 / Fig. 3).
    pub fn mistral_7b() -> Self {
        Arch { h: 4096, l: 32, a: 32, v: 32_000 }
    }

    /// LLaMA2-130M pre-training variant (Table 6).
    pub fn llama_130m() -> Self {
        Arch { h: 768, l: 12, a: 12, v: 32_000 }
    }

    /// LLaMA2-350M pre-training variant (Table 6).
    pub fn llama_350m() -> Self {
        Arch { h: 1024, l: 24, a: 16, v: 32_000 }
    }
}

/// Training workload shape.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub b: u64,
    pub s: u64,
    /// flash-attention: the `a·b·s²` score tensor is never materialized
    /// (Appendix B.1 / Fig. 5c)
    pub flash: bool,
}

impl Workload {
    pub fn new(b: u64, s: u64) -> Self {
        Workload { b, s, flash: false }
    }

    pub fn flash(b: u64, s: u64) -> Self {
        Workload { b, s, flash: true }
    }

    /// The attention-score activation term: a·b·s² (0 with flash-attn).
    fn score(&self, arch: &Arch) -> u64 {
        if self.flash {
            0
        } else {
            arch.a * self.b * self.s * self.s
        }
    }
}

/// bytes per f32
pub const F32: u64 = 4;

// ---------------------------------------------------------------------------
// Appendix E.1: layer-wise method
// ---------------------------------------------------------------------------

/// Activation memory of a frozen transformer layer: a·b·s² + 8bsh.
pub fn act_frozen_layer(arch: &Arch, w: &Workload) -> u64 {
    w.score(arch) + 8 * w.b * w.s * arch.h
}

/// Activation memory of an activated layer: a·b·s² + 15bsh.
pub fn act_active_layer(arch: &Arch, w: &Workload) -> u64 {
    w.score(arch) + 15 * w.b * w.s * arch.h
}

/// Transformer-trunk parameter memory: 12 h² L.
pub fn trunk_params(arch: &Arch) -> u64 {
    12 * arch.h * arch.h * arch.l
}

/// Peak memory of the layer-wise method (BAdam/LISA-style single active
/// layer): L(abs² + 8bsh) + 7bsh + 12h²L + 36h².
pub fn layerwise_peak(arch: &Arch, w: &Workload) -> u64 {
    arch.l * act_frozen_layer(arch, w) + 7 * w.b * w.s * arch.h + trunk_params(arch)
        + 36 * arch.h * arch.h
}

// ---------------------------------------------------------------------------
// Appendix E.2: module-wise BCD (Table 15/16) and LoRA
// ---------------------------------------------------------------------------

/// The activation/optimizer surcharges of activating one module kind
/// (paper Table 15). Returns (extra_activation, extra_opt_and_grad).
pub fn module_surcharge(kind: ModuleClass, arch: &Arch, w: &Workload) -> (u64, u64) {
    let bsh = w.b * w.s * arch.h;
    let h2 = arch.h * arch.h;
    match kind {
        ModuleClass::Attn => (bsh, 3 * h2),      // W_Q/W_K/W_V/W_O
        ModuleClass::FfnIn => (bsh, 12 * h2),    // W_1 (gate/up)
        ModuleClass::FfnOut => (4 * bsh, 12 * h2), // W_2 (down)
    }
}

/// Coarse module classes of the paper's 6-module standard-transformer
/// memory analysis (W_1 = h×4h FFN in, W_2 = 4h×h FFN out).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModuleClass {
    Attn,
    FfnIn,
    FfnOut,
}

/// Peak memory of module-wise BCD with a single active module (Table 16,
/// "Modulewise-BCD" column).
pub fn modulewise_peak(kind: ModuleClass, arch: &Arch, w: &Workload) -> u64 {
    let (act, opt) = module_surcharge(kind, arch, w);
    arch.l * act_frozen_layer(arch, w) + trunk_params(arch) + act + opt
}

/// Peak memory of LoRA targeting all modules (Table 16 last row):
/// L(abs² + 15bsh + 12h² + 72hr).
pub fn lora_peak_all(arch: &Arch, w: &Workload, r: u64) -> u64 {
    arch.l * (w.score(arch) + 15 * w.b * w.s * arch.h + 12 * arch.h * arch.h + 72 * arch.h * r)
}

/// Peak memory of GaLore on all modules (Table 16 last row):
/// L(abs² + 15bsh + 12h² + 42hr).
pub fn galore_peak_all(arch: &Arch, w: &Workload, r: u64) -> u64 {
    arch.l * (w.score(arch) + 15 * w.b * w.s * arch.h + 12 * arch.h * arch.h + 42 * arch.h * r)
}

// ---------------------------------------------------------------------------
// Appendix E.4: MISA peak memory (Eq. 14)
// ---------------------------------------------------------------------------

/// Peak memory of MISA at trainable-parameter ratio δ (Eq. 14):
/// L(abs² + 8bsh + 12h² + 12bshδ + 36h²δ).
pub fn misa_peak(arch: &Arch, w: &Workload, delta: f64) -> u64 {
    let bsh = (w.b * w.s * arch.h) as f64;
    let h2 = (arch.h * arch.h) as f64;
    let per_layer = w.score(arch) as f64 + 8.0 * bsh + 12.0 * h2
        + 12.0 * bsh * delta + 36.0 * h2 * delta;
    (arch.l as f64 * per_layer).round() as u64
}

/// Full fine-tuning with Adam: every layer active, grads + 2 moment
/// buffers for the whole trunk.
pub fn full_ft_peak(arch: &Arch, w: &Workload) -> u64 {
    arch.l * act_active_layer(arch, w) + 4 * trunk_params(arch)
}

// ---------------------------------------------------------------------------
// Evaluation-table extras (embedding/head/logits), Sec. 5 realism
// ---------------------------------------------------------------------------

/// Embedding + LM-head parameters: 2·v·h (always resident).
pub fn embed_head_params(arch: &Arch) -> u64 {
    2 * arch.v * arch.h
}

/// Logits + embedding activations: b·s·v + b·s·h.
pub fn embed_head_acts(arch: &Arch, w: &Workload) -> u64 {
    w.b * w.s * arch.v + w.b * w.s * arch.h
}

/// LISA's surcharge: it *trains* embedding + head, so grad + Adam m/v
/// for 2vh parameters (the reason its Mem column exceeds BAdam's).
pub fn lisa_embed_head_opt(arch: &Arch) -> u64 {
    3 * embed_head_params(arch)
}

/// Methods of the evaluation tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    FullFT,
    Lora { r: u64 },
    /// DoRA ≈ LoRA + magnitude path: extra normalized-weight activations
    /// (paper Sec. 5.1: "DoRA's additional memory … arises from
    /// activations"). The 4bsh/layer surcharge is calibrated to the
    /// paper's measured gap; see EXPERIMENTS.md.
    Dora { r: u64 },
    Lisa,
    BAdam,
    Galore { r: u64 },
    Misa { delta: f64 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::FullFT => "FT".into(),
            Method::Lora { r } => format!("LoRA(r={r})"),
            Method::Dora { r } => format!("DoRA(r={r})"),
            Method::Lisa => "LISA".into(),
            Method::BAdam => "BAdam".into(),
            Method::Galore { r } => format!("GaLore(r={r})"),
            Method::Misa { delta } => format!("MISA(d={:.0}%)", delta * 100.0),
        }
    }
}

/// Peak memory (bytes) of a method on the evaluation workload, including
/// the embed/head extras. This produces the "Mem.(GB)" columns.
pub fn table_peak_bytes(method: Method, arch: &Arch, w: &Workload) -> u64 {
    let trunk = match method {
        Method::FullFT => full_ft_peak(arch, w),
        Method::Lora { r } => lora_peak_all(arch, w, r),
        Method::Dora { r } => {
            lora_peak_all(arch, w, r) + arch.l * 4 * w.b * w.s * arch.h
        }
        Method::Lisa | Method::BAdam => layerwise_peak(arch, w),
        Method::Galore { r } => galore_peak_all(arch, w, r),
        Method::Misa { delta } => misa_peak(arch, w, delta),
    };
    let mut elems = trunk + embed_head_params(arch) + embed_head_acts(arch, w);
    if method == Method::Lisa {
        elems += lisa_embed_head_opt(arch);
    }
    elems * F32
}

/// Peak memory in GiB — the tables' unit.
pub fn table_peak_gib(method: Method, arch: &Arch, w: &Workload) -> f64 {
    table_peak_bytes(method, arch, w) as f64 / (1u64 << 30) as f64
}

// ---------------------------------------------------------------------------
// Lemmas 4-6 (verified by property tests below)
// ---------------------------------------------------------------------------

/// Lemma 4 threshold: MISA beats the layer-wise method whenever
/// δ < (7bs + 36h) / (12bsL + 36hL).
pub fn lemma4_delta_threshold(arch: &Arch, w: &Workload) -> f64 {
    let (b, s, h, l) = (w.b as f64, w.s as f64, arch.h as f64, arch.l as f64);
    (7.0 * b * s + 36.0 * h) / (12.0 * b * s * l + 36.0 * h * l)
}

/// Lemma 5 threshold: the layer-wise method beats LoRA/GaLore whenever
/// s > (36h − 42rL) / (7bL − 7b).
pub fn lemma5_seq_threshold(arch: &Arch, b: u64, r: u64) -> f64 {
    let (b, h, l, r) = (b as f64, arch.h as f64, arch.l as f64, r as f64);
    (36.0 * h - 42.0 * r * l) / (7.0 * b * l - 7.0 * b)
}

/// Lemma 6 premise: layer-wise updates more params per unit peak memory
/// than LoRA when h > 3rL/2.
pub fn lemma6_holds(arch: &Arch, r: u64) -> bool {
    (arch.h as f64) > 1.5 * (r as f64) * (arch.l as f64)
}

/// Params-per-peak-memory ratio of the layer-wise method (Lemma 6 LHS).
pub fn layerwise_params_per_mem(arch: &Arch, w: &Workload) -> f64 {
    (12 * arch.h * arch.h) as f64 / layerwise_peak(arch, w) as f64
}

/// Params-per-peak-memory ratio of LoRA-all (Lemma 6 RHS), counting the
/// 18hrL trainable adapter params of the paper's proof.
pub fn lora_params_per_mem(arch: &Arch, w: &Workload, r: u64) -> f64 {
    (18 * arch.h * r * arch.l) as f64 / lora_peak_all(arch, w, r) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_arch(rng: &mut crate::util::Rng) -> Arch {
        let a = 1 << rng.range(2, 6);
        Arch {
            h: a * (1 << rng.range(4, 8)),
            l: rng.range(2, 48) as u64,
            a,
            v: 1000 * rng.range(1, 150) as u64,
        }
    }

    fn rand_workload(rng: &mut crate::util::Rng) -> Workload {
        Workload { b: rng.range(1, 33) as u64, s: 1 << rng.range(5, 13), flash: rng.f64() < 0.3 }
    }

    #[test]
    fn active_layer_costs_more_than_frozen() {
        crate::prop!("act", |rng| {
            let arch = rand_arch(rng);
            let w = rand_workload(rng);
            assert!(act_active_layer(&arch, &w) > act_frozen_layer(&arch, &w));
            // the delta is exactly 7bsh (paper Table 14)
            assert_eq!(
                act_active_layer(&arch, &w) - act_frozen_layer(&arch, &w),
                7 * w.b * w.s * arch.h
            );
        });
    }

    #[test]
    fn misa_at_full_delta_matches_all_modules_active() {
        // δ = 1 activates everything: Eq.14 becomes L(abs²+20bsh+48h²)
        let arch = Arch::llama3_8b();
        let w = Workload::new(4, 512);
        let m = misa_peak(&arch, &w, 1.0);
        let expect = arch.l * (w.score(&arch) + 20 * w.b * w.s * arch.h + 48 * arch.h * arch.h);
        assert_eq!(m, expect);
    }

    #[test]
    fn lemma4_misa_beats_layerwise_below_threshold() {
        crate::prop!("lemma4", |rng| {
            let arch = rand_arch(rng);
            let w = rand_workload(rng);
            let thr = lemma4_delta_threshold(&arch, &w);
            let delta = thr * rng.f64(); // strictly below threshold
            assert!(
                misa_peak(&arch, &w, delta) < layerwise_peak(&arch, &w),
                "delta {delta} thr {thr}"
            );
            // NOTE (paper discrepancy): Appendix E.6 remarks "when
            // δ < 1/L, memory of MISA is always smaller" — but by the
            // paper's own Eq. 14 vs layer-wise formula the true threshold
            // is (7bs+36h)/(12bsL+36hL) < 1/L (at δ=1/L MISA pays
            // 12bsh+36h² vs layer-wise 7bsh+36h²). We verify the Lemma 4
            // threshold, which is the binding one.
            assert!(lemma4_delta_threshold(&arch, &w) < 1.0 / arch.l as f64);
        });
    }

    #[test]
    fn lemma5_layerwise_beats_lora_for_long_sequences() {
        crate::prop!("lemma5", |rng| {
            let arch = rand_arch(rng);
            let b = rng.range(1, 17) as u64;
            let r = [8u64, 16, 32][rng.below(3)];
            let thr = lemma5_seq_threshold(&arch, b, r);
            let s = (thr.max(0.0) as u64 + 1 + rng.range(0, 4096) as u64).max(8);
            let w = Workload::new(b, s);
            assert!(
                layerwise_peak(&arch, &w) < lora_peak_all(&arch, &w, r),
                "s={s} thr={thr}"
            );
            assert!(layerwise_peak(&arch, &w) < galore_peak_all(&arch, &w, r));
        });
    }

    #[test]
    fn lemma6_layerwise_updates_more_params_per_byte() {
        crate::prop!("lemma6", |rng| {
            let arch = rand_arch(rng);
            let w = rand_workload(rng);
            let r = [8u64, 16, 32][rng.below(3)];
            if lemma6_holds(&arch, r) {
                assert!(
                    layerwise_params_per_mem(&arch, &w)
                        > lora_params_per_mem(&arch, &w, r),
                    "arch {arch:?} r {r}"
                );
            }
        });
    }

    #[test]
    fn fig2_crossover_misa_beats_lora_at_long_seq() {
        // Fig. 2's qualitative claim: at LLaMA3-8B scale MISA(δ small)
        // wins over LoRA once the sequence gets long enough.
        let arch = Arch::llama3_8b();
        for &delta in &[0.01, 0.03] {
            let short = Workload::new(4, 128);
            let long = Workload::new(4, 8192);
            let lora_short = lora_peak_all(&arch, &short, 16);
            let misa_short = misa_peak(&arch, &short, delta);
            let lora_long = lora_peak_all(&arch, &long, 16);
            let misa_long = misa_peak(&arch, &long, delta);
            // long-sequence regime must favour MISA
            assert!(misa_long < lora_long, "delta {delta}");
            // and the gap grows with s
            let gap_long = lora_long as f64 / misa_long as f64;
            let gap_short = lora_short as f64 / misa_short as f64;
            assert!(gap_long > gap_short);
        }
    }

    #[test]
    fn table1_memory_ordering_matches_paper() {
        // Paper Table 1 (LLaMA3-8B): FT >> LISA > DoRA > LoRA > BAdam ≈
        // MISA(3%) > MISA(1%).
        let arch = Arch::llama3_8b();
        let w = Workload::new(4, 512);
        let gb = |m| table_peak_gib(m, &arch, &w);
        let ft = gb(Method::FullFT);
        let lora = gb(Method::Lora { r: 32 });
        let dora = gb(Method::Dora { r: 16 });
        let lisa = gb(Method::Lisa);
        let badam = gb(Method::BAdam);
        let misa1 = gb(Method::Misa { delta: 0.01 });
        let misa3 = gb(Method::Misa { delta: 0.03 });
        assert!(ft > lisa && ft > dora && ft > lora, "FT={ft:.1}");
        assert!(lisa > badam, "LISA={lisa:.1} BAdam={badam:.1}");
        assert!(dora > lora, "DoRA={dora:.1} LoRA={lora:.1}");
        assert!(misa1 < misa3, "MISA1={misa1:.1} MISA3={misa3:.1}");
        assert!(misa1 < badam && misa1 < lora);
        assert!(misa3 < lisa && misa3 < dora);
    }

    #[test]
    fn flash_attention_removes_score_term() {
        let arch = Arch::llama3_70b();
        let dense = Workload::new(4, 4096);
        let flash = Workload::flash(4, 4096);
        let d = layerwise_peak(&arch, &dense);
        let f = layerwise_peak(&arch, &flash);
        assert_eq!(d - f, arch.l * arch.a * dense.b * dense.s * dense.s);
    }

    #[test]
    fn modulewise_cheaper_than_layerwise() {
        // Table 15/16: a single active module costs less than a full
        // active layer for every module class.
        crate::prop!("module_vs_layer", |rng| {
            let arch = rand_arch(rng);
            let w = rand_workload(rng);
            for kind in [ModuleClass::Attn, ModuleClass::FfnIn, ModuleClass::FfnOut] {
                assert!(modulewise_peak(kind, &arch, &w) < layerwise_peak(&arch, &w));
            }
        });
    }
}
