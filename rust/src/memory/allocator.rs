//! Simulated device allocator.
//!
//! The CPU testbed cannot reproduce GPU residency, so the trainer charges
//! a simulated allocator with exactly the buffers the method would hold
//! on a real device (params, grads, optimizer states, activations,
//! adapters). Its peak-byte ledger is the runtime counterpart of the
//! closed forms in [`super`] — experiments report both so the analytical
//! model is continuously cross-checked against the allocation pattern
//! the coordinator actually performs.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Allocation category (ledger row).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    Params,
    Grads,
    OptimStates,
    Activations,
    Adapters,
    Indicators,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Params,
        Category::Grads,
        Category::OptimStates,
        Category::Activations,
        Category::Adapters,
        Category::Indicators,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            Category::Params => "params",
            Category::Grads => "grads",
            Category::OptimStates => "optim_states",
            Category::Activations => "activations",
            Category::Adapters => "adapters",
            Category::Indicators => "indicators",
        }
    }
}

/// One live allocation.
#[derive(Clone, Debug)]
struct Allocation {
    category: Category,
    bytes: u64,
}

/// Simulated allocator with per-category and total peak tracking.
#[derive(Clone, Debug, Default)]
pub struct Allocator {
    live: HashMap<u64, Allocation>,
    next_id: u64,
    current: u64,
    peak: u64,
    per_cat: HashMap<Category, u64>,
    per_cat_peak: HashMap<Category, u64>,
}

impl Allocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `bytes` in `category`; returns a handle for `free`.
    pub fn alloc(&mut self, category: Category, bytes: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.live.insert(id, Allocation { category, bytes });
        self.current += bytes;
        let c = self.per_cat.entry(category).or_insert(0);
        *c += bytes;
        let cp = self.per_cat_peak.entry(category).or_insert(0);
        *cp = (*cp).max(*c);
        self.peak = self.peak.max(self.current);
        id
    }

    /// Free a handle. Double-free or unknown handles are hard errors —
    /// the trainer's accounting must be exact.
    pub fn free(&mut self, id: u64) -> Result<()> {
        match self.live.remove(&id) {
            Some(a) => {
                self.current -= a.bytes;
                *self.per_cat.get_mut(&a.category).unwrap() -= a.bytes;
                Ok(())
            }
            None => bail!("free of unknown/double-freed allocation {id}"),
        }
    }

    /// Free every live allocation in a category (e.g. Activations at
    /// step end, OptimStates at MISA's block switch — Alg. 1 line 17).
    pub fn free_category(&mut self, category: Category) {
        let ids: Vec<u64> = self
            .live
            .iter()
            .filter(|(_, a)| a.category == category)
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            let _ = self.free(id);
        }
    }

    pub fn current_bytes(&self) -> u64 {
        self.current
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak
    }

    pub fn category_bytes(&self, category: Category) -> u64 {
        self.per_cat.get(&category).copied().unwrap_or(0)
    }

    pub fn category_peak(&self, category: Category) -> u64 {
        self.per_cat_peak.get(&category).copied().unwrap_or(0)
    }

    /// Human-readable ledger summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "current={:.3} GiB peak={:.3} GiB\n",
            crate::util::gib(self.current),
            crate::util::gib(self.peak)
        );
        for c in Category::ALL {
            s.push_str(&format!(
                "  {:<12} cur={:.3} GiB peak={:.3} GiB\n",
                c.as_str(),
                crate::util::gib(self.category_bytes(c)),
                crate::util::gib(self.category_peak(c)),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_current_and_peak() {
        let mut a = Allocator::new();
        let x = a.alloc(Category::Params, 100);
        let y = a.alloc(Category::Grads, 50);
        assert_eq!(a.current_bytes(), 150);
        assert_eq!(a.peak_bytes(), 150);
        a.free(y).unwrap();
        assert_eq!(a.current_bytes(), 100);
        assert_eq!(a.peak_bytes(), 150);
        a.free(x).unwrap();
        assert_eq!(a.current_bytes(), 0);
    }

    #[test]
    fn double_free_is_error() {
        let mut a = Allocator::new();
        let x = a.alloc(Category::Params, 10);
        a.free(x).unwrap();
        assert!(a.free(x).is_err());
    }

    #[test]
    fn free_category_clears_only_that_category() {
        let mut a = Allocator::new();
        a.alloc(Category::OptimStates, 30);
        a.alloc(Category::OptimStates, 20);
        let p = a.alloc(Category::Params, 70);
        a.free_category(Category::OptimStates);
        assert_eq!(a.category_bytes(Category::OptimStates), 0);
        assert_eq!(a.current_bytes(), 70);
        a.free(p).unwrap();
    }

    #[test]
    fn invariants_under_random_workload() {
        crate::prop!("allocator", |rng| {
            let mut a = Allocator::new();
            let mut live: Vec<u64> = Vec::new();
            let mut expect: u64 = 0;
            let mut expect_peak: u64 = 0;
            for _ in 0..rng.range(1, 200) {
                if live.is_empty() || rng.f64() < 0.6 {
                    let bytes = rng.range(1, 10_000) as u64;
                    let cat = Category::ALL[rng.below(6)];
                    live.push(a.alloc(cat, bytes));
                    expect += bytes;
                    expect_peak = expect_peak.max(expect);
                } else {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    let before = a.current_bytes();
                    a.free(id).unwrap();
                    expect -= before - a.current_bytes();
                }
                assert_eq!(a.current_bytes(), expect);
                assert!(a.peak_bytes() >= a.current_bytes());
                assert_eq!(a.peak_bytes(), expect_peak);
                let cat_sum: u64 = Category::ALL
                    .iter()
                    .map(|&c| a.category_bytes(c))
                    .sum();
                assert_eq!(cat_sum, a.current_bytes());
            }
        });
    }
}
