//! `misa` — the launcher CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   train [--config run.toml] [--model M] [--method NAME] [--steps N] …
//!   exp <name|all|list> [--full]       regenerate paper tables/figures
//!   info                               registry + memory-model summary
//!
//! Every subcommand takes `--backend host|pjrt` (default: host — the
//! pure-Rust backend that needs no artifacts). `--host` is kept as the
//! legacy switch for "host Adam loops instead of fused kernels".
//!
//! Hand-rolled flag parsing — clap is not vendorable offline. Unknown
//! flags and valued flags missing their value are hard errors.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use misa::config::{DataSpec, Doc, RunConfig};
use misa::coordinator::experiments::{self, ExpCtx};
use misa::coordinator::Trainer;
use misa::memory::{self, Arch, Method, Workload};
use misa::runtime::{BackendKind, Engine};

fn usage() -> ! {
    eprintln!(
        "misa — Module-wise Importance Sampling (paper reproduction)\n\n\
         USAGE:\n  misa train [--config FILE] [--model M] [--method NAME] [--steps N]\n\
         \x20           [--lr F] [--delta F] [--eta F] [--t-inner N] [--data D]\n\
         \x20           [--pretrain] [--seed N] [--out DIR] [--artifacts DIR]\n\
         \x20           [--backend host|pjrt] [--host]\n\
         \x20 misa exp <name|all|list> [--full] [--artifacts DIR] [--backend B]\n\
         \x20 misa info [--artifacts DIR] [--backend B]\n"
    );
    std::process::exit(2)
}

/// Flags that take a value. Anything else starting with `--` must be a
/// known switch — unknown flags are errors, not silent switches.
const VALUED_FLAGS: &[&str] = &[
    "config", "model", "method", "steps", "lr", "delta", "eta", "t-inner", "rank", "alpha",
    "data", "seed", "out", "artifacts", "backend",
];

/// Boolean switches.
const SWITCHES: &[&str] = &["pretrain", "full", "host"];

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut a = Args {
        positional: Vec::new(),
        flags: HashMap::new(),
        switches: HashSet::new(),
    };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if let Some(name) = arg.strip_prefix("--") {
            if SWITCHES.contains(&name) {
                a.switches.insert(name.to_string());
            } else if VALUED_FLAGS.contains(&name) {
                let val = argv
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .ok_or_else(|| anyhow!("flag --{name} requires a value"))?;
                a.flags.insert(name.to_string(), val.clone());
                i += 1;
            } else {
                bail!("unknown flag --{name}");
            }
        } else {
            a.positional.push(arg.clone());
        }
        i += 1;
    }
    Ok(a)
}

fn artifact_dir(args: &Args) -> PathBuf {
    args.flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    match args.flags.get("backend") {
        Some(b) => BackendKind::parse(b),
        None => Ok(BackendKind::Host),
    }
}

fn make_engine(args: &Args) -> Result<Engine> {
    Engine::with_backend(&artifact_dir(args), backend_kind(args)?)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut rc = if let Some(path) = args.flags.get("config") {
        RunConfig::from_doc(&Doc::load(Path::new(path))?)?
    } else {
        RunConfig::default()
    };
    if let Some(m) = args.flags.get("model") {
        rc.model = m.clone();
    }
    if let Some(s) = args.flags.get("steps") {
        rc.steps = s.parse().context("--steps")?;
    }
    if let Some(l) = args.flags.get("lr") {
        rc.lr = l.parse().context("--lr")?;
    }
    if let Some(s) = args.flags.get("seed") {
        rc.seed = s.parse().context("--seed")?;
    }
    if let Some(d) = args.flags.get("data") {
        rc.data = match d.as_str() {
            "lm" => DataSpec::Lm,
            "commonsense" => DataSpec::Commonsense,
            "math" => DataSpec::Math,
            "instruction" => DataSpec::Instruction,
            other => bail!("unknown data {other:?}"),
        };
    }
    if args.switches.contains("pretrain") {
        rc.pretrain = true;
    }
    if args.switches.contains("host") {
        rc.use_kernel = false;
    }
    rc.out_dir = args.flags.get("out").cloned();
    if let Some(name) = args.flags.get("method") {
        let mut doc = format!("[method]\nname = \"{name}\"\n");
        for key in ["delta", "eta", "t-inner", "rank", "alpha"] {
            if let Some(v) = args.flags.get(key) {
                doc.push_str(&format!("{} = {v}\n", key.replace('-', "_")));
            }
        }
        let parsed = RunConfig::from_doc(&Doc::parse(&format!(
            "[run]\npretrain = {}\n{doc}",
            rc.pretrain
        ))?)?;
        rc.method = parsed.method;
    }
    let mut engine = make_engine(args)?;
    println!(
        "run: model={} method={} data={:?} steps={} lr={} backend={}",
        rc.model,
        rc.method.label(),
        rc.data,
        rc.steps,
        rc.lr,
        engine.backend_name()
    );
    let mut t = Trainer::new(&mut engine, rc.clone())?;
    let eval_every = rc.eval_every.max(1);
    let mut remaining = rc.steps;
    while remaining > 0 {
        let chunk = eval_every.min(remaining);
        t.run(chunk)?;
        let e = t.evaluate(rc.eval_batches)?;
        println!(
            "step {:>6}  train_loss {:>8.4}  val_loss {:>8.4}  ppl {:>9.3}  acc {:>5.1}%  sim-peak {:>7.3} GiB",
            t.step_no(),
            t.metrics.last("train_loss").unwrap_or(f64::NAN),
            e.loss,
            e.ppl,
            e.accuracy * 100.0,
            misa::util::gib(t.alloc.peak_bytes()),
        );
        remaining -= chunk;
    }
    let (fb, op) = t.avg_times_ms();
    println!("avg per-step: fwd+bwd {fb:.1} ms, optimizer {op:.1} ms");
    t.metrics.flush();
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
    if name == "list" {
        println!("available experiments:");
        for (n, _, desc) in experiments::registry() {
            println!("  {n:<10} {desc}");
        }
        return Ok(());
    }
    let mut engine = make_engine(args)?;
    let fast = !args.switches.contains("full");
    let mut ctx = ExpCtx::new(&mut engine, fast);
    if name == "all" {
        for (n, f, _) in experiments::registry() {
            let t0 = std::time::Instant::now();
            match f(&mut ctx) {
                Ok(body) => {
                    println!("=== {n} ({:.1}s) ===\n{body}", t0.elapsed().as_secs_f64());
                }
                Err(e) => println!("=== {n} FAILED: {e:#} ==="),
            }
        }
    } else {
        let body = experiments::run(&mut ctx, name)?;
        println!("{body}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = make_engine(args)?;
    println!("backend: {}", engine.backend_name());
    println!("registry: {}", engine.manifest.dir.display());
    println!("configs:");
    for m in &engine.manifest.models {
        let c = &m.config;
        println!(
            "  {:<7} vocab={:<6} dim={:<5} layers={:<3} heads={}/{} ffn={:<5} b×s={}×{}  params={:.2}M  modules={}",
            c.name, c.vocab, c.dim, c.n_layers, c.n_heads, c.n_kv_heads, c.ffn_dim,
            c.batch, c.seq_len,
            m.total_params() as f64 / 1e6,
            m.matrix_module_indices().len(),
        );
    }
    // paper-scale memory summary (Table 1 Mem column)
    let arch = Arch::llama3_8b();
    let w = Workload::new(4, 512);
    println!("\nAppendix-E peak memory @ LLaMA3-8B, b=4, s=512:");
    for m in [
        Method::FullFT,
        Method::Lora { r: 32 },
        Method::Dora { r: 16 },
        Method::Lisa,
        Method::BAdam,
        Method::Misa { delta: 0.01 },
        Method::Misa { delta: 0.03 },
    ] {
        println!("  {:<14} {:>7.1} GB", m.label(), memory::table_peak_gib(m, &arch, &w));
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n");
            usage();
        }
    };
    let result = match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("exp") => cmd_exp(&args),
        Some("info") => cmd_info(&args),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positionals_flags_and_switches() {
        let a = parse_args(&v(&[
            "train", "--model", "tiny", "--steps", "20", "--pretrain", "--backend", "host",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.flags.get("model").unwrap(), "tiny");
        assert_eq!(a.flags.get("steps").unwrap(), "20");
        assert_eq!(a.flags.get("backend").unwrap(), "host");
        assert!(a.switches.contains("pretrain"));
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Host);
    }

    #[test]
    fn unknown_flag_is_an_error() {
        let err = parse_args(&v(&["train", "--bogus"])).unwrap_err();
        assert!(err.to_string().contains("--bogus"), "{err}");
        // previously silently absorbed as a switch
        assert!(parse_args(&v(&["train", "--bogus", "3"])).is_err());
    }

    #[test]
    fn valued_flag_missing_value_is_an_error() {
        // at end of argv
        let err = parse_args(&v(&["train", "--steps"])).unwrap_err();
        assert!(err.to_string().contains("--steps"), "{err}");
        // followed by another flag
        assert!(parse_args(&v(&["train", "--steps", "--lr", "0.1"])).is_err());
    }

    #[test]
    fn switches_never_consume_values() {
        let a = parse_args(&v(&["train", "--pretrain", "50"])).unwrap();
        assert!(a.switches.contains("pretrain"));
        assert_eq!(a.positional, vec!["train", "50"]);
    }

    #[test]
    fn backend_flag_parses_and_rejects() {
        let a = parse_args(&v(&["info", "--backend", "pjrt"])).unwrap();
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Pjrt);
        let a = parse_args(&v(&["info", "--backend", "tpu"])).unwrap();
        assert!(backend_kind(&a).is_err());
        let a = parse_args(&v(&["info"])).unwrap();
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Host);
    }
}
