//! `misa` — the launcher CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   train [--config run.toml] [--model M] [--method NAME] [--steps N] …
//!   generate --ckpt PATH [--prompt IDS] …   incremental decode from a checkpoint
//!   bench-serve [--requests N] …            continuous-batching throughput bench
//!   exp <name|all|list> [--full]            regenerate paper tables/figures
//!   info                                    registry + memory-model summary
//!
//! Every subcommand takes `--backend host|pjrt` (default: host — the
//! pure-Rust backend that needs no artifacts). `--host` is kept as the
//! legacy switch for "host Adam loops instead of fused kernels".
//!
//! Hand-rolled flag parsing — clap is not vendorable offline. Unknown
//! flags and valued flags missing their value are hard errors.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use misa::config::{DataSpec, Doc, MethodSpec, RunConfig};
use misa::coordinator::experiments::{self, ExpCtx};
use misa::coordinator::{ckpt, Trainer};
use misa::memory::{self, Arch, Method, Workload};
use misa::modelspec::ModelSpec;
use misa::runtime::{BackendKind, Engine, KvCache, Session};
use misa::serve::{
    generate, CacheStoreCfg, GenerateCfg, Request, SamplerCfg, Scheduler, SchedulerCfg,
    SpecCfg,
};
use misa::util::Rng;
use misa::{log_error, log_info};

fn usage() -> ! {
    eprintln!(
        "misa — Module-wise Importance Sampling (paper reproduction)\n\n\
         USAGE:\n  misa train [--config FILE] [--model M] [--method NAME] [--steps N]\n\
         \x20           [--lr F] [--delta F] [--eta F] [--t-inner N] [--data D]\n\
         \x20           [--pretrain] [--seed N] [--out DIR] [--artifacts DIR]\n\
         \x20           [--save-ckpt FILE] [--backend host|pjrt] [--host]\n\
         \x20           [--report-out FILE]  (per-step JSON training report)\n\
         \x20 misa generate --ckpt FILE [--model M] [--prompt \"1,2,3\"] [--max-new N]\n\
         \x20           [--temp F] [--top-k N] [--top-p F] [--eos TOK] [--seed N]\n\
         \x20           [--spec] [--draft-len N] [--spec-ngram N]\n\
         \x20 misa bench-serve [--ckpt FILE] [--model M] [--requests N] [--max-new N]\n\
         \x20           [--prompt-len N] [--shared-prefix N] [--slots N]\n\
         \x20           [--token-budget N] [--prefix-cache] [--prefix-cache-cap N]\n\
         \x20           [--prefix-cache-entries N] [--prefill-chunk N] [--spec]\n\
         \x20           [--draft-len N] [--spec-ngram N] [--temp F] [--top-k N]\n\
         \x20           [--top-p F] [--seed N] [--json FILE]\n\
         \x20 misa bench [--model M] [--steps N] [--seed N] [--json FILE]\n\
         \x20           [--variance-report] [--t-inner N]  (MISA-vs-layerwise\n\
         \x20           gradient-estimator variance on the same norms)\n\
         \x20           [--gemm]  (kernel-level GEMM GFLOP/s sweep by shape)\n\
         \x20 misa fuzz [--target kvcache|trie|scheduler|all] [--ops N] [--seed N]\n\
         \x20           [--spec] [--prefix-cache] [--prefill-chunk N]\n\
         \x20           (seed-replayable differential fuzzer; MISA_FUZZ_SEED /\n\
         \x20           MISA_FUZZ_OPS override; violations print a replay command)\n\
         \x20 misa capacity [--model M] [--slots-list 1,2,4] [--budget-list 4096]\n\
         \x20           [--threads-list 1] [--requests N] [--prompt-len N]\n\
         \x20           [--max-new N] [--holdout] [--seed N] [--json FILE]\n\
         \x20 misa capacity --predict --fit FILE --slots N --token-budget N\n\
         \x20           [--threads N]  (answer sizing queries from a saved fit)\n\
         \x20 misa exp <name|all|list> [--full] [--artifacts DIR] [--backend B]\n\
         \x20 misa info [--artifacts DIR] [--backend B]\n\n\
         Every subcommand also takes --threads N (GEMM worker-pool width;\n\
         default: MISA_THREADS, else 1), --trace-out FILE (record spans and\n\
         write a Chrome trace-event JSON on exit; also MISA_TRACE=1),\n\
         --metrics-out FILE (Prometheus-style metrics dump on exit),\n\
         --profile-out FILE (folded wall-clock stacks from the sampling\n\
         profiler; rate MISA_PROF_HZ, default 97), --roofline-out FILE\n\
         (per-core/per-module GEMM achieved-vs-peak GFLOP/s JSON) and\n\
         --flight-out FILE (flight-recorder ring dumped on exit and on\n\
         panic; also MISA_FLIGHT=1 / MISA_FLIGHT_OUT=FILE).\n\
         MISA_LOG=error|warn|info|debug sets stderr log verbosity;\n\
         MISA_SIMD=0 forces the scalar GEMM microkernel (bit-identical,\n\
         AVX2 is used when detected otherwise).\n"
    );
    std::process::exit(2)
}

/// Flags that take a value. Anything else starting with `--` must be a
/// known switch — unknown flags are errors, not silent switches.
const VALUED_FLAGS: &[&str] = &[
    "config", "model", "method", "steps", "lr", "delta", "eta", "t-inner", "rank", "alpha",
    "data", "seed", "out", "artifacts", "backend", "save-ckpt", "ckpt", "prompt",
    "max-new", "temp", "top-k", "top-p", "eos", "requests", "prompt-len", "shared-prefix",
    "slots", "token-budget", "prefix-cache-cap", "prefix-cache-entries", "prefill-chunk",
    "draft-len", "spec-ngram", "threads", "json", "trace-out", "metrics-out",
    "profile-out", "roofline-out", "flight-out",
    "report-out", "target", "ops", "slots-list", "budget-list", "threads-list", "fit",
];

/// Boolean switches.
const SWITCHES: &[&str] = &[
    "pretrain", "full", "host", "prefix-cache", "spec", "variance-report", "gemm", "predict",
    "holdout",
];

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut a = Args {
        positional: Vec::new(),
        flags: HashMap::new(),
        switches: HashSet::new(),
    };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if let Some(name) = arg.strip_prefix("--") {
            if SWITCHES.contains(&name) {
                a.switches.insert(name.to_string());
            } else if VALUED_FLAGS.contains(&name) {
                let val = argv
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .ok_or_else(|| anyhow!("flag --{name} requires a value"))?;
                a.flags.insert(name.to_string(), val.clone());
                i += 1;
            } else {
                bail!("unknown flag --{name}");
            }
        } else {
            a.positional.push(arg.clone());
        }
        i += 1;
    }
    Ok(a)
}

fn artifact_dir(args: &Args) -> PathBuf {
    args.flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    match args.flags.get("backend") {
        Some(b) => BackendKind::parse(b),
        None => Ok(BackendKind::Host),
    }
}

fn make_engine(args: &Args) -> Result<Engine> {
    Engine::with_backend(&artifact_dir(args), backend_kind(args)?)
}

/// `--threads N` sets the GEMM worker-pool width for the whole process
/// (falls back to `MISA_THREADS`, else 1, when absent).
fn apply_threads(args: &Args) -> Result<()> {
    if let Some(t) = args.flags.get("threads") {
        let n: usize = t.parse().context("--threads")?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        misa::tensor::set_threads(n);
    }
    Ok(())
}

/// Destination files for the run's observability exports, resolved
/// from `--trace-out` / `--metrics-out` / `--profile-out` /
/// `--roofline-out` / `--flight-out` before the subcommand runs.
struct ObsOut {
    trace: Option<PathBuf>,
    metrics: Option<PathBuf>,
    profile: Option<PathBuf>,
    roofline: Option<PathBuf>,
    flight: Option<PathBuf>,
}

/// `--trace-out FILE` switches span recording on for the whole process
/// (same effect as `MISA_TRACE=1`); `--metrics-out FILE` needs no
/// enablement — the metrics registry is always live. `--profile-out` /
/// `--roofline-out` start the sampling profiler at the `MISA_PROF_HZ`
/// rate; `--flight-out` switches the flight recorder on, points the
/// panic hook and the fuzz failure path at FILE, and dumps the ring
/// there at exit. The exports themselves happen in [`finish_obs`]
/// after the subcommand completes.
fn apply_obs(args: &Args) -> Result<ObsOut> {
    let out = ObsOut {
        trace: args.flags.get("trace-out").map(PathBuf::from),
        metrics: args.flags.get("metrics-out").map(PathBuf::from),
        profile: args.flags.get("profile-out").map(PathBuf::from),
        roofline: args.flags.get("roofline-out").map(PathBuf::from),
        flight: args.flags.get("flight-out").map(PathBuf::from),
    };
    if out.trace.is_some() {
        misa::obs::span::enable_tracing();
    }
    if out.profile.is_some() || out.roofline.is_some() {
        misa::obs::profile::start(misa::obs::profile::default_hz())?;
    }
    if let Some(path) = &out.flight {
        misa::obs::flight::enable();
        misa::obs::flight::set_dump_path(path);
        misa::obs::flight::install_panic_hook();
    }
    Ok(out)
}

/// Write the Chrome trace, the Prometheus-style dump, the folded
/// profiler stacks, the roofline JSON, and/or the flight-recorder
/// ring. Runs even when the subcommand failed, so the trace of a
/// failing run survives.
fn finish_obs(out: &ObsOut) -> Result<()> {
    if let Some(path) = &out.trace {
        let n = misa::obs::span::export_chrome_trace(path)?;
        log_info!("trace written: {} ({n} spans)", path.display());
    }
    if let Some(path) = &out.metrics {
        // land the byte-accounting gauges (mem.* + process RSS) in the
        // registry so every dump carries the run's memory picture
        misa::obs::memory::publish();
        std::fs::write(path, misa::obs::metrics::prometheus_dump())
            .with_context(|| format!("writing metrics dump {path:?}"))?;
        log_info!("metrics written: {}", path.display());
    }
    if out.profile.is_some() || out.roofline.is_some() {
        misa::obs::profile::stop();
        let rep = misa::obs::profile::report();
        if let Some(path) = &out.profile {
            std::fs::write(path, rep.folded.render_folded())
                .with_context(|| format!("writing folded stacks {path:?}"))?;
            log_info!(
                "profile written: {} ({} samples, {} stacks, {} torn)",
                path.display(),
                rep.folded.samples,
                rep.folded.distinct(),
                rep.folded.torn,
            );
        }
        if let Some(path) = &out.roofline {
            std::fs::write(path, rep.kernels.render_roofline_json())
                .with_context(|| format!("writing roofline {path:?}"))?;
            log_info!("roofline written: {}", path.display());
        }
    }
    if let Some(path) = &out.flight {
        let n = misa::obs::flight::dump_to(path)?;
        log_info!("flight dump written: {} ({n} events)", path.display());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut rc = if let Some(path) = args.flags.get("config") {
        RunConfig::from_doc(&Doc::load(Path::new(path))?)?
    } else {
        RunConfig::default()
    };
    if let Some(m) = args.flags.get("model") {
        rc.model = m.clone();
    }
    if let Some(s) = args.flags.get("steps") {
        rc.steps = s.parse().context("--steps")?;
    }
    if let Some(l) = args.flags.get("lr") {
        rc.lr = l.parse().context("--lr")?;
    }
    if let Some(s) = args.flags.get("seed") {
        rc.seed = s.parse().context("--seed")?;
    }
    if let Some(d) = args.flags.get("data") {
        rc.data = match d.as_str() {
            "lm" => DataSpec::Lm,
            "commonsense" => DataSpec::Commonsense,
            "math" => DataSpec::Math,
            "instruction" => DataSpec::Instruction,
            other => bail!("unknown data {other:?}"),
        };
    }
    if args.switches.contains("pretrain") {
        rc.pretrain = true;
    }
    if args.switches.contains("host") {
        rc.use_kernel = false;
    }
    rc.out_dir = args.flags.get("out").cloned();
    if let Some(name) = args.flags.get("method") {
        let mut doc = format!("[method]\nname = \"{name}\"\n");
        for key in ["delta", "eta", "t-inner", "rank", "alpha"] {
            if let Some(v) = args.flags.get(key) {
                doc.push_str(&format!("{} = {v}\n", key.replace('-', "_")));
            }
        }
        let parsed = RunConfig::from_doc(&Doc::parse(&format!(
            "[run]\npretrain = {}\n{doc}",
            rc.pretrain
        ))?)?;
        rc.method = parsed.method;
    }
    let mut engine = make_engine(args)?;
    println!(
        "run: model={} method={} data={:?} steps={} lr={} backend={}",
        rc.model,
        rc.method.label(),
        rc.data,
        rc.steps,
        rc.lr,
        engine.backend_name()
    );
    let mut t = Trainer::new(&mut engine, rc.clone())?;
    if args.flags.contains_key("report-out") {
        t.enable_report();
    }
    let eval_every = rc.eval_every.max(1);
    let mut remaining = rc.steps;
    while remaining > 0 {
        let chunk = eval_every.min(remaining);
        t.run(chunk)?;
        let e = t.evaluate(rc.eval_batches)?;
        println!(
            "step {:>6}  train_loss {:>8.4}  val_loss {:>8.4}  ppl {:>9.3}  acc {:>5.1}%  sim-peak {:>7.3} GiB",
            t.step_no(),
            t.metrics.last("train_loss").unwrap_or(f64::NAN),
            e.loss,
            e.ppl,
            e.accuracy * 100.0,
            misa::util::gib(t.alloc.peak_bytes()),
        );
        remaining -= chunk;
    }
    let (fb, op) = t.avg_times_ms();
    println!("avg per-step: fwd+bwd {fb:.1} ms, optimizer {op:.1} ms");
    t.metrics.flush();
    if let Some(path) = args.flags.get("report-out") {
        t.write_report(Path::new(path))?;
        println!("training report written: {path}");
    }
    if let Some(path) = args.flags.get("save-ckpt") {
        ckpt::save(Path::new(path), &t.sess.host)?;
        println!("checkpoint written: {path}");
    }
    Ok(())
}

/// Parse `--prompt "1,2,3"` (comma- and/or whitespace-separated token
/// ids). Defaults to a single BOS token when the flag is absent.
fn parse_prompt(args: &Args) -> Result<Vec<i32>> {
    let Some(raw) = args.flags.get("prompt") else {
        return Ok(vec![misa::data::tok::BOS]);
    };
    let toks: Vec<i32> = raw
        .split(|c: char| c == ',' || c.is_whitespace())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<i32>().with_context(|| format!("--prompt token {s:?}")))
        .collect::<Result<_>>()?;
    if toks.is_empty() {
        bail!("--prompt contains no token ids");
    }
    Ok(toks)
}

/// Resolve the speculative-decoding configuration: `--spec` enables it
/// (with `--draft-len` / `--spec-ngram` overrides); without the switch
/// the `MISA_SPEC` environment default applies (unset = disabled).
/// `--draft-len` / `--spec-ngram` without `--spec` are hard errors —
/// silently measuring the non-speculative baseline would be worse.
fn spec_from(args: &Args) -> Result<Option<SpecCfg>> {
    if !args.switches.contains("spec") {
        for flag in ["draft-len", "spec-ngram"] {
            if args.flags.contains_key(flag) {
                bail!("--{flag} requires --spec");
            }
        }
        return Ok(SpecCfg::from_env());
    }
    let mut cfg = SpecCfg::default();
    if let Some(k) = args.flags.get("draft-len") {
        cfg.draft_len = k.parse().context("--draft-len")?;
    }
    if let Some(n) = args.flags.get("spec-ngram") {
        cfg.ngram = n.parse().context("--spec-ngram")?;
    }
    cfg.validate()?;
    Ok(Some(cfg))
}

fn sampler_from(args: &Args) -> Result<SamplerCfg> {
    let mut cfg = SamplerCfg::greedy();
    if let Some(t) = args.flags.get("temp") {
        cfg.temperature = t.parse().context("--temp")?;
    }
    if let Some(k) = args.flags.get("top-k") {
        cfg.top_k = k.parse().context("--top-k")?;
    }
    if let Some(p) = args.flags.get("top-p") {
        cfg.top_p = p.parse().context("--top-p")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Resolve the model config for a loaded checkpoint: `--model` when
/// given, else inferred by matching parameter shapes against the
/// registry (every builtin config has a distinct registry signature).
fn spec_for_ckpt<'a>(
    engine: &'a Engine,
    args: &Args,
    params: &[Vec<f32>],
) -> Result<&'a ModelSpec> {
    if let Some(name) = args.flags.get("model") {
        return engine.manifest.model(name);
    }
    let matches: Vec<&ModelSpec> = engine
        .manifest
        .models
        .iter()
        .filter(|m| {
            m.params.len() == params.len()
                && m.params.iter().zip(params).all(|(p, d)| p.numel() == d.len())
        })
        .collect();
    match matches.as_slice() {
        [one] => Ok(one),
        [] => bail!(
            "checkpoint matches no registry config ({} params); pass --model",
            params.len()
        ),
        many => bail!(
            "checkpoint shape is ambiguous across configs {:?}; pass --model",
            many.iter().map(|m| m.config.name.as_str()).collect::<Vec<_>>()
        ),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let ckpt_path = args
        .flags
        .get("ckpt")
        .ok_or_else(|| anyhow!("generate requires --ckpt FILE"))?;
    let params = ckpt::load(Path::new(ckpt_path))?;
    let mut engine = make_engine(args)?;
    let spec = spec_for_ckpt(&engine, args, &params)?.clone();
    let sess = Session::with_params(&mut engine, spec, params)?;
    let prompt = parse_prompt(args)?;
    let vocab = sess.spec.config.vocab;
    for &t in &prompt {
        if t < 0 || t as usize >= vocab {
            bail!("prompt token {t} outside vocab {vocab}");
        }
    }
    let cfg = GenerateCfg {
        max_new: match args.flags.get("max-new") {
            Some(n) => n.parse().context("--max-new")?,
            None => 32,
        },
        sampler: sampler_from(args)?,
        seed: match args.flags.get("seed") {
            Some(s) => s.parse().context("--seed")?,
            None => 0,
        },
        eos: match args.flags.get("eos") {
            Some(e) => Some(e.parse().context("--eos")?),
            None => None,
        },
        spec: spec_from(args)?,
    };
    let spec_label = match &cfg.spec {
        Some(s) => format!("on(k={},ngram={})", s.draft_len, s.ngram),
        None => "off".to_string(),
    };
    println!(
        "generate: model={} backend={} ckpt={ckpt_path} prompt_len={} max_new={} \
         temp={} top_k={} top_p={} seed={} spec={spec_label}",
        sess.spec.config.name,
        sess.backend_name(),
        prompt.len(),
        cfg.max_new,
        cfg.sampler.temperature,
        cfg.sampler.top_k,
        cfg.sampler.top_p,
        cfg.seed,
    );
    let g = generate(&sess, &prompt, &cfg)?;
    let rendered: Vec<String> = g.tokens.iter().map(|t| t.to_string()).collect();
    println!("tokens: {}", rendered.join(" "));
    println!(
        "ttft {:.1} ms · decode {:.1} tok/s · {} new tokens",
        g.ttft_s * 1e3,
        g.decode_tps,
        g.tokens.len(),
    );
    if !g.itl_ms.is_empty() {
        let itl = misa::obs::LatencySummary::of(&g.itl_ms);
        println!(
            "itl p50 {:.3} ms · p90 {:.3} ms · p99 {:.3} ms · max {:.3} ms",
            itl.p50, itl.p90, itl.p99, itl.max,
        );
    }
    if let Some(st) = g.spec {
        println!(
            "spec: {} drafted · {} accepted · acceptance {:.2}",
            st.drafted,
            st.accepted,
            st.acceptance_rate(),
        );
    }
    Ok(())
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let mut engine = make_engine(args)?;
    let seed: u64 = match args.flags.get("seed") {
        Some(s) => s.parse().context("--seed")?,
        None => 0,
    };
    let sess = if let Some(path) = args.flags.get("ckpt") {
        let params = ckpt::load(Path::new(path))?;
        let spec = spec_for_ckpt(&engine, args, &params)?.clone();
        Session::with_params(&mut engine, spec, params)?
    } else {
        let model = args.flags.get("model").map(String::as_str).unwrap_or("tiny");
        Session::create(&mut engine, model, seed)?
    };
    let requests: usize = match args.flags.get("requests") {
        Some(n) => n.parse().context("--requests")?,
        None => 16,
    };
    let max_new: usize = match args.flags.get("max-new") {
        Some(n) => n.parse().context("--max-new")?,
        None => 32,
    };
    // prompts always start with BOS, so the effective length is >= 1
    let prompt_len: usize = match args.flags.get("prompt-len") {
        Some(n) => n.parse::<usize>().context("--prompt-len")?.max(1),
        None => 8,
    };
    // --shared-prefix N: a common N-token system prompt (BOS included)
    // shared by every request, ahead of its --prompt-len unique tokens —
    // the workload prefix caching exists for
    let shared_prefix: usize = match args.flags.get("shared-prefix") {
        Some(n) => n.parse().context("--shared-prefix")?,
        None => 0,
    };
    let prefix_cache = if args.switches.contains("prefix-cache") {
        let mut c = CacheStoreCfg::default();
        if let Some(v) = args.flags.get("prefix-cache-cap") {
            c.capacity = v.parse().context("--prefix-cache-cap")?;
        }
        if let Some(v) = args.flags.get("prefix-cache-entries") {
            c.max_entries = v.parse().context("--prefix-cache-entries")?;
        }
        Some(c)
    } else {
        None
    };
    let cfg = SchedulerCfg {
        max_slots: match args.flags.get("slots") {
            Some(n) => n.parse().context("--slots")?,
            None => 4,
        },
        token_budget: match args.flags.get("token-budget") {
            Some(n) => n.parse().context("--token-budget")?,
            None => 4096,
        },
        prefix_cache,
        prefill_chunk: match args.flags.get("prefill-chunk") {
            Some(n) => n.parse().context("--prefill-chunk")?,
            None => 0,
        },
        spec: spec_from(args)?,
    };
    let sampler = sampler_from(args)?;
    let mc = &sess.spec.config;
    // total per-request prompt: the shared block, then the unique tail
    // (--shared-prefix 0 degenerates to the bare BOS head inside
    // --prompt-len, the pre-prefix-cache workload)
    let target_len = shared_prefix + prompt_len;
    let cache_label = match &cfg.prefix_cache {
        Some(c) => format!("on(cap={},entries={})", c.capacity, c.max_entries),
        None => "off".to_string(),
    };
    let spec_label = match &cfg.spec {
        Some(s) => format!("on(k={},ngram={})", s.draft_len, s.ngram),
        None => "off".to_string(),
    };
    println!(
        "bench-serve: model={} backend={} requests={requests} max_new={max_new} \
         prompt_len={prompt_len} shared_prefix={shared_prefix} slots={} \
         token_budget={} prefix_cache={cache_label} prefill_chunk={} \
         spec={spec_label} threads={}",
        mc.name,
        sess.backend_name(),
        cfg.max_slots,
        cfg.token_budget,
        cfg.prefill_chunk,
        misa::tensor::threads(),
    );
    let mut rng = Rng::new(seed ^ 0x5E57E);
    let mut sched = Scheduler::new(cfg);
    let vocab = mc.vocab;
    // the shared block (seeded separately so it is identical across
    // requests): BOS plus shared_prefix - 1 system-prompt tokens; with
    // --shared-prefix 0 it degenerates to the bare BOS head
    let shared: Vec<i32> = {
        let mut srng = Rng::new(seed ^ 0xA11CE);
        let mut s = vec![misa::data::tok::BOS];
        while s.len() < shared_prefix {
            s.push(srng.range(misa::data::tok::SYM0 as usize, vocab) as i32);
        }
        s
    };
    for id in 0..requests as u64 {
        let mut prompt = shared.clone();
        // each request's unique tail cycles a short random motif — the
        // repeated-structure synthetic workload (retrieval spans,
        // templates, code) that self-drafting speculation exploits
        let motif: Vec<i32> = (0..4)
            .map(|_| rng.range(misa::data::tok::SYM0 as usize, vocab) as i32)
            .collect();
        let mut j = 0usize;
        while prompt.len() < target_len {
            prompt.push(motif[j % motif.len()]);
            j += 1;
        }
        sched.submit(Request {
            id,
            prompt,
            max_new,
            sampler,
            seed: seed ^ (id.wrapping_mul(0x9E3779B9) + 1),
            eos: None,
        })?;
    }
    let t0 = std::time::Instant::now();
    let done = sched.run(&sess)?;
    let wall = t0.elapsed().as_secs_f64();
    let new_tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    let mean_ttft_ms =
        done.iter().map(|c| c.ttft_s).sum::<f64>() / done.len().max(1) as f64 * 1e3;
    let mean_tps =
        done.iter().map(|c| c.decode_tps).sum::<f64>() / done.len().max(1) as f64;
    // measured peak: the scheduler samples COW-deduplicated physical
    // bytes across slots + prefill jobs + store entries every tick and
    // the byte-accounting tracker keeps the high-water mark; the
    // analytic product bound ignores sharing and ring right-sizing
    let kv_meas = misa::obs::memory::peak(misa::obs::memory::MemCategory::KvCache);
    let kv_bound =
        KvCache::bytes_for(&sess.spec, target_len + max_new) * sched.peak_active();
    println!(
        "completed {} requests in {wall:.2} s · aggregate {:.1} tok/s · \
         mean ttft {mean_ttft_ms:.1} ms · mean per-request decode {mean_tps:.1} tok/s",
        done.len(),
        new_tokens as f64 / wall.max(1e-9),
    );
    println!(
        "peak concurrency {} slots · peak kv residency {:.2} MiB measured \
         (analytic bound {:.2} MiB)",
        sched.peak_active(),
        kv_meas as f64 / (1024.0 * 1024.0),
        kv_bound as f64 / (1024.0 * 1024.0),
    );
    // pooled per-request timelines → exact percentile distributions
    let ttft = sched.latencies().ttft();
    let itl = sched.latencies().itl();
    println!(
        "ttft p50 {:.1} / p90 {:.1} / p99 {:.1} ms · \
         itl p50 {:.3} / p90 {:.3} / p99 {:.3} ms",
        ttft.p50, ttft.p90, ttft.p99, itl.p50, itl.p90, itl.p99,
    );
    // land the run's gauges + cache/spec counters in the registry so a
    // --metrics-out dump reflects this run, not just the histograms
    sched.publish_metrics();
    let cache_stats = sched.cache_stats();
    let stats = cache_stats.unwrap_or_default();
    if cache_stats.is_some() {
        println!(
            "prefix cache: {} lookups · {} hits ({:.0}%) · {} prompt tokens reused · \
             {} entries resident · {} evicted",
            stats.lookups,
            stats.hits,
            stats.hit_rate() * 100.0,
            stats.reused_tokens,
            stats.entries,
            stats.evictions,
        );
    }
    let spec_stats = sched.spec_stats();
    let sp = spec_stats.unwrap_or_default();
    if spec_stats.is_some() {
        println!(
            "speculation: {} drafted · {} accepted · acceptance rate {:.2}",
            sp.drafted,
            sp.accepted,
            sp.acceptance_rate(),
        );
    }
    if let Some(path) = args.flags.get("json") {
        misa::util::BenchRecord::new("bench-serve")
            .tag("model", mc.name.clone())
            .tag("backend", sess.backend_name())
            .tag("prefix_cache", if cache_stats.is_some() { "on" } else { "off" })
            .tag("spec", if spec_stats.is_some() { "on" } else { "off" })
            .num("threads", misa::tensor::threads() as f64)
            .num("requests", done.len() as f64)
            .num("slots", cfg.max_slots as f64)
            .num("token_budget", cfg.token_budget as f64)
            .num("prompt_len", prompt_len as f64)
            .num("shared_prefix", shared_prefix as f64)
            .num("max_new", max_new as f64)
            .num("prefill_chunk", cfg.prefill_chunk as f64)
            .num("draft_len", cfg.spec.map_or(0.0, |s| s.draft_len as f64))
            .num("wall_s", wall)
            .num("aggregate_tok_s", new_tokens as f64 / wall.max(1e-9))
            .num("mean_ttft_ms", mean_ttft_ms)
            .num("mean_decode_tps", mean_tps)
            .num("ttft_p50", ttft.p50)
            .num("ttft_p90", ttft.p90)
            .num("ttft_p99", ttft.p99)
            .num("itl_p50", itl.p50)
            .num("itl_p90", itl.p90)
            .num("itl_p99", itl.p99)
            .num("peak_active", sched.peak_active() as f64)
            .num("peak_kv_mib", kv_meas as f64 / (1024.0 * 1024.0))
            .num("peak_kv_bound_mib", kv_bound as f64 / (1024.0 * 1024.0))
            .nums(&[
                ("cache_lookups", stats.lookups as f64),
                ("cache_hits", stats.hits as f64),
                ("cache_hit_rate", stats.hit_rate()),
                ("cache_reused_tokens", stats.reused_tokens as f64),
                ("cache_entries", stats.entries as f64),
                ("cache_evictions", stats.evictions as f64),
                ("drafted_tokens", sp.drafted as f64),
                ("accepted_tokens", sp.accepted as f64),
                ("acceptance_rate", sp.acceptance_rate()),
            ])
            .write(Path::new(path))?;
        println!("bench record written: {path}");
    }
    Ok(())
}

/// `misa bench --variance-report` — price MISA's importance sampling
/// against the uniform layer-wise counterfactual. One MISA training
/// run on the tiny builtin model feeds the online estimator: at every
/// step it computes the single-draw gradient-estimator variance under
/// the sampler's actual probabilities *and* under uniform layer
/// sampling, from the same per-module squared gradient norms
/// (Proposition 1: p ∝ s minimizes it, so the ratio should land
/// below 1 once the score EMA differentiates). A LISA run with the
/// same budget supplies a trained loss reference. Everything lands in
/// a `bench-train-variance` record (`--json`, default
/// `BENCH_train.json`).
fn cmd_bench_variance(args: &Args) -> Result<()> {
    let model = args.flags.get("model").cloned().unwrap_or_else(|| "tiny".to_string());
    let steps: u64 = match args.flags.get("steps") {
        Some(n) => n.parse().context("--steps")?,
        None => 120,
    };
    let seed: u64 = match args.flags.get("seed") {
        Some(s) => s.parse().context("--seed")?,
        None => 0,
    };
    let t_inner: usize = match args.flags.get("t-inner") {
        Some(n) => n.parse().context("--t-inner")?,
        None => 20,
    };
    let base = RunConfig { model: model.clone(), steps, seed, ..RunConfig::default() };
    let mut engine = make_engine(args)?;
    println!(
        "bench --variance-report: model={model} steps={steps} t_inner={t_inner} \
         backend={} threads={}",
        engine.backend_name(),
        misa::tensor::threads(),
    );
    let t0 = std::time::Instant::now();
    let (misa_loss, mean_s, mean_l, mean_ratio, ratio_of_means, last_ratio, counted) = {
        let mut rc = base.clone();
        rc.method = MethodSpec::Misa(misa::optim::MisaConfig {
            t_inner,
            ..misa::optim::MisaConfig::default()
        });
        let mut t = Trainer::new(&mut engine, rc)?;
        t.run(steps)?;
        let v = &t.varest;
        (
            t.metrics.last("train_loss").unwrap_or(f64::NAN),
            v.mean_sampled(),
            v.mean_layerwise(),
            v.mean_ratio(),
            v.ratio_of_means(),
            v.last().ratio,
            v.counted_steps(),
        )
    };
    let lisa_loss = {
        let mut rc = base;
        rc.method = MethodSpec::Lisa { t_inner };
        let mut t = Trainer::new(&mut engine, rc)?;
        t.run(steps)?;
        t.metrics.last("train_loss").unwrap_or(f64::NAN)
    };
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "grad-estimator variance (single draw, same norms): \
         misa {mean_s:.4e} · layerwise {mean_l:.4e}"
    );
    println!(
        "variance ratio misa/layerwise: mean {mean_ratio:.4} · \
         ratio-of-means {ratio_of_means:.4} · last {last_ratio:.4} \
         ({counted} scored steps)"
    );
    println!("final train_loss: misa {misa_loss:.4} · lisa {lisa_loss:.4}");
    if !(mean_ratio < 1.0) {
        misa::log_warn!(
            "importance sampling did not reduce estimator variance \
             (mean ratio {mean_ratio:.4} >= 1); scores may not have \
             differentiated in {steps} steps"
        );
    }
    let json_path = args
        .flags
        .get("json")
        .cloned()
        .unwrap_or_else(|| "BENCH_train.json".to_string());
    misa::util::BenchRecord::new("bench-train-variance")
        .tag("model", model)
        .tag("backend", engine.backend_name())
        .num("threads", misa::tensor::threads() as f64)
        .num("steps", steps as f64)
        .num("t_inner", t_inner as f64)
        .num("counted_steps", counted as f64)
        .num("var_sampled_mean", mean_s)
        .num("var_layerwise_mean", mean_l)
        .num("var_ratio_mean", mean_ratio)
        .num("var_ratio_of_means", ratio_of_means)
        .num("var_ratio_last", last_ratio)
        .num("misa_train_loss", misa_loss)
        .num("lisa_train_loss", lisa_loss)
        .num("wall_s", wall)
        .write(Path::new(&json_path))?;
    println!("variance report written: {json_path}");
    Ok(())
}

/// `misa bench --gemm` — kernel-level GFLOP/s sweep: time the three
/// blocked GEMM cores over the standard shapes (decode-sized, LM-head
/// tall-skinny, squares, tile-ragged) at the current `--threads` width
/// and SIMD mode, print a table, and with `--json` write one
/// `bench-gemm` record per (core, shape) as a JSON array — the
/// before/after evidence a kernel PR lands in `BENCH_serve.json` /
/// `BENCH_train.json`.
fn cmd_bench_gemm(args: &Args) -> Result<()> {
    use misa::tensor::{gemm_nn_into, gemm_nt_into, gemm_tn_acc};
    let seed: u64 = match args.flags.get("seed") {
        Some(s) => s.parse().context("--seed")?,
        None => 0,
    };
    let threads = misa::tensor::threads();
    let simd = misa::tensor::simd_label();
    println!("bench --gemm: threads={threads} simd={simd}");
    // iteration count is auto-calibrated per (core, shape) toward this
    // wall budget, so tiny and large shapes get comparable noise floors
    const BUDGET_S: f64 = 0.25;
    fn time_iters(budget: f64, mut f: impl FnMut()) -> (usize, f64) {
        let t0 = std::time::Instant::now();
        f(); // warm caches, panels, and the pool
        let once = t0.elapsed().as_secs_f64();
        let iters = ((budget / once.max(1e-9)) as usize).clamp(1, 1000);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            f();
        }
        (iters, t0.elapsed().as_secs_f64() / iters as f64)
    }
    // decode-sized projection, LM-head tall-skinny, squares, and a
    // shape ragged against every tile edge
    const SHAPES: &[(usize, usize, usize)] =
        &[(8, 256, 256), (64, 256, 1024), (256, 256, 256), (512, 512, 512), (97, 161, 133)];
    let mut rng = Rng::new(seed);
    let mut records = Vec::new();
    println!(
        "{:<8} {:>14} {:>7} {:>11} {:>9}",
        "core", "m×k×n", "iters", "ms/iter", "GFLOP/s"
    );
    for &(m, k, n) in SHAPES {
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut a_nt = vec![0.0f32; m * n];
        let mut b_nt = vec![0.0f32; k * n];
        let mut c_tn = vec![0.0f32; m * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut a_nt, 1.0);
        rng.fill_normal(&mut b_nt, 1.0);
        rng.fill_normal(&mut c_tn, 1.0);
        let mut out_nn = vec![0.0f32; m * n];
        let mut out_nt = vec![0.0f32; m * k];
        let mut out_tn = vec![0.0f32; k * n];
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let runs: [(&'static str, Box<dyn FnMut() + '_>); 3] = [
            ("nn", Box::new(|| gemm_nn_into(&a, &b, m, k, n, &mut out_nn))),
            ("nt", Box::new(|| gemm_nt_into(&a_nt, &b_nt, m, n, k, &mut out_nt))),
            ("tn", Box::new(|| gemm_tn_acc(&a, &c_tn, m, k, n, &mut out_tn))),
        ];
        for (core, f) in runs {
            let (iters, secs) = time_iters(BUDGET_S, f);
            let gflops = flops / secs / 1e9;
            let shape = format!("{m}x{k}x{n}");
            println!(
                "{core:<8} {shape:>14} {iters:>7} {:>11.3} {gflops:>9.2}",
                secs * 1e3
            );
            records.push(
                misa::util::BenchRecord::new("bench-gemm")
                    .tag("core", core)
                    .tag("shape", shape)
                    .tag("simd", simd)
                    .num("threads", threads as f64)
                    .num("m", m as f64)
                    .num("k", k as f64)
                    .num("n", n as f64)
                    .num("iters", iters as f64)
                    .num("ms_per_iter", secs * 1e3)
                    .num("gflops", gflops),
            );
        }
    }
    if let Some(path) = args.flags.get("json") {
        let body = format!(
            "[\n{}\n]\n",
            records
                .iter()
                .map(|r| r.to_json().trim_end().to_string())
                .collect::<Vec<_>>()
                .join(",\n")
        );
        std::fs::write(path, body).with_context(|| format!("writing gemm bench to {path}"))?;
        println!("gemm bench records written: {path}");
    }
    Ok(())
}

/// `misa bench` — training step-time: run `--steps` fwd/bwd+optimizer
/// steps on `--model` and report/record ms per phase (the training
/// counterpart of `bench-serve`, sharing the same JSON schema).
/// `--variance-report` switches to the MISA-vs-layerwise estimator-
/// variance measurement instead ([`cmd_bench_variance`]);
/// `--gemm` to the kernel-level GFLOP/s sweep ([`cmd_bench_gemm`]).
fn cmd_bench(args: &Args) -> Result<()> {
    if args.switches.contains("gemm") {
        return cmd_bench_gemm(args);
    }
    if args.switches.contains("variance-report") {
        return cmd_bench_variance(args);
    }
    let mut engine = make_engine(args)?;
    let mut rc = RunConfig::default();
    if let Some(m) = args.flags.get("model") {
        rc.model = m.clone();
    }
    rc.steps = match args.flags.get("steps") {
        Some(n) => n.parse().context("--steps")?,
        None => 10,
    };
    if let Some(s) = args.flags.get("seed") {
        rc.seed = s.parse().context("--seed")?;
    }
    println!(
        "bench: model={} method={} steps={} backend={} threads={}",
        rc.model,
        rc.method.label(),
        rc.steps,
        engine.backend_name(),
        misa::tensor::threads(),
    );
    let mut t = Trainer::new(&mut engine, rc.clone())?;
    let t0 = std::time::Instant::now();
    t.run(rc.steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let (fb_ms, opt_ms) = t.avg_times_ms();
    let loss = t.metrics.last("train_loss").unwrap_or(f64::NAN);
    println!(
        "{} steps in {wall:.2} s · avg fwd+bwd {fb_ms:.1} ms · avg optimizer {opt_ms:.1} ms \
         · final train_loss {loss:.4}",
        rc.steps,
    );
    if let Some(path) = args.flags.get("json") {
        misa::util::BenchRecord::new("bench")
            .tag("model", rc.model.clone())
            .tag("method", rc.method.label())
            .tag("backend", engine.backend_name())
            .num("threads", misa::tensor::threads() as f64)
            .num("steps", rc.steps as f64)
            .num("wall_s", wall)
            .num("fwd_bwd_ms", fb_ms)
            .num("optimizer_ms", opt_ms)
            .num("step_ms", wall * 1e3 / rc.steps.max(1) as f64)
            .num("train_loss", loss)
            .write(Path::new(path))?;
        println!("bench record written: {path}");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
    if name == "list" {
        println!("available experiments:");
        for (n, _, desc) in experiments::registry() {
            println!("  {n:<10} {desc}");
        }
        return Ok(());
    }
    let mut engine = make_engine(args)?;
    let fast = !args.switches.contains("full");
    let mut ctx = ExpCtx::new(&mut engine, fast);
    if name == "all" {
        for (n, f, _) in experiments::registry() {
            let t0 = std::time::Instant::now();
            match f(&mut ctx) {
                Ok(body) => {
                    println!("=== {n} ({:.1}s) ===\n{body}", t0.elapsed().as_secs_f64());
                }
                Err(e) => println!("=== {n} FAILED: {e:#} ==="),
            }
        }
    } else {
        let body = experiments::run(&mut ctx, name)?;
        println!("{body}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = make_engine(args)?;
    println!("backend: {}", engine.backend_name());
    println!("registry: {}", engine.manifest.dir.display());
    println!("configs:");
    for m in &engine.manifest.models {
        let c = &m.config;
        println!(
            "  {:<7} vocab={:<6} dim={:<5} layers={:<3} heads={}/{} ffn={:<5} b×s={}×{}  params={:.2}M  modules={}",
            c.name, c.vocab, c.dim, c.n_layers, c.n_heads, c.n_kv_heads, c.ffn_dim,
            c.batch, c.seq_len,
            m.total_params() as f64 / 1e6,
            m.matrix_module_indices().len(),
        );
    }
    // paper-scale memory summary (Table 1 Mem column)
    let arch = Arch::llama3_8b();
    let w = Workload::new(4, 512);
    println!("\nAppendix-E peak memory @ LLaMA3-8B, b=4, s=512:");
    for m in [
        Method::FullFT,
        Method::Lora { r: 32 },
        Method::Dora { r: 16 },
        Method::Lisa,
        Method::BAdam,
        Method::Misa { delta: 0.01 },
        Method::Misa { delta: 0.03 },
    ] {
        println!("  {:<14} {:>7.1} GB", m.label(), memory::table_peak_gib(m, &arch, &w));
    }
    Ok(())
}

/// Parse a `u64` accepting decimal or `0x…` hex — fuzz replay commands
/// print seeds in hex, and pasting one back must just work.
fn parse_u64_flex(name: &str, s: &str) -> Result<u64> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(h) => u64::from_str_radix(h, 16).with_context(|| format!("--{name}")),
        None => s.parse().with_context(|| format!("--{name}")),
    }
}

/// Parse a comma-separated `usize` list flag (`--slots-list 1,2,4`).
fn parse_list(args: &Args, name: &str, default: &[usize]) -> Result<Vec<usize>> {
    match args.flags.get(name) {
        None => Ok(default.to_vec()),
        Some(raw) => {
            let out = raw
                .split(',')
                .map(|p| p.trim().parse::<usize>().with_context(|| format!("--{name}: {p:?}")))
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(!out.is_empty(), "--{name} must not be empty");
            Ok(out)
        }
    }
}

fn cmd_fuzz(args: &Args) -> Result<()> {
    use misa::fuzz::{self, FuzzCfg, SchedFuzzCfg};
    let defaults = FuzzCfg::from_env(fuzz::DEFAULT_SEED, fuzz::DEFAULT_OPS);
    let cfg = FuzzCfg {
        seed: match args.flags.get("seed") {
            Some(s) => parse_u64_flex("seed", s)?,
            None => defaults.seed,
        },
        ops: match args.flags.get("ops") {
            Some(n) => n.parse().context("--ops")?,
            None => defaults.ops,
        },
    };
    let target = args.flags.get("target").map(String::as_str).unwrap_or("all");
    let targets: Vec<&str> = match target {
        "all" => vec!["kvcache", "trie", "scheduler"],
        t => vec![t],
    };
    for t in targets {
        let stats = match t {
            "kvcache" => fuzz::run_target(t, cfg, || fuzz::fuzz_kvcache(cfg))?,
            "trie" => fuzz::run_target(t, cfg, || fuzz::fuzz_trie(cfg))?,
            "scheduler" => {
                let scfg = SchedFuzzCfg {
                    fuzz: cfg,
                    spec: args.switches.contains("spec"),
                    prefix_cache: args.switches.contains("prefix-cache"),
                    prefill_chunk: match args.flags.get("prefill-chunk") {
                        Some(n) => n.parse().context("--prefill-chunk")?,
                        None => 3,
                    },
                    // the CLI owns the process, so the stream may
                    // resize the worker pool mid-run
                    resize_threads: true,
                };
                fuzz::run_target(t, cfg, || fuzz::fuzz_scheduler(scfg))?
            }
            other => bail!("unknown fuzz target {other:?} (kvcache|trie|scheduler|all)"),
        };
        let notes = stats
            .notes
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "fuzz {t}: clean · seed {:#x} · {} ops · {} checks · {notes}",
            cfg.seed, stats.ops, stats.checks,
        );
    }
    Ok(())
}

fn cmd_capacity(args: &Args) -> Result<()> {
    use misa::serve::capacity::{self, CapacityModel, SweepCfg};
    if args.switches.contains("predict") {
        let fit_path = args
            .flags
            .get("fit")
            .ok_or_else(|| anyhow!("--predict requires --fit FILE (a saved capacity fit)"))?;
        let text = std::fs::read_to_string(fit_path)
            .with_context(|| format!("reading capacity fit {fit_path}"))?;
        let model = CapacityModel::from_json(&text)?;
        let slots: usize = args
            .flags
            .get("slots")
            .ok_or_else(|| anyhow!("--predict requires --slots N"))?
            .parse()
            .context("--slots")?;
        let budget: usize = args
            .flags
            .get("token-budget")
            .ok_or_else(|| anyhow!("--predict requires --token-budget N"))?
            .parse()
            .context("--token-budget")?;
        let threads: usize = match args.flags.get("threads") {
            Some(t) => t.parse().context("--threads")?,
            None => 1,
        };
        println!(
            "capacity predict: slots={slots} token_budget={budget} threads={threads} → \
             peak_kv {:.3} MiB · {:.1} tok/s \
             (fit over {} points, workload {}+{} × {} requests)",
            model.predict_kv_mib(slots, budget, threads),
            model.predict_tok_s(slots, budget, threads),
            model.points.len(),
            model.prompt_len,
            model.max_new,
            model.requests,
        );
        return Ok(());
    }

    let mut engine = make_engine(args)?;
    let seed: u64 = match args.flags.get("seed") {
        Some(s) => parse_u64_flex("seed", s)?,
        None => 0,
    };
    let model = args.flags.get("model").map(String::as_str).unwrap_or("tiny");
    let sess = Session::create(&mut engine, model, seed)?;
    let cfg = SweepCfg {
        slots_list: parse_list(args, "slots-list", &[1, 2, 4])?,
        budget_list: parse_list(args, "budget-list", &[4096])?,
        threads_list: parse_list(args, "threads-list", &[1])?,
        requests: match args.flags.get("requests") {
            Some(n) => n.parse().context("--requests")?,
            None => 8,
        },
        prompt_len: match args.flags.get("prompt-len") {
            Some(n) => n.parse().context("--prompt-len")?,
            None => 8,
        },
        max_new: match args.flags.get("max-new") {
            Some(n) => n.parse().context("--max-new")?,
            None => 8,
        },
        seed,
    };
    println!(
        "capacity sweep: model={model} slots={:?} budgets={:?} threads={:?} \
         workload {}+{} × {} requests",
        cfg.slots_list, cfg.budget_list, cfg.threads_list, cfg.prompt_len, cfg.max_new,
        cfg.requests,
    );
    let points = capacity::run_sweep(&sess, &cfg)?;
    for p in &points {
        println!(
            "  slots={:<3} budget={:<6} threads={:<2} peak_kv {:.3} MiB · {:.1} tok/s",
            p.slots, p.token_budget, p.threads, p.peak_kv_mib, p.tok_s,
        );
    }
    let holdout = if args.switches.contains("holdout") {
        let (kv, tps) =
            capacity::holdout_rel_err(&points, cfg.requests, cfg.prompt_len, cfg.max_new)?;
        println!(
            "holdout (last point): peak_kv rel err {:.1}% · tok/s rel err {:.1}%",
            kv * 100.0,
            tps * 100.0,
        );
        Some((kv, tps))
    } else {
        None
    };
    let fit = CapacityModel::fit(points, cfg.requests, cfg.prompt_len, cfg.max_new)?;
    println!(
        "fit: peak_kv_mib ≈ {:.4} + {:.6}·eff_pos (max rel err {:.1}%) · \
         tok_s ≈ {:.2} + {:.2}·conc + {:.2}·threads",
        fit.kv_coef[0],
        fit.kv_coef[1],
        fit.kv_fit_rel_err() * 100.0,
        fit.tps_coef[0],
        fit.tps_coef[1],
        fit.tps_coef[2],
    );
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, fit.to_json_with(holdout))
            .with_context(|| format!("writing capacity fit {path}"))?;
        println!("capacity fit written: {path}");
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            log_error!("{e:#}");
            usage();
        }
    };
    if let Err(e) = apply_threads(&args) {
        log_error!("{e:#}");
        usage();
    }
    let obs = match apply_obs(&args) {
        Ok(o) => o,
        Err(e) => {
            log_error!("{e:#}");
            usage();
        }
    };
    let result = match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("generate") => cmd_generate(&args),
        Some("bench-serve") => cmd_bench_serve(&args),
        Some("fuzz") => cmd_fuzz(&args),
        Some("capacity") => cmd_capacity(&args),
        Some("bench") => cmd_bench(&args),
        Some("exp") => cmd_exp(&args),
        Some("info") => cmd_info(&args),
        _ => usage(),
    };
    // export even on failure, then report whichever error came first
    if let Err(e) = result.and(finish_obs(&obs)) {
        log_error!("{e:#}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positionals_flags_and_switches() {
        let a = parse_args(&v(&[
            "train", "--model", "tiny", "--steps", "20", "--pretrain", "--backend", "host",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.flags.get("model").unwrap(), "tiny");
        assert_eq!(a.flags.get("steps").unwrap(), "20");
        assert_eq!(a.flags.get("backend").unwrap(), "host");
        assert!(a.switches.contains("pretrain"));
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Host);
    }

    #[test]
    fn unknown_flag_is_an_error() {
        let err = parse_args(&v(&["train", "--bogus"])).unwrap_err();
        assert!(err.to_string().contains("--bogus"), "{err}");
        // previously silently absorbed as a switch
        assert!(parse_args(&v(&["train", "--bogus", "3"])).is_err());
    }

    #[test]
    fn valued_flag_missing_value_is_an_error() {
        // at end of argv
        let err = parse_args(&v(&["train", "--steps"])).unwrap_err();
        assert!(err.to_string().contains("--steps"), "{err}");
        // followed by another flag
        assert!(parse_args(&v(&["train", "--steps", "--lr", "0.1"])).is_err());
    }

    #[test]
    fn switches_never_consume_values() {
        let a = parse_args(&v(&["train", "--pretrain", "50"])).unwrap();
        assert!(a.switches.contains("pretrain"));
        assert_eq!(a.positional, vec!["train", "50"]);
    }

    #[test]
    fn bench_gemm_switch_parses() {
        let a = parse_args(&v(&["bench", "--gemm", "--threads", "2", "--json", "g.json"]))
            .unwrap();
        assert!(a.switches.contains("gemm"));
        assert_eq!(a.flags.get("threads").unwrap(), "2");
        assert_eq!(a.flags.get("json").unwrap(), "g.json");
    }

    #[test]
    fn serve_flags_parse() {
        let a = parse_args(&v(&[
            "generate", "--ckpt", "c.bin", "--prompt", "1, 2 3", "--max-new", "4",
            "--temp", "0.8", "--top-k", "20", "--top-p", "0.9", "--seed", "7",
        ]))
        .unwrap();
        assert_eq!(parse_prompt(&a).unwrap(), vec![1, 2, 3]);
        let s = sampler_from(&a).unwrap();
        assert_eq!(s.top_k, 20);
        assert!((s.temperature - 0.8).abs() < 1e-6);
        assert!((s.top_p - 0.9).abs() < 1e-6);
        // default prompt is a single BOS; default sampler is greedy
        let a = parse_args(&v(&["generate", "--ckpt", "c.bin"])).unwrap();
        assert_eq!(parse_prompt(&a).unwrap(), vec![misa::data::tok::BOS]);
        assert_eq!(sampler_from(&a).unwrap(), SamplerCfg::greedy());
        // malformed prompts are hard errors
        let a = parse_args(&v(&["generate", "--prompt", "1,x"])).unwrap();
        assert!(parse_prompt(&a).is_err());
        let a = parse_args(&v(&["generate", "--prompt", ", ,"])).unwrap();
        assert!(parse_prompt(&a).is_err());
        // invalid sampler configs are rejected at parse time
        let a = parse_args(&v(&["generate", "--top-p", "0"])).unwrap();
        assert!(sampler_from(&a).is_err());
    }

    #[test]
    fn fuzz_and_capacity_flags_parse() {
        let a = parse_args(&v(&[
            "fuzz", "--target", "scheduler", "--ops", "2000", "--seed", "0xab",
            "--spec", "--prefix-cache", "--prefill-chunk", "3",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["fuzz"]);
        assert_eq!(a.flags.get("target").unwrap(), "scheduler");
        assert!(a.switches.contains("spec") && a.switches.contains("prefix-cache"));
        assert_eq!(parse_u64_flex("seed", a.flags.get("seed").unwrap()).unwrap(), 0xAB);

        let a = parse_args(&v(&[
            "capacity", "--slots-list", "1, 2,4", "--budget-list", "4096",
            "--threads-list", "1,2", "--holdout", "--json", "cap.json",
        ]))
        .unwrap();
        assert!(a.switches.contains("holdout"));
        assert_eq!(parse_list(&a, "slots-list", &[9]).unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_list(&a, "threads-list", &[9]).unwrap(), vec![1, 2]);
        // absent list flags fall back to the default
        assert_eq!(parse_list(&a, "requests", &[7]).unwrap(), vec![7]);
        // malformed entries are hard errors
        let a = parse_args(&v(&["capacity", "--slots-list", "1,x"])).unwrap();
        assert!(parse_list(&a, "slots-list", &[1]).is_err());
        // predict-side flags share the existing valued set
        let a = parse_args(&v(&[
            "capacity", "--predict", "--fit", "cap.json", "--slots", "8",
            "--token-budget", "4096",
        ]))
        .unwrap();
        assert!(a.switches.contains("predict"));
        assert_eq!(a.flags.get("fit").unwrap(), "cap.json");
    }

    #[test]
    fn flex_u64_accepts_decimal_and_hex() {
        assert_eq!(parse_u64_flex("seed", "42").unwrap(), 42);
        assert_eq!(parse_u64_flex("seed", "0xC0FFEE").unwrap(), 0xC0FFEE);
        assert!(parse_u64_flex("seed", "0xZZ").is_err());
        assert!(parse_u64_flex("seed", "nope").is_err());
    }

    #[test]
    fn ckpt_inference_resolves_unique_config() {
        let eng = Engine::host();
        let a = parse_args(&v(&["generate"])).unwrap();
        let tiny = eng.manifest.model("tiny").unwrap();
        let params: Vec<Vec<f32>> =
            tiny.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        assert_eq!(spec_for_ckpt(&eng, &a, &params).unwrap().config.name, "tiny");
        // wrong shape set matches nothing
        let bad = vec![vec![0.0f32; 3]];
        assert!(spec_for_ckpt(&eng, &a, &bad).is_err());
        // explicit --model overrides inference
        let a = parse_args(&v(&["generate", "--model", "small"])).unwrap();
        assert_eq!(spec_for_ckpt(&eng, &a, &params).unwrap().config.name, "small");
    }

    #[test]
    fn threads_and_json_flags_parse() {
        let a = parse_args(&v(&["bench-serve", "--threads", "4", "--json", "out.json"]))
            .unwrap();
        assert_eq!(a.flags.get("threads").unwrap(), "4");
        assert_eq!(a.flags.get("json").unwrap(), "out.json");
        apply_threads(&a).unwrap();
        assert_eq!(misa::tensor::threads(), 4);
        misa::tensor::set_threads(0); // restore the env default
        // absent flag leaves the knob untouched
        let a = parse_args(&v(&["bench"])).unwrap();
        apply_threads(&a).unwrap();
        // zero and garbage are rejected
        let a = parse_args(&v(&["bench", "--threads", "0"])).unwrap();
        assert!(apply_threads(&a).is_err());
        let a = parse_args(&v(&["bench", "--threads", "x"])).unwrap();
        assert!(apply_threads(&a).is_err());
    }

    #[test]
    fn prefix_cache_flags_parse() {
        let a = parse_args(&v(&[
            "bench-serve", "--prefix-cache", "--prefix-cache-cap", "256",
            "--prefix-cache-entries", "8", "--shared-prefix", "64",
        ]))
        .unwrap();
        assert!(a.switches.contains("prefix-cache"));
        assert_eq!(a.flags.get("prefix-cache-cap").unwrap(), "256");
        assert_eq!(a.flags.get("prefix-cache-entries").unwrap(), "8");
        assert_eq!(a.flags.get("shared-prefix").unwrap(), "64");
        // the switch does not consume a value
        let a = parse_args(&v(&["bench-serve", "--prefix-cache", "9"])).unwrap();
        assert!(a.switches.contains("prefix-cache"));
        assert_eq!(a.positional, vec!["bench-serve", "9"]);
    }

    #[test]
    fn spec_flags_parse() {
        let a = parse_args(&v(&[
            "bench-serve", "--spec", "--draft-len", "6", "--spec-ngram", "2",
            "--prefill-chunk", "32",
        ]))
        .unwrap();
        assert!(a.switches.contains("spec"));
        let s = spec_from(&a).unwrap().expect("--spec enables speculation");
        assert_eq!(s.draft_len, 6);
        assert_eq!(s.ngram, 2);
        assert_eq!(a.flags.get("prefill-chunk").unwrap(), "32");
        // degenerate draft lengths are rejected at parse time
        let a = parse_args(&v(&["generate", "--spec", "--draft-len", "0"])).unwrap();
        assert!(spec_from(&a).is_err());
        let a = parse_args(&v(&["generate", "--spec", "--spec-ngram", "0"])).unwrap();
        assert!(spec_from(&a).is_err());
        // --spec alone takes the defaults; the switch consumes no value
        let a = parse_args(&v(&["bench-serve", "--spec", "9"])).unwrap();
        assert!(a.switches.contains("spec"));
        assert_eq!(a.positional, vec!["bench-serve", "9"]);
        assert_eq!(spec_from(&a).unwrap(), Some(SpecCfg::default()));
        // spec knobs without --spec are hard errors, not a silent
        // non-speculative baseline
        let a = parse_args(&v(&["bench-serve", "--draft-len", "8"])).unwrap();
        let err = spec_from(&a).unwrap_err();
        assert!(format!("{err:#}").contains("--spec"), "{err:#}");
        // without the switch the MISA_SPEC environment default applies
        let a = parse_args(&v(&["bench-serve"])).unwrap();
        assert_eq!(spec_from(&a).unwrap(), SpecCfg::from_env());
    }

    #[test]
    fn telemetry_flags_parse() {
        let a = parse_args(&v(&["train", "--report-out", "rep.json", "--steps", "5"]))
            .unwrap();
        assert_eq!(a.flags.get("report-out").unwrap(), "rep.json");
        // --report-out is valued: a missing value is a hard error
        assert!(parse_args(&v(&["train", "--report-out"])).is_err());
        assert!(parse_args(&v(&["train", "--report-out", "--steps", "5"])).is_err());
        // --variance-report is a switch and consumes no value
        let a = parse_args(&v(&["bench", "--variance-report", "9"])).unwrap();
        assert!(a.switches.contains("variance-report"));
        assert_eq!(a.positional, vec!["bench", "9"]);
        let a =
            parse_args(&v(&["bench", "--variance-report", "--t-inner", "10"])).unwrap();
        assert!(a.switches.contains("variance-report"));
        assert_eq!(a.flags.get("t-inner").unwrap(), "10");
    }

    #[test]
    fn obs_flags_parse_and_export() {
        let dir = std::env::temp_dir();
        let trace = dir.join("misa_cli_obs_trace.json");
        let metrics = dir.join("misa_cli_obs_metrics.prom");
        let a = parse_args(&v(&[
            "bench-serve",
            "--trace-out",
            trace.to_str().unwrap(),
            "--metrics-out",
            metrics.to_str().unwrap(),
        ]))
        .unwrap();
        let out = apply_obs(&a).unwrap();
        assert!(misa::obs::span::tracing_enabled(), "--trace-out enables spans");
        {
            let _sp = misa::span!("cli_obs_test", "test");
        }
        misa::obs::metrics::counter_add("cli.obs_test", 1);
        finish_obs(&out).unwrap();
        misa::obs::span::disable_tracing();
        let body = std::fs::read_to_string(&trace).unwrap();
        assert!(body.contains("\"traceEvents\""), "{body}");
        assert!(body.contains("cli_obs_test"), "{body}");
        let prom = std::fs::read_to_string(&metrics).unwrap();
        assert!(prom.contains("misa_cli_obs_test"), "{prom}");
        let _ = std::fs::remove_file(&trace);
        let _ = std::fs::remove_file(&metrics);
        // absent flags resolve to no outputs and finish_obs is a no-op
        let a = parse_args(&v(&["bench"])).unwrap();
        let out = apply_obs(&a).unwrap();
        assert!(out.trace.is_none() && out.metrics.is_none());
        assert!(out.profile.is_none() && out.roofline.is_none() && out.flight.is_none());
        finish_obs(&out).unwrap();
    }

    #[test]
    fn forensics_flags_parse_and_export() {
        let dir = std::env::temp_dir();
        let profile = dir.join("misa_cli_prof.folded");
        let roofline = dir.join("misa_cli_roofline.json");
        let flight = dir.join("misa_cli_flight.json");
        let a = parse_args(&v(&[
            "bench-serve",
            "--profile-out",
            profile.to_str().unwrap(),
            "--roofline-out",
            roofline.to_str().unwrap(),
            "--flight-out",
            flight.to_str().unwrap(),
        ]))
        .unwrap();
        let out = apply_obs(&a).unwrap();
        assert!(misa::obs::profile::running(), "--profile-out starts the sampler");
        assert!(misa::obs::flight::enabled(), "--flight-out enables the recorder");
        assert_eq!(misa::obs::flight::dump_path().as_deref(), Some(flight.as_path()));
        // hold a span open long enough for at least one sample, and
        // drop a flight event so the dump is non-trivial
        {
            let _sp = misa::span!("cli_forensics_test", "test");
            let t0 = std::time::Instant::now();
            while misa::obs::profile::report().folded.samples == 0 {
                assert!(t0.elapsed().as_secs() < 5, "sampler never fired");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        misa::obs::flight::record("test", "cli_forensics", 1, 2);
        finish_obs(&out).unwrap();
        assert!(!misa::obs::profile::running(), "finish_obs stops the sampler");
        misa::obs::flight::disable();
        let folded = std::fs::read_to_string(&profile).unwrap();
        assert!(!folded.is_empty());
        let roof = std::fs::read_to_string(&roofline).unwrap();
        misa::util::json::Json::parse(&roof).unwrap();
        let dump = std::fs::read_to_string(&flight).unwrap();
        let doc = misa::util::json::Json::parse(&dump).unwrap();
        assert!(doc
            .arr_field("events")
            .unwrap()
            .iter()
            .any(|e| e.str_field("name").is_ok_and(|n| n == "cli_forensics")));
        for p in [&profile, &roofline, &flight] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn backend_flag_parses_and_rejects() {
        let a = parse_args(&v(&["info", "--backend", "pjrt"])).unwrap();
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Pjrt);
        let a = parse_args(&v(&["info", "--backend", "tpu"])).unwrap();
        assert!(backend_kind(&a).is_err());
        let a = parse_args(&v(&["info"])).unwrap();
        assert_eq!(backend_kind(&a).unwrap(), BackendKind::Host);
    }
}
