//! Differential fuzzing of [`CacheStore`] against a flat scan model.
//!
//! The reference ([`RefStore`]) keeps stored prompts in a plain `Vec`
//! and answers every query by brute force: longest-common-prefix by
//! linear scan, LRU eviction by minimum stamp, the deterministic
//! candidate rule as an explicit "lexicographically smallest stored
//! prompt extending the match". The fuzzer drives random insert /
//! lookup / peek streams — duplicate prompts, shared prefixes,
//! degenerate donors, forced evictions — and checks after **every**
//! op:
//!
//! - hit/miss parity, matched-prefix-length parity, and that a hit's
//!   forked cache has exactly `m` positions at store capacity;
//! - `Result`/`bool` parity on insert (dedup refreshes, oversize and
//!   empty prompts decline, short donors error);
//! - full [`CacheStats`] equality (lookups, hits, reused tokens,
//!   insertions, evictions, entry count) — the LRU clock is part of
//!   the contract, not an implementation detail;
//! - [`CacheStore::peek_match`] equality against the scan over every
//!   prompt in a bounded insertion log.

use anyhow::{ensure, Result};

use crate::modelspec::{builtin_configs, spec_for};
use crate::runtime::KvCache;
use crate::serve::{CacheStore, CacheStoreCfg};
use crate::util::Rng;

use super::{FuzzCfg, FuzzStats};

/// Token alphabet (small, to force prefix collisions).
const ALPHABET: i32 = 6;

/// One stored prompt in the reference: its tokens and LRU stamp.
struct RefEntry {
    tokens: Vec<i32>,
    stamp: u64,
}

/// Flat mirror of the trie store: entries in a `Vec`, counters by hand.
struct RefStore {
    capacity: usize,
    max_entries: usize,
    min_prefix: usize,
    entries: Vec<RefEntry>,
    clock: u64,
    lookups: u64,
    hits: u64,
    reused: u64,
    insertions: u64,
    evictions: u64,
}

impl RefStore {
    fn new(cfg: CacheStoreCfg) -> RefStore {
        RefStore {
            // mirror CacheStore::new's degenerate-limit clamping
            capacity: cfg.capacity.max(1),
            max_entries: cfg.max_entries.max(1),
            min_prefix: cfg.min_prefix.max(1),
            entries: Vec::new(),
            clock: 0,
            lookups: 0,
            hits: 0,
            reused: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Longest common prefix of `prompt` with any stored prompt — the
    /// brute-force [`CacheStore::peek_match`].
    fn peek(&self, prompt: &[i32]) -> usize {
        self.entries
            .iter()
            .map(|e| e.tokens.iter().zip(prompt).take_while(|(a, b)| a == b).count())
            .max()
            .unwrap_or(0)
    }

    /// Mirror of [`CacheStore::lookup`]: returns the matched length on
    /// a hit, refreshing the chosen entry's LRU stamp.
    fn lookup(&mut self, prompt: &[i32]) -> Option<usize> {
        self.lookups += 1;
        let m = self.peek(prompt).min(prompt.len().saturating_sub(1));
        if m < self.min_prefix {
            return None;
        }
        // the deterministic candidate: the lexicographically smallest
        // stored prompt extending the matched prefix (a stored prompt
        // equal to the prefix sorts before every extension)
        let idx = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.tokens.len() >= m && e.tokens[..m] == prompt[..m])
            .min_by(|(_, a), (_, b)| a.tokens.cmp(&b.tokens))
            .map(|(i, _)| i)?;
        self.clock += 1;
        self.entries[idx].stamp = self.clock;
        self.hits += 1;
        self.reused += m as u64;
        Some(m)
    }

    /// Mirror of [`CacheStore::insert`]: `Ok(stored)` / `Err` parity
    /// including the exact ordering of the decline, dedup, donor-check
    /// and clock-bump steps.
    fn insert(&mut self, prompt: &[i32], donor_len: usize, donor_cap: usize) -> Result<bool> {
        if prompt.is_empty() || prompt.len() > self.capacity {
            return Ok(false);
        }
        ensure!(donor_len >= prompt.len(), "donor holds {donor_len} < {}", prompt.len());
        self.clock += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.tokens == prompt) {
            e.stamp = self.clock;
            return Ok(false);
        }
        // snapshot legality, mirroring the fork_from / copy_prefix split
        let plen = prompt.len();
        if donor_cap == self.capacity {
            ensure!(
                donor_len <= (plen + 1).saturating_sub(donor_cap) + donor_cap,
                "snapshot fork from a wrapped donor"
            );
        } else {
            ensure!(donor_len <= donor_cap, "snapshot copy from a wrapped donor");
        }
        self.entries.push(RefEntry { tokens: prompt.to_vec(), stamp: self.clock });
        self.insertions += 1;
        while self.entries.len() > self.max_entries {
            let idx = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("non-empty by the loop condition");
            self.entries.remove(idx);
            self.evictions += 1;
        }
        Ok(true)
    }

    fn stats_tuple(&self) -> (u64, u64, u64, u64, u64, usize) {
        (self.lookups, self.hits, self.reused, self.insertions, self.evictions, self.entries.len())
    }
}

/// Draw a prompt: fresh, a mutation of a logged prompt (shared
/// prefixes), or a logged prompt verbatim (dedup pressure).
fn draw_prompt(rng: &mut Rng, log: &[Vec<i32>], capacity: usize) -> Vec<i32> {
    let fresh = |rng: &mut Rng| -> Vec<i32> {
        let len = rng.range(1, capacity + 3);
        (0..len).map(|_| 1 + rng.below(ALPHABET as usize) as i32).collect()
    };
    if log.is_empty() {
        return fresh(rng);
    }
    match rng.below(4) {
        0 => fresh(rng),
        1 => rng.choose(log).clone(),
        _ => {
            // keep a prefix of a logged prompt, extend with fresh tokens
            let base = rng.choose(log);
            let keep = rng.below(base.len() + 1);
            let extra = rng.below(4);
            let mut p: Vec<i32> = base[..keep].to_vec();
            for _ in 0..extra {
                p.push(1 + rng.below(ALPHABET as usize) as i32);
            }
            if p.is_empty() {
                p.push(1 + rng.below(ALPHABET as usize) as i32);
            }
            p
        }
    }
}

/// Run the trie differential fuzz target.
pub fn fuzz_trie(cfg: FuzzCfg) -> Result<FuzzStats> {
    let spec = spec_for(builtin_configs().remove(0));
    let mut rng = Rng::new(cfg.seed).fork(0x7472); // "tr"
    let mut stats = FuzzStats::default();

    let store_cfg = CacheStoreCfg {
        capacity: rng.range(8, 17),
        max_entries: rng.range(2, 6),
        min_prefix: rng.range(1, 4),
    };
    let mut real = CacheStore::new(store_cfg);
    let mut model = RefStore::new(store_cfg);
    // every prompt ever offered (insertion log for the peek sweep)
    let mut log: Vec<Vec<i32>> = Vec::new();

    for _ in 0..cfg.ops {
        stats.ops += 1;
        match rng.below(100) {
            // insert with a randomized donor shape
            0..=44 => {
                let prompt = draw_prompt(&mut rng, &log, model.capacity);
                // donor variants: right-sized unwrapped (the miss
                // path), store-layout (the fork path), too short
                // (must error), wrapped (must error when snapshotted)
                let (donor_len, donor_cap) = match rng.below(8) {
                    0..=2 => (prompt.len(), prompt.len().max(1)),
                    3..=4 => (prompt.len().min(model.capacity), model.capacity),
                    5 => (prompt.len().saturating_sub(rng.range(1, 3)).max(1), model.capacity),
                    6 => (prompt.len() + 2, prompt.len().max(1)),
                    _ => (prompt.len(), model.capacity),
                };
                let donor_cap = donor_cap.max(1);
                let mut donor = KvCache::new(&spec, donor_cap)?;
                donor.advance(donor_len);
                let got = real.insert(&prompt, &donor);
                let want = model.insert(&prompt, donor_len, donor_cap);
                match (&got, &want) {
                    (Ok(a), Ok(b)) => {
                        ensure!(a == b, "insert stored={a} but the reference says {b}");
                        stats.note(if *a { "insert_stored" } else { "insert_declined" }, 1);
                    }
                    (Err(_), Err(_)) => stats.note("insert_rejected", 1),
                    _ => anyhow::bail!(
                        "insert parity: real {:?} vs reference {:?} for prompt {:?} \
                         (donor len {donor_len}, cap {donor_cap})",
                        got.as_ref().map(|_| ()),
                        want.as_ref().map(|_| ()),
                        prompt
                    ),
                }
                log.push(prompt);
            }
            // lookup: hit/miss, match length, forked-cache shape parity
            45..=79 => {
                let prompt = draw_prompt(&mut rng, &log, model.capacity);
                let got = real.lookup(&prompt);
                let want = model.lookup(&prompt);
                match (&got, want) {
                    (Some((cache, m)), Some(wm)) => {
                        ensure!(
                            *m == wm,
                            "lookup matched {m} positions, reference says {wm}, for {prompt:?}"
                        );
                        ensure!(
                            cache.len() == wm && cache.capacity() == model.capacity,
                            "hit fork shape (len {}, cap {}) != ({wm}, {})",
                            cache.len(),
                            cache.capacity(),
                            model.capacity
                        );
                        stats.note("lookup_hit", 1);
                        stats.checks += 2;
                    }
                    (None, None) => stats.note("lookup_miss", 1),
                    _ => anyhow::bail!(
                        "lookup parity: real {:?} vs reference {want:?} for {prompt:?}",
                        got.as_ref().map(|(_, m)| *m)
                    ),
                }
            }
            // pure peek probe (no side effects on either side)
            _ => {
                let prompt = draw_prompt(&mut rng, &log, model.capacity);
                ensure!(
                    real.peek_match(&prompt) == model.peek(&prompt),
                    "peek_match disagrees on {prompt:?}"
                );
                stats.note("peek", 1);
                stats.checks += 1;
            }
        }

        // invariants after every op: full stats equality and a peek
        // sweep over a bounded window of the insertion log
        let s = real.stats();
        ensure!(
            (s.lookups, s.hits, s.reused_tokens, s.insertions, s.evictions, s.entries)
                == model.stats_tuple(),
            "stats drift: real {s:?} vs reference {:?}",
            model.stats_tuple()
        );
        ensure!(real.len() == model.entries.len(), "entry-count drift");
        stats.checks += 2;
        let window = log.len().saturating_sub(48);
        for p in &log[window..] {
            ensure!(
                real.peek_match(p) == model.peek(p),
                "peek sweep disagrees on logged prompt {p:?}"
            );
            stats.checks += 1;
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_is_clean_and_covers_every_op() {
        let stats = fuzz_trie(FuzzCfg { seed: 0xFACE, ops: 1200 }).unwrap();
        assert_eq!(stats.ops, 1200);
        for kind in
            ["insert_stored", "insert_declined", "insert_rejected", "lookup_hit", "lookup_miss", "peek"]
        {
            assert!(stats.count(kind) > 0, "op kind {kind:?} never fired");
        }
    }

    #[test]
    fn runs_are_deterministic_in_the_seed() {
        let a = fuzz_trie(FuzzCfg { seed: 11, ops: 500 }).unwrap();
        let b = fuzz_trie(FuzzCfg { seed: 11, ops: 500 }).unwrap();
        assert_eq!(a.checks, b.checks);
        assert_eq!(a.notes, b.notes);
    }
}
