//! Adversarial fuzzing of the continuous-batching [`Scheduler`].
//!
//! Drives a real `tiny`-model [`Session`] through random interleavings
//! of submit / tick / cancel / thread-resize — with speculative
//! decoding and the prefix cache independently on or off — and checks:
//!
//! - **budget**: `in_flight_tokens() <= token_budget` after every op,
//!   and `0` once drained;
//! - **accounting**: every accepted request is eventually answered by
//!   exactly one completion (tick output or [`Scheduler::cancel`]'s
//!   return), so `accepted == completions + pending()` at all times;
//! - **residency**: COW-deduped [`Scheduler::kv_resident_bytes`] never
//!   exceeds the analytic bound `row_bytes × (token_budget +
//!   max_slots × (store_capacity + chunk) + max_entries ×
//!   store_capacity)` — a leak (a retired ring still referenced, a
//!   store entry never evicted) trips this within a few ops;
//! - **parity**: after draining, every surviving completion's tokens
//!   are **bit-identical** to a solo [`generate`] replay of the same
//!   request with speculation off — so spec-on scheduling, prefix
//!   reuse, chunked prefill, cancellations of neighbors, and thread
//!   resizes all provably never change any output token. Cancelled
//!   completions must be a strict prefix of their solo replay;
//!   rejected ones must contain an out-of-vocab token and no output.

use anyhow::{ensure, Result};

use crate::runtime::backend::CHUNK_POSITIONS;
use crate::runtime::{Engine, Session};
use crate::serve::{generate, CacheStoreCfg, FinishReason, GenerateCfg, Request};
use crate::serve::{SamplerCfg, Scheduler, SchedulerCfg, SpecCfg};
use crate::util::Rng;

use super::{FuzzCfg, FuzzStats};

/// One scheduler fuzz run's shape: the base seed/op budget plus which
/// serving features the run exercises.
#[derive(Clone, Copy, Debug)]
pub struct SchedFuzzCfg {
    /// Seed and op count.
    pub fuzz: FuzzCfg,
    /// Speculative decoding on (`draft_len` 4, `ngram` 3).
    pub spec: bool,
    /// Prefix-sharing prompt cache on.
    pub prefix_cache: bool,
    /// Per-tick prefill row cap (`0` = unlimited) — small values force
    /// multi-tick prompts, so cancels land mid-prefill.
    pub prefill_chunk: usize,
    /// Allow the stream to resize the global worker pool mid-run
    /// (leave off inside multi-threaded test binaries unless the
    /// caller serializes access to the pool).
    pub resize_threads: bool,
}

impl Default for SchedFuzzCfg {
    fn default() -> Self {
        SchedFuzzCfg {
            fuzz: FuzzCfg::default(),
            spec: true,
            prefix_cache: true,
            prefill_chunk: 3,
            resize_threads: false,
        }
    }
}

/// Prompt-cache shape used by every fuzz run with the cache on; small
/// enough that eviction happens constantly.
const STORE: CacheStoreCfg = CacheStoreCfg { capacity: 32, max_entries: 4, min_prefix: 2 };

/// Run the scheduler fuzz target.
pub fn fuzz_scheduler(cfg: SchedFuzzCfg) -> Result<FuzzStats> {
    let mut rng = Rng::new(cfg.fuzz.seed).fork(0x5C); // "sched"
    let mut stats = FuzzStats::default();

    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", rng.next_u64())?;
    let vocab = sess.spec.config.vocab;
    let row_bytes =
        (2 * sess.spec.config.n_layers * sess.spec.config.kv_dim() * std::mem::size_of::<f32>())
            as u64;

    let max_slots = rng.range(2, 5);
    let token_budget = rng.range(64, 129);
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots,
        token_budget,
        prefix_cache: cfg.prefix_cache.then_some(STORE),
        prefill_chunk: cfg.prefill_chunk,
        spec: cfg.spec.then_some(SpecCfg { draft_len: 4, ngram: 3 }),
    });
    // the analytic no-leak residency ceiling (see the module docs);
    // store entries and live rings are all bounded in ring positions
    let (store_cap, store_entries) =
        if cfg.prefix_cache { (STORE.capacity, STORE.max_entries) } else { (0, 0) };
    let bound_positions = token_budget
        + max_slots * (store_cap + CHUNK_POSITIONS)
        + store_entries * (store_cap + CHUNK_POSITIONS);
    let residency_bound = row_bytes * bound_positions as u64;

    // shared prefix pool so the store actually hits
    let prefix_pool: Vec<Vec<i32>> = (0..3)
        .map(|_| {
            let len = rng.range(2, 6);
            (0..len).map(|_| rng.range(4, vocab) as i32).collect()
        })
        .collect();

    let mut next_id = 0u64;
    let mut accepted: Vec<Request> = Vec::new();
    let mut completions = Vec::new();

    let draw_request = |rng: &mut Rng, next_id: &mut u64| -> Request {
        let mut prompt: Vec<i32> = if rng.below(2) == 0 {
            rng.choose(&prefix_pool).clone()
        } else {
            Vec::new()
        };
        let extra = rng.range(if prompt.is_empty() { 2 } else { 0 }, 8);
        for _ in 0..extra {
            prompt.push(rng.range(4, vocab) as i32);
        }
        if rng.below(12) == 0 {
            // adversarial: out of vocab → must become a Rejected
            // completion, not a crash
            let i = rng.below(prompt.len());
            prompt[i] = vocab as i32 + 5;
        }
        let sampler = if rng.below(2) == 0 {
            SamplerCfg { temperature: 0.0, ..SamplerCfg::default() }
        } else {
            SamplerCfg { temperature: 0.7, top_k: 16, top_p: 0.9 }
        };
        let id = *next_id;
        *next_id += 1;
        Request {
            id,
            prompt,
            max_new: rng.range(1, 7),
            sampler,
            seed: 1000 + id,
            eos: (rng.below(4) == 0).then_some(rng.range(4, vocab) as i32),
        }
    };

    for _ in 0..cfg.fuzz.ops {
        stats.ops += 1;
        match rng.below(100) {
            // submit a request (occasionally one that must be refused)
            0..=34 => {
                if rng.below(16) == 0 {
                    // cost above the whole budget: submit must refuse
                    // (deadlock guard), and nothing is charged
                    let mut req = draw_request(&mut rng, &mut next_id);
                    req.prompt = (0..token_budget + 1).map(|_| 4i32).collect();
                    ensure!(sched.submit(req).is_err(), "oversize submit was accepted");
                    stats.note("submit_refused", 1);
                } else {
                    let req = draw_request(&mut rng, &mut next_id);
                    sched.submit(req.clone())?;
                    accepted.push(req);
                    stats.note("submit", 1);
                }
            }
            // advance the machine
            35..=74 => {
                completions.extend(sched.tick(&sess)?);
                stats.note("tick", 1);
            }
            // cancel: a live id must yield a completion, a dead or
            // unknown id must yield None
            75..=84 => {
                let live: Vec<u64> = accepted
                    .iter()
                    .map(|r| r.id)
                    .filter(|id| !completions.iter().any(|c: &crate::serve::Completion| c.id == *id))
                    .collect();
                if !live.is_empty() && rng.below(4) != 0 {
                    let id = *rng.choose(&live);
                    let c = sched.cancel(id);
                    ensure!(c.is_some(), "cancel({id}) of a live request returned None");
                    completions.extend(c);
                    stats.note("cancel", 1);
                } else {
                    ensure!(
                        sched.cancel(u64::MAX).is_none(),
                        "cancel of an unknown id returned a completion"
                    );
                    stats.note("cancel_unknown", 1);
                }
            }
            // resize the worker pool mid-stream (decode must stay
            // bit-identical at any width)
            85..=89 => {
                if cfg.resize_threads {
                    crate::tensor::set_threads(1 + rng.below(4));
                    stats.note("resize", 1);
                }
            }
            // burst of ticks (drains toward idle, exercises retirement)
            _ => {
                for _ in 0..rng.range(2, 5) {
                    completions.extend(sched.tick(&sess)?);
                }
                stats.note("tick_burst", 1);
            }
        }

        // invariants after every op
        ensure!(
            sched.in_flight_tokens() <= token_budget,
            "in-flight {} exceeds the token budget {token_budget}",
            sched.in_flight_tokens()
        );
        ensure!(
            accepted.len() == completions.len() + sched.pending(),
            "accounting drift: {} accepted vs {} completed + {} pending",
            accepted.len(),
            completions.len(),
            sched.pending()
        );
        let resident = sched.kv_resident_bytes();
        ensure!(
            resident <= residency_bound,
            "resident {resident} B exceeds the no-leak bound {residency_bound} B \
             (budget {token_budget}, slots {max_slots})"
        );
        stats.checks += 3;
    }

    // drain, then verify the terminal state and replay every stream
    while sched.pending() > 0 {
        completions.extend(sched.tick(&sess)?);
    }
    if cfg.resize_threads {
        crate::tensor::set_threads(0); // restore the default pool
    }
    ensure!(sched.in_flight_tokens() == 0, "drained scheduler still charges budget");
    ensure!(accepted.len() == completions.len(), "drained scheduler lost completions");
    stats.checks += 2;

    for c in &completions {
        let req = accepted
            .iter()
            .find(|r| r.id == c.id)
            .expect("completion for an unsubmitted id");
        let oov = req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab);
        if matches!(c.finish, FinishReason::Rejected) {
            ensure!(oov, "request {} rejected without an out-of-vocab token", c.id);
            ensure!(c.tokens.is_empty(), "rejected request {} produced tokens", c.id);
            stats.note("verified_rejected", 1);
            stats.checks += 2;
            continue;
        }
        if oov {
            // the only non-Rejected exit for a bad prompt: cancelled
            // while still queued, before admission could reject it
            ensure!(
                matches!(c.finish, FinishReason::Cancelled) && c.tokens.is_empty(),
                "request {} with an out-of-vocab token finished {:?} with tokens",
                c.id,
                c.finish
            );
            stats.note("verified_cancelled", 1);
            stats.checks += 1;
            continue;
        }
        let solo = generate(
            &sess,
            &req.prompt,
            &GenerateCfg {
                max_new: req.max_new,
                sampler: req.sampler,
                seed: req.seed,
                eos: req.eos,
                spec: None, // plain decode: the parity baseline
            },
        )?;
        if matches!(c.finish, FinishReason::Cancelled) {
            ensure!(
                c.tokens.len() <= solo.tokens.len() && solo.tokens[..c.tokens.len()] == c.tokens[..],
                "request {}: cancelled tokens are not a prefix of the solo replay",
                c.id
            );
            stats.note("verified_cancelled", 1);
        } else {
            ensure!(
                c.tokens == solo.tokens,
                "request {}: scheduled tokens {:?} != solo replay {:?}",
                c.id,
                c.tokens,
                solo.tokens
            );
            stats.note("verified_exact", 1);
        }
        stats.checks += 1;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_is_clean_with_everything_on() {
        let stats = fuzz_scheduler(SchedFuzzCfg {
            fuzz: FuzzCfg { seed: 0xD1CE, ops: 160 },
            ..SchedFuzzCfg::default()
        })
        .unwrap();
        assert_eq!(stats.ops, 160);
        for kind in ["submit", "tick", "cancel", "verified_exact"] {
            assert!(stats.count(kind) > 0, "op kind {kind:?} never fired");
        }
    }

    #[test]
    fn plain_decode_and_no_cache_also_hold() {
        let stats = fuzz_scheduler(SchedFuzzCfg {
            fuzz: FuzzCfg { seed: 0xBEEF, ops: 120 },
            spec: false,
            prefix_cache: false,
            prefill_chunk: 0,
            resize_threads: false,
        })
        .unwrap();
        assert!(stats.count("verified_exact") > 0);
    }
}
