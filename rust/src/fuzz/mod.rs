//! Adversarial serving fuzzer: seed-replayable differential testing.
//!
//! Grown from the [`crate::util::prop`] mini-harness, this module
//! drives long randomized op streams against the serving stack's three
//! stateful cores and checks machine-checkable invariants after every
//! single op:
//!
//! - [`kvcache`] — [`crate::runtime::KvCache`] (append / fork /
//!   truncate / copy / reset / drop) against a dense reference model:
//!   bitwise-equal rows over the live attention window, COW-deduped
//!   residency bounded by physical ring bytes.
//! - [`trie`] — [`crate::serve::CacheStore`] (insert / lookup / peek
//!   under LRU eviction) against a flat longest-common-prefix scan over
//!   the insertion log: identical hits, reuse lengths, stats counters
//!   and eviction order.
//! - [`sched`] — [`crate::serve::Scheduler`] (admit / tick / cancel /
//!   thread-resize, speculative decoding and the prefix cache on or
//!   off) against solo [`fn@crate::serve::generate`] replays: budget never
//!   exceeded, residency bounded, survivors bit-identical, cancelled
//!   streams a prefix of their solo run.
//!
//! Every run is a pure function of one `u64` seed. A violation aborts
//! with a one-line replay command (CLI and `cargo test` forms), the
//! same contract `MISA_PROP_SEED` gives the property tests. The
//! `MISA_FUZZ_SEED` / `MISA_FUZZ_OPS` environment knobs override the
//! built-in defaults everywhere a fuzz target runs (tests, CI smoke,
//! `misa fuzz`).

pub mod kvcache;
pub mod sched;
pub mod trie;

pub use kvcache::fuzz_kvcache;
pub use sched::{fuzz_scheduler, SchedFuzzCfg};
pub use trie::fuzz_trie;

use anyhow::{anyhow, Result};

/// Default op count per target — sized so the three CI smoke targets
/// together clear 10k ops in seconds on the `tiny` config.
pub const DEFAULT_OPS: usize = 4096;

/// Default master seed (any value works; fixed so CI failures are
/// reproducible without copying a log line).
pub const DEFAULT_SEED: u64 = 0x5EED_F022;

/// One fuzz run's identity: every op drawn, every checked value, is a
/// pure function of `seed` and `ops`.
#[derive(Clone, Copy, Debug)]
pub struct FuzzCfg {
    /// Master seed for the op stream.
    pub seed: u64,
    /// Number of ops to drive before declaring the run clean.
    pub ops: usize,
}

impl FuzzCfg {
    /// Build from defaults, honoring the `MISA_FUZZ_SEED` /
    /// `MISA_FUZZ_OPS` environment overrides (decimal or `0x…` hex,
    /// same grammar as `MISA_PROP_SEED`).
    pub fn from_env(seed: u64, ops: usize) -> FuzzCfg {
        FuzzCfg {
            seed: crate::util::prop::env_u64("MISA_FUZZ_SEED").unwrap_or(seed),
            ops: crate::util::prop::env_u64("MISA_FUZZ_OPS").map(|n| n as usize).unwrap_or(ops),
        }
    }
}

impl Default for FuzzCfg {
    fn default() -> Self {
        FuzzCfg { seed: DEFAULT_SEED, ops: DEFAULT_OPS }
    }
}

/// What a clean run did — op and check counts plus per-op-kind tallies,
/// so a smoke run can assert the stream actually exercised every
/// transition (a fuzzer that never forks proves nothing about forks).
#[derive(Clone, Debug, Default)]
pub struct FuzzStats {
    /// Ops executed.
    pub ops: usize,
    /// Individual invariant checks that passed.
    pub checks: u64,
    /// Per-op-kind counters, in first-seen order.
    pub notes: Vec<(&'static str, u64)>,
}

impl FuzzStats {
    /// Bump the named counter by `delta` (creating it at first use).
    pub fn note(&mut self, key: &'static str, delta: u64) {
        match self.notes.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => *v += delta,
            None => self.notes.push((key, delta)),
        }
    }

    /// The named counter's value (0 when never bumped).
    pub fn count(&self, key: &str) -> u64 {
        self.notes.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(0)
    }
}

/// The one-line replay command printed on any violation: the CLI form
/// first (works without a checkout of the test tree), then the
/// `cargo test` form driven by the environment knobs.
pub fn replay_cmd(target: &str, cfg: FuzzCfg) -> String {
    format!(
        "replay: misa fuzz --target {target} --seed {seed:#x} --ops {ops} \
         (or: MISA_FUZZ_SEED={seed:#x} MISA_FUZZ_OPS={ops} cargo test --test fuzz_serve {target})",
        seed = cfg.seed,
        ops = cfg.ops,
    )
}

/// On any failure, write the flight recorder's ring (when it is on)
/// next to the replay command, so the violation ships its own
/// forensics: the last thousands of scheduler/span/pool events in
/// order. Returns the note to append to the error message.
fn flight_note() -> String {
    match crate::obs::flight::dump_to_configured() {
        Some((path, _events)) => format!("\n  flight dump: {}", path.display()),
        None if crate::obs::flight::enabled() => format!(
            "\n  flight recorder captured {} event(s); pass --flight-out FILE \
             (or set MISA_FLIGHT_OUT) to dump them on failure",
            crate::obs::flight::recorded()
        ),
        None => String::new(),
    }
}

/// Run a fuzz body, converting both `Err` returns and panics (a
/// debug-assert or index bug inside the target counts as a violation,
/// not a crash) into an error whose message carries the replay
/// command for exactly this `(target, seed, ops)` — plus a flight
/// dump when the recorder is on.
///
/// `MISA_FUZZ_INJECT=1` turns a clean run into an injected violation
/// *after* the body completes: a deterministic tripwire so CI can
/// assert the whole failure path (replay line + flight dump) without
/// depending on a real bug existing.
pub fn run_target<F>(target: &str, cfg: FuzzCfg, body: F) -> Result<FuzzStats>
where
    F: FnOnce() -> Result<FuzzStats>,
{
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
    match outcome {
        Ok(Ok(stats)) => {
            if std::env::var("MISA_FUZZ_INJECT").is_ok_and(|v| {
                let v = v.trim();
                !v.is_empty() && v != "0"
            }) {
                return Err(anyhow!(
                    "fuzz target {target:?}: injected violation (MISA_FUZZ_INJECT) after {} \
                     clean ops\n  {}{}",
                    stats.ops,
                    replay_cmd(target, cfg),
                    flight_note(),
                ));
            }
            Ok(stats)
        }
        Ok(Err(e)) => Err(anyhow!(
            "fuzz target {target:?}: {e:#}\n  {}{}",
            replay_cmd(target, cfg),
            flight_note(),
        )),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            Err(anyhow!(
                "fuzz target {target:?} panicked: {msg}\n  {}{}",
                replay_cmd(target, cfg),
                flight_note(),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes sibling tests that set the fuzz env knobs — or call
    /// [`run_target`], which reads `MISA_FUZZ_INJECT` — against each
    /// other.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn from_env_prefers_overrides() {
        // the shared env knobs are read by name; use the real names
        // but restore them
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::remove_var("MISA_FUZZ_SEED");
        std::env::remove_var("MISA_FUZZ_OPS");
        let cfg = FuzzCfg::from_env(7, 11);
        assert_eq!((cfg.seed, cfg.ops), (7, 11));
        std::env::set_var("MISA_FUZZ_SEED", "0x10");
        std::env::set_var("MISA_FUZZ_OPS", "3");
        let cfg = FuzzCfg::from_env(7, 11);
        assert_eq!((cfg.seed, cfg.ops), (16, 3));
        std::env::remove_var("MISA_FUZZ_SEED");
        std::env::remove_var("MISA_FUZZ_OPS");
    }

    #[test]
    fn stats_notes_accumulate() {
        let mut s = FuzzStats::default();
        s.note("fork", 1);
        s.note("fork", 2);
        s.note("drop", 1);
        assert_eq!(s.count("fork"), 3);
        assert_eq!(s.count("drop"), 1);
        assert_eq!(s.count("never"), 0);
    }

    #[test]
    fn violations_carry_a_replay_command() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let cfg = FuzzCfg { seed: 0xAB, ops: 9 };
        let err = run_target("kvcache", cfg, || Err(anyhow!("len mismatch"))).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("len mismatch"), "{msg}");
        assert!(msg.contains("misa fuzz --target kvcache --seed 0xab --ops 9"), "{msg}");
        assert!(msg.contains("MISA_FUZZ_SEED=0xab MISA_FUZZ_OPS=9"), "{msg}");

        let err = run_target("trie", cfg, || panic!("index out of bounds")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("index out of bounds"), "{msg}");
        assert!(msg.contains("--target trie"), "{msg}");
    }

    #[test]
    fn clean_runs_pass_stats_through() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::remove_var("MISA_FUZZ_INJECT");
        let cfg = FuzzCfg::default();
        let stats = run_target("kvcache", cfg, || {
            let mut s = FuzzStats { ops: 5, checks: 10, ..FuzzStats::default() };
            s.note("append", 5);
            Ok(s)
        })
        .unwrap();
        assert_eq!(stats.ops, 5);
        assert_eq!(stats.count("append"), 5);
    }

    #[test]
    fn injected_violation_ships_replay_line_and_flight_dump() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _gate = crate::obs::span::TEST_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let tmp = std::env::temp_dir()
            .join(format!("misa_flight_inject_{}.json", std::process::id()));
        crate::obs::flight::enable();
        crate::obs::flight::set_dump_path(&tmp);
        crate::obs::flight::record("test", "pre_failure_op", 7, 0);
        std::env::set_var("MISA_FUZZ_INJECT", "1");
        let err = run_target("trie", FuzzCfg { seed: 0x7E, ops: 3 }, || {
            Ok(FuzzStats { ops: 3, ..FuzzStats::default() })
        })
        .unwrap_err();
        std::env::remove_var("MISA_FUZZ_INJECT");
        crate::obs::flight::disable();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected violation"), "{msg}");
        assert!(msg.contains("misa fuzz --target trie --seed 0x7e --ops 3"), "{msg}");
        assert!(msg.contains(&format!("flight dump: {}", tmp.display())), "{msg}");
        // the dump is well-formed JSON containing the pre-failure event
        let body = std::fs::read_to_string(&tmp).unwrap();
        let doc = crate::util::json::Json::parse(&body).unwrap();
        let events = doc.arr_field("events").unwrap();
        assert!(events
            .iter()
            .any(|e| e.str_field("name").is_ok_and(|n| n == "pre_failure_op")));
        let _ = std::fs::remove_file(&tmp);
    }
}
