//! Differential fuzzing of [`KvCache`] against a dense reference model.
//!
//! The reference ([`RefKv`]) stores every appended K/V row by absolute
//! position in plain `Vec`s — no rings, no chunks, no sharing — and
//! mirrors the real cache's legality rules as predicates. The fuzzer
//! drives a pool of (real, reference) pairs through random append /
//! fork / truncate / copy / reset / drop streams and checks after
//! **every** op:
//!
//! - `Result` parity: an op the reference deems illegal must fail on
//!   the real cache, and vice versa (no silent clamping either way);
//! - `len` / `capacity` agreement on every live pair;
//! - bitwise row equality (`f32::to_bits`) over the live attention
//!   window — the positions the ring contract guarantees resident:
//!   `(len + 1).saturating_sub(capacity) .. len`, what the *next*
//!   query would attend over;
//! - COW residency: [`kv_resident_bytes`] over the whole pool never
//!   exceeds the sum of per-cache physical ring bytes, never
//!   undercounts a single cache, and collapses to exactly one ring's
//!   physical bytes when the pool is dropped to one cache.

use anyhow::{ensure, Result};

use crate::modelspec::{builtin_configs, spec_for, ModelSpec};
use crate::runtime::backend::CHUNK_POSITIONS;
use crate::runtime::{kv_resident_bytes, KvCache};
use crate::util::Rng;

use super::{FuzzCfg, FuzzStats};

/// Upper bound on live (real, reference) pairs; ops that would grow the
/// pool past this mutate an existing pair instead.
const MAX_POOL: usize = 8;

/// Dense mirror of one cache: rows by absolute position, per layer.
struct RefKv {
    capacity: usize,
    len: usize,
    /// `rows[layer][pos] = (k_row, v_row)`; `rows[layer].len()` can
    /// exceed `len` after a truncate (stale tail rows are simply
    /// overwritten on re-append, like ring slots are).
    rows: Vec<Vec<(Vec<f32>, Vec<f32>)>>,
}

impl RefKv {
    fn new(n_layers: usize, capacity: usize) -> RefKv {
        RefKv { capacity, len: 0, rows: vec![Vec::new(); n_layers] }
    }

    fn set_row(&mut self, layer: usize, pos: usize, krow: Vec<f32>, vrow: Vec<f32>) {
        let rows = &mut self.rows[layer];
        if pos < rows.len() {
            rows[pos] = (krow, vrow);
        } else {
            assert_eq!(pos, rows.len(), "reference rows must stay dense");
            rows.push((krow, vrow));
        }
    }

    /// Mirror of [`KvCache::fork_from`]'s legality.
    fn fork_legal(&self, len: usize) -> bool {
        len <= self.len && self.len <= (len + 1).saturating_sub(self.capacity) + self.capacity
    }

    /// Mirror of [`KvCache::truncate`]'s legality.
    fn truncate_legal(&self, len: usize) -> bool {
        len <= self.len && (self.len <= self.capacity || self.len <= len + 1)
    }

    /// Mirror of [`KvCache::copy_prefix`]'s legality (positive target
    /// capacity is guaranteed by the op generator).
    fn copy_legal(&self, len: usize, capacity: usize) -> bool {
        len <= self.len && len <= capacity && self.len <= self.capacity
    }

    fn fork(&self, len: usize) -> RefKv {
        RefKv {
            capacity: self.capacity,
            len,
            rows: self.rows.clone(),
        }
    }

    fn copy(&self, len: usize, capacity: usize) -> RefKv {
        RefKv {
            capacity,
            len,
            rows: self.rows.iter().map(|layer| layer[..len].to_vec()).collect(),
        }
    }
}

/// Physical ring bytes of one cache: chunk-granular, both K and V,
/// all layers — what its chunks occupy when it shares nothing.
fn physical_bytes(spec: &ModelSpec, capacity: usize) -> u64 {
    let mc = &spec.config;
    let chunk_floats = CHUNK_POSITIONS * mc.kv_dim();
    (2 * mc.n_layers * capacity.div_ceil(CHUNK_POSITIONS) * chunk_floats
        * std::mem::size_of::<f32>()) as u64
}

/// Check one (real, reference) pair: shape agreement plus bitwise row
/// equality over the live attention window. Returns the number of
/// checks performed.
fn check_pair(real: &KvCache, model: &RefKv) -> Result<u64> {
    ensure!(
        real.len() == model.len && real.capacity() == model.capacity,
        "shape drift: real (len {}, cap {}) vs reference (len {}, cap {})",
        real.len(),
        real.capacity(),
        model.len,
        model.capacity
    );
    let mut checks = 1u64;
    let lo = (model.len + 1).saturating_sub(model.capacity).min(model.len);
    for layer in 0..model.rows.len() {
        for pos in lo..model.len {
            let slot = pos % model.capacity;
            let (ref_k, ref_v) = &model.rows[layer][pos];
            let (real_k, real_v) = (real.k_row(layer, slot), real.v_row(layer, slot));
            let k_eq = real_k.iter().zip(ref_k).all(|(a, b)| a.to_bits() == b.to_bits());
            let v_eq = real_v.iter().zip(ref_v).all(|(a, b)| a.to_bits() == b.to_bits());
            ensure!(
                k_eq && v_eq,
                "row mismatch at layer {layer} pos {pos} (slot {slot}, len {}, cap {})",
                model.len,
                model.capacity
            );
            checks += 1;
        }
    }
    Ok(checks)
}

/// Run the KvCache differential fuzz target. Clean runs return stats;
/// callers wanting the replay-command contract wrap this in
/// [`super::run_target`].
pub fn fuzz_kvcache(cfg: FuzzCfg) -> Result<FuzzStats> {
    let spec = spec_for(builtin_configs().remove(0)); // tiny: 2 layers, kv_dim 32
    let n_layers = spec.config.n_layers;
    let kv_dim = spec.config.kv_dim();
    // domain-separate per target so `--target all` never replays the
    // same stream three times
    let mut rng = Rng::new(cfg.seed).fork(0x6B76); // "kv"
    let mut stats = FuzzStats::default();

    let cap0 = rng.range(4, 40);
    let mut pool: Vec<(KvCache, RefKv)> =
        vec![(KvCache::new(&spec, cap0)?, RefKv::new(n_layers, cap0))];

    for _ in 0..cfg.ops {
        stats.ops += 1;
        let i = rng.below(pool.len());
        match rng.below(100) {
            // append 1..=5 positions of fresh random rows
            0..=39 => {
                let t = rng.range(1, 6);
                let (real, model) = &mut pool[i];
                for _ in 0..t {
                    let pos = model.len;
                    for layer in 0..n_layers {
                        let mut krow = vec![0.0f32; kv_dim];
                        let mut vrow = vec![0.0f32; kv_dim];
                        rng.fill_normal(&mut krow, 1.0);
                        rng.fill_normal(&mut vrow, 1.0);
                        real.write_kv(layer, pos, &krow, &vrow);
                        model.set_row(layer, pos, krow, vrow);
                    }
                    real.advance(1);
                    model.len += 1;
                }
                stats.note("append", 1);
            }
            // fork at a random length, legal or not
            40..=54 => {
                let child = {
                    let (real, model) = &pool[i];
                    let len = rng.below(model.len + 3);
                    let got = KvCache::fork_from(real, len);
                    let legal = model.fork_legal(len);
                    ensure!(
                        got.is_ok() == legal,
                        "fork_from({len}) on (len {}, cap {}): real says {:?}, \
                         reference says {legal}",
                        model.len,
                        model.capacity,
                        got.is_ok()
                    );
                    got.ok().map(|c| (c, model.fork(len)))
                };
                if let Some(pair) = child {
                    if pool.len() < MAX_POOL {
                        pool.push(pair);
                    } else {
                        pool[i] = pair;
                    }
                    stats.note("fork", 1);
                } else {
                    stats.note("fork_rejected", 1);
                }
            }
            // truncate to a random length, legal or not
            55..=69 => {
                let (real, model) = &mut pool[i];
                let len = rng.below(model.len + 3);
                let got = real.truncate(len);
                let legal = model.truncate_legal(len);
                ensure!(
                    got.is_ok() == legal,
                    "truncate({len}) on (len {}, cap {}): real says {:?}, reference says {legal}",
                    model.len,
                    model.capacity,
                    got.is_ok()
                );
                if got.is_ok() {
                    model.len = len;
                    stats.note("truncate", 1);
                } else {
                    stats.note("truncate_rejected", 1);
                }
            }
            // copy_prefix into a fresh ring of a random capacity
            70..=79 => {
                let child = {
                    let (real, model) = &pool[i];
                    let len = rng.below(model.len + 2);
                    let new_cap = rng.range(1, 48);
                    let got = KvCache::copy_prefix(real, len, new_cap);
                    let legal = model.copy_legal(len, new_cap);
                    ensure!(
                        got.is_ok() == legal,
                        "copy_prefix({len}, {new_cap}) on (len {}, cap {}): real says {:?}, \
                         reference says {legal}",
                        model.len,
                        model.capacity,
                        got.is_ok()
                    );
                    got.ok().map(|c| (c, model.copy(len, new_cap)))
                };
                if let Some(pair) = child {
                    if pool.len() < MAX_POOL {
                        pool.push(pair);
                    } else {
                        pool[i] = pair;
                    }
                    stats.note("copy", 1);
                } else {
                    stats.note("copy_rejected", 1);
                }
            }
            // reset in place
            80..=84 => {
                let (real, model) = &mut pool[i];
                real.reset();
                model.len = 0;
                model.rows.iter_mut().for_each(Vec::clear);
                stats.note("reset", 1);
            }
            // drop a pool member (the last COW sharer releasing chunks)
            85..=91 => {
                if pool.len() > 1 {
                    pool.swap_remove(i);
                    stats.note("drop", 1);
                }
            }
            // fresh cache at a fresh capacity
            _ => {
                let cap = rng.range(4, 40);
                let pair = (KvCache::new(&spec, cap)?, RefKv::new(n_layers, cap));
                if pool.len() < MAX_POOL {
                    pool.push(pair);
                } else {
                    pool[i] = pair;
                }
                stats.note("fresh", 1);
            }
        }

        // invariants after every op
        for (real, model) in &pool {
            stats.checks += check_pair(real, model)?;
        }
        let resident = kv_resident_bytes(pool.iter().map(|(c, _)| c));
        let sum_physical: u64 =
            pool.iter().map(|(c, _)| physical_bytes(&spec, c.capacity())).sum();
        let max_physical =
            pool.iter().map(|(c, _)| physical_bytes(&spec, c.capacity())).max().unwrap_or(0);
        ensure!(
            resident <= sum_physical,
            "residency {resident} exceeds the no-sharing bound {sum_physical}"
        );
        ensure!(
            resident >= max_physical,
            "residency {resident} undercounts the largest single ring {max_physical}"
        );
        stats.checks += 2;
    }

    // endgame: a single survivor owns exactly its own physical ring
    pool.truncate(1);
    let survivor = &pool[0].0;
    let resident = kv_resident_bytes(pool.iter().map(|(c, _)| c));
    ensure!(
        resident == physical_bytes(&spec, survivor.capacity()),
        "sole survivor resident {resident} != physical {}",
        physical_bytes(&spec, survivor.capacity())
    );
    stats.checks += 1;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_is_clean_and_covers_every_op() {
        let stats = fuzz_kvcache(FuzzCfg { seed: 0xFEED, ops: 1500 }).unwrap();
        assert_eq!(stats.ops, 1500);
        for kind in ["append", "fork", "truncate", "copy", "reset", "drop", "fresh"] {
            assert!(stats.count(kind) > 0, "op kind {kind:?} never fired");
        }
        // illegal transitions were actually attempted, not just avoided
        assert!(stats.count("fork_rejected") + stats.count("truncate_rejected") > 0);
    }

    #[test]
    fn runs_are_deterministic_in_the_seed() {
        let a = fuzz_kvcache(FuzzCfg { seed: 3, ops: 400 }).unwrap();
        let b = fuzz_kvcache(FuzzCfg { seed: 3, ops: 400 }).unwrap();
        assert_eq!(a.checks, b.checks);
        assert_eq!(a.notes, b.notes);
    }

    #[test]
    fn physical_bytes_matches_a_real_ring() {
        let spec = spec_for(builtin_configs().remove(0));
        for cap in [1, 15, 16, 17, 33] {
            let c = KvCache::new(&spec, cap).unwrap();
            assert_eq!(
                physical_bytes(&spec, cap),
                kv_resident_bytes([&c]),
                "capacity {cap}"
            );
        }
    }
}
