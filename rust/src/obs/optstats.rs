//! Module-sampling telemetry: per-module importance scores, empirical
//! vs. target sampling frequencies with a chi-square drift statistic,
//! and an online single-draw gradient-variance estimator that turns
//! the paper's variance-reduction claim (Prop. 1 / Theorem 1) into a
//! live metric.
//!
//! Everything here is a *pure read-out*: the inputs are the scaled
//! squared gradient norms the backend already computes as a by-product
//! (App. A.2) and counters the samplers already maintain
//! ([`SamplingUnit`] snapshots from [`SamplerTelemetry`]). Recording
//! never touches an RNG stream or a parameter, so bit-parity with
//! telemetry enabled is structural — pinned by the report on/off
//! parity tests.
//!
//! ## The variance estimator
//!
//! A block-sampling optimizer draws block `b` with probability `p_b`
//! and scales its update by `1/p_b` (importance sampling). For the
//! per-block scalar `s_b ≥ 0` — here the scaled squared grad norm
//! `‖g_b‖²/n_b` — the single-draw estimator `X = s_B/p_B`, `B ~ p`,
//! has
//!
//! ```text
//! Var(p) = Σ_b s_b²/p_b − (Σ_b s_b)²
//! ```
//!
//! minimized at `p_b ∝ s_b` (Prop. 1). [`VarianceEstimator`] evaluates
//! this functional each step at the sampler's own distribution (MISA's
//! tempered softmax over the Eq. 4 EMA) **and** at the uniform
//! layer-wise counterfactual `p_b = 1/(L·k_l)` — pick one of `L`
//! layers uniformly, then each of its `k_l` modules — i.e. the
//! LISA/BAdam distribution evaluated on the *same* norms. The ratio of
//! the two is the measured analogue of the paper's layer-wise
//! comparison; `misa bench --variance-report` records it into
//! `BENCH_train.json`.
//!
//! [`SamplerTelemetry`]: crate::optim::sampler::SamplerTelemetry

use crate::obs::{memory, metrics};
use crate::optim::sampler::SamplingUnit;
use crate::util::bench::escape;

/// Single-draw importance-sampling variance `Σ_b s_b²/p_b − (Σ_b s_b)²`
/// of the estimator `s_B/p_B`, `B ~ p`. Zero-mass blocks contribute
/// nothing; a positive-mass block at (numerically) zero probability is
/// priced at the smallest positive normal instead of `Inf` so one
/// degenerate softmax tail cannot poison a whole report. Clamped at
/// 0.0 against rounding when `p ∝ s` exactly.
pub fn importance_variance(s: &[f64], p: &[f64]) -> f64 {
    debug_assert_eq!(s.len(), p.len());
    let total: f64 = s.iter().sum();
    let mut second = 0.0;
    for (&si, &pi) in s.iter().zip(p) {
        if si == 0.0 {
            continue;
        }
        second += si * si / pi.max(f64::MIN_POSITIVE);
    }
    (second - total * total).max(0.0)
}

/// The uniform layer-wise counterfactual distribution over `units`:
/// `p_b = 1/(L·k_l)` where `L` is the number of layer groups and `k_l`
/// the number of units in `b`'s group — one of `L` layers drawn
/// uniformly, then every module of that layer. Layerless units
/// (`layer < 0`, embed/head/norms) are lumped into one pseudo-group so
/// the distribution still sums to 1 over mixed pools.
pub fn layerwise_probs(units: &[SamplingUnit]) -> Vec<f64> {
    use std::collections::BTreeMap;
    let mut group_size: BTreeMap<i32, usize> = BTreeMap::new();
    for u in units {
        *group_size.entry(u.layer.max(-1)).or_insert(0) += 1;
    }
    let l = group_size.len().max(1) as f64;
    units
        .iter()
        .map(|u| 1.0 / (l * group_size[&u.layer.max(-1)] as f64))
        .collect()
}

/// Pearson chi-square drift between empirical selection counts and the
/// sampler's *current* target distribution:
/// `Σ_b (c_b − N·p_b)²/(N·p_b)` with `N = Σ_b c_b` total selections.
/// Returns 0.0 before any selection. Near `B−1` when the empirical
/// frequencies track the target; grows linearly in `N` under a fixed
/// mismatch. Because MISA's target moves with the score EMA, this is a
/// drift indicator (how far history lags the present distribution),
/// not a goodness-of-fit test.
pub fn chi_square(units: &[SamplingUnit]) -> f64 {
    let n: u64 = units.iter().map(|u| u.count).sum();
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;
    units
        .iter()
        .map(|u| {
            let e = nf * u.prob;
            if e <= 0.0 {
                if u.count == 0 {
                    0.0
                } else {
                    f64::INFINITY
                }
            } else {
                let d = u.count as f64 - e;
                d * d / e
            }
        })
        .sum()
}

/// One step's variance read-out.
#[derive(Clone, Copy, Debug, Default)]
pub struct VarianceSample {
    /// `Var(p)` at the sampler's own distribution.
    pub var_sampled: f64,
    /// `Var(p)` at the uniform layer-wise counterfactual.
    pub var_layerwise: f64,
    /// `var_sampled / var_layerwise` (1.0 when the counterfactual is 0).
    pub ratio: f64,
    /// Whether this step entered the running means (scores
    /// differentiated and the counterfactual variance was positive).
    pub counted: bool,
}

/// Online accumulator of [`VarianceSample`]s over a training run.
///
/// Cold-start steps are excluded from the running means: until the
/// first EMA refresh every sampler's scores are identical, its
/// distribution is uniform, and the "comparison" is vacuous (ratio
/// pinned at ~1.0 by construction). Only steps where the scores
/// actually differentiate are counted — the per-step samples still
/// report the raw values either way.
#[derive(Clone, Debug, Default)]
pub struct VarianceEstimator {
    steps: u64,
    counted: u64,
    sum_sampled: f64,
    sum_layerwise: f64,
    sum_ratio: f64,
    last: VarianceSample,
}

impl VarianceEstimator {
    /// A fresh estimator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one step: `s[i]` is the scaled squared grad norm of
    /// `units[i]` this step. Pure arithmetic on copies — never
    /// perturbs the sampler or the step.
    pub fn record(&mut self, units: &[SamplingUnit], s: &[f64]) -> VarianceSample {
        let probs: Vec<f64> = units.iter().map(|u| u.prob).collect();
        let lw = layerwise_probs(units);
        let var_sampled = importance_variance(s, &probs);
        let var_layerwise = importance_variance(s, &lw);
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for u in units {
            mn = mn.min(u.score);
            mx = mx.max(u.score);
        }
        let scored = units.len() > 1 && mx > mn;
        let ratio = if var_layerwise > 0.0 {
            var_sampled / var_layerwise
        } else {
            1.0
        };
        let counted = scored && var_layerwise > 0.0;
        self.steps += 1;
        if counted {
            self.counted += 1;
            self.sum_sampled += var_sampled;
            self.sum_layerwise += var_layerwise;
            self.sum_ratio += ratio;
        }
        let sample = VarianceSample {
            var_sampled,
            var_layerwise,
            ratio,
            counted,
        };
        self.last = sample;
        sample
    }

    /// Steps recorded (counted or not).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Steps that entered the running means.
    pub fn counted_steps(&self) -> u64 {
        self.counted
    }

    /// Mean sampled-distribution variance over counted steps.
    pub fn mean_sampled(&self) -> f64 {
        if self.counted == 0 {
            0.0
        } else {
            self.sum_sampled / self.counted as f64
        }
    }

    /// Mean layer-wise counterfactual variance over counted steps.
    pub fn mean_layerwise(&self) -> f64 {
        if self.counted == 0 {
            0.0
        } else {
            self.sum_layerwise / self.counted as f64
        }
    }

    /// Mean of the per-step ratios over counted steps (1.0 if none).
    pub fn mean_ratio(&self) -> f64 {
        if self.counted == 0 {
            1.0
        } else {
            self.sum_ratio / self.counted as f64
        }
    }

    /// Ratio of the summed variances `Σ var_sampled / Σ var_layerwise`
    /// — the aggregate variance reduction, robust to a few
    /// small-denominator steps that skew [`Self::mean_ratio`].
    pub fn ratio_of_means(&self) -> f64 {
        if self.sum_layerwise > 0.0 {
            self.sum_sampled / self.sum_layerwise
        } else {
            1.0
        }
    }

    /// The most recent sample.
    pub fn last(&self) -> VarianceSample {
        self.last
    }
}

/// Publish a sampler snapshot + variance sample into the metrics
/// registry. Per-unit gauges are namespaced
/// `optim.<label>.unit.<name>.{score,prob,freq}`; aggregates are
/// `optim.<label>.{rounds,chi_square}` and `train.grad_var.*`.
pub fn publish(label: &str, rounds: u64, units: &[SamplingUnit], sample: &VarianceSample) {
    let n: u64 = units.iter().map(|u| u.count).sum();
    for u in units {
        let base = format!("optim.{label}.unit.{}", u.name);
        metrics::gauge_set(&format!("{base}.score"), u.score);
        metrics::gauge_set(&format!("{base}.prob"), u.prob);
        let freq = if n == 0 {
            0.0
        } else {
            u.count as f64 / n as f64
        };
        metrics::gauge_set(&format!("{base}.freq"), freq);
    }
    metrics::gauge_set(&format!("optim.{label}.rounds"), rounds as f64);
    metrics::gauge_set(&format!("optim.{label}.chi_square"), chi_square(units));
    metrics::gauge_set("train.grad_var.sampled", sample.var_sampled);
    metrics::gauge_set("train.grad_var.layerwise", sample.var_layerwise);
    metrics::gauge_set("train.grad_var.ratio", sample.ratio);
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// One per-step record of the `misa train --report-out` document.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// 0-based trainer step.
    pub step: u64,
    /// Training loss at this step.
    pub loss: f64,
    /// `Var(p)` at the sampler's distribution (0.0 for non-samplers).
    pub var_sampled: f64,
    /// `Var(p)` at the layer-wise counterfactual.
    pub var_layerwise: f64,
    /// `var_sampled / var_layerwise`.
    pub var_ratio: f64,
    /// Total squared gradient norm over all parameters.
    pub grad_sq_norm: f64,
    /// Optimizer-state residency after the update (bytes).
    pub optim_state_bytes: u64,
    /// Activation scratch held by the backend this step (bytes).
    pub activation_scratch_bytes: u64,
}

impl StepRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\"step\": {}, \"loss\": {}, \"var_sampled\": {}, \"var_layerwise\": {}, \
             \"var_ratio\": {}, \"grad_sq_norm\": {}, \"optim_state_bytes\": {}, \
             \"activation_scratch_bytes\": {}}}",
            self.step,
            jf(self.loss),
            jf(self.var_sampled),
            jf(self.var_layerwise),
            jf(self.var_ratio),
            jf(self.grad_sq_norm),
            self.optim_state_bytes,
            self.activation_scratch_bytes
        )
    }
}

/// The whole `--report-out` document: per-step records plus a final
/// summary (variance trajectory, per-module sampling table, peak
/// memory by category). Renders as ONE `json.load`-valid object,
/// hand-rolled like the rest of `util::bench`.
pub struct TrainReport {
    /// Model registry name.
    pub model: String,
    /// Optimizer display name.
    pub method: String,
    /// Per-step records, in step order.
    pub per_step: Vec<StepRecord>,
}

impl TrainReport {
    /// An empty report for the given run.
    pub fn new(model: &str, method: &str) -> Self {
        TrainReport {
            model: model.to_string(),
            method: method.to_string(),
            per_step: Vec::new(),
        }
    }

    /// Append one step's record.
    pub fn push(&mut self, rec: StepRecord) {
        self.per_step.push(rec);
    }

    /// Render the document. `units`/`rounds` come from the optimizer's
    /// `SamplerTelemetry` (empty slice / 0 for non-sampling methods —
    /// the sampler table renders as `null`). Memory peaks are read
    /// from [`crate::obs::memory`] at render time.
    pub fn to_json(&self, est: &VarianceEstimator, units: &[SamplingUnit], rounds: u64) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"model\": \"{}\",\n", escape(&self.model)));
        out.push_str(&format!("  \"method\": \"{}\",\n", escape(&self.method)));
        out.push_str("  \"per_step\": [\n");
        for (i, r) in self.per_step.iter().enumerate() {
            let comma = if i + 1 == self.per_step.len() { "" } else { "," };
            out.push_str(&format!("    {}{comma}\n", r.to_json()));
        }
        out.push_str("  ],\n");
        out.push_str("  \"summary\": {\n");
        out.push_str(&format!("    \"steps\": {},\n", est.steps()));
        out.push_str(&format!(
            "    \"variance\": {{\"counted_steps\": {}, \"mean_sampled\": {}, \
             \"mean_layerwise\": {}, \"mean_ratio\": {}, \"ratio_of_means\": {}, \
             \"last_ratio\": {}}},\n",
            est.counted_steps(),
            jf(est.mean_sampled()),
            jf(est.mean_layerwise()),
            jf(est.mean_ratio()),
            jf(est.ratio_of_means()),
            jf(est.last().ratio)
        ));
        if units.is_empty() {
            out.push_str("    \"sampler\": null,\n");
        } else {
            let total: u64 = units.iter().map(|u| u.count).sum();
            out.push_str(&format!(
                "    \"sampler\": {{\"rounds\": {rounds}, \"chi_square\": {}, \"modules\": [\n",
                jf(chi_square(units))
            ));
            for (i, u) in units.iter().enumerate() {
                let comma = if i + 1 == units.len() { "" } else { "," };
                let freq = if total == 0 {
                    0.0
                } else {
                    u.count as f64 / total as f64
                };
                out.push_str(&format!(
                    "      {{\"name\": \"{}\", \"layer\": {}, \"score\": {}, \"prob\": {}, \
                     \"count\": {}, \"freq\": {}, \"numel\": {}, \"active\": {}}}{comma}\n",
                    escape(&u.name),
                    u.layer,
                    jf(u.score),
                    jf(u.prob),
                    u.count,
                    jf(freq),
                    u.numel,
                    u.active
                ));
            }
            out.push_str("    ]},\n");
        }
        out.push_str(&format!(
            "    \"memory\": {{\"optim_states_peak_bytes\": {}, \
             \"activation_scratch_peak_bytes\": {}, \"kv_cache_peak_bytes\": {}, \
             \"process_peak_rss_bytes\": {}}}\n",
            memory::peak(memory::MemCategory::OptimStates),
            memory::peak(memory::MemCategory::ActivationScratch),
            memory::peak(memory::MemCategory::KvCache),
            memory::process_peak_rss_bytes()
                .map(|b| b.to_string())
                .unwrap_or_else(|| "null".to_string())
        ));
        out.push_str("  }\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn unit(name: &str, layer: i32, score: f64, prob: f64, count: u64) -> SamplingUnit {
        SamplingUnit {
            name: name.to_string(),
            params: vec![0],
            layer,
            score,
            prob,
            count,
            numel: 64,
            active: false,
        }
    }

    /// The streaming formula must match the naive definitional oracle
    /// `Σ_b p_b (s_b/p_b − E[X])²` on random instances.
    #[test]
    fn variance_matches_naive_oracle() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let b = 2 + rng.below(12);
            let s: Vec<f64> = (0..b).map(|_| rng.f32() as f64).collect();
            let raw: Vec<f64> = (0..b).map(|_| 0.05 + rng.f32() as f64).collect();
            let z: f64 = raw.iter().sum();
            let p: Vec<f64> = raw.iter().map(|r| r / z).collect();
            let fast = importance_variance(&s, &p);
            let mean: f64 = s.iter().sum();
            let naive: f64 = s
                .iter()
                .zip(&p)
                .map(|(&si, &pi)| pi * (si / pi - mean) * (si / pi - mean))
                .sum();
            let scale = naive.abs().max(1.0);
            assert!(
                (fast - naive).abs() / scale < 1e-10,
                "fast {fast} vs naive {naive}"
            );
        }
    }

    /// `p ∝ s` is the minimizer (Prop. 1): any other distribution over
    /// the same `s` has no smaller variance, and the optimum is ~0.
    #[test]
    fn proportional_probabilities_minimize_variance() {
        let s = [1.0, 4.0, 0.5, 2.5];
        let total: f64 = s.iter().sum();
        let opt: Vec<f64> = s.iter().map(|&x| x / total).collect();
        assert!(importance_variance(&s, &opt) < 1e-9);
        let uniform = vec![0.25; 4];
        assert!(importance_variance(&s, &uniform) > importance_variance(&s, &opt));
        let skew = [0.7, 0.1, 0.1, 0.1];
        assert!(importance_variance(&s, &skew) > importance_variance(&s, &opt));
    }

    #[test]
    fn layerwise_probs_group_by_layer_and_lump_layerless() {
        // 2 layers with 2 and 1 units + 1 layerless unit => L = 3 groups
        let units = vec![
            unit("a", 0, 0.0, 0.0, 0),
            unit("b", 0, 0.0, 0.0, 0),
            unit("c", 1, 0.0, 0.0, 0),
            unit("embed", -1, 0.0, 0.0, 0),
        ];
        let p = layerwise_probs(&units);
        assert!((p[0] - 1.0 / 6.0).abs() < 1e-12);
        assert!((p[1] - 1.0 / 6.0).abs() < 1e-12);
        assert!((p[2] - 1.0 / 3.0).abs() < 1e-12);
        assert!((p[3] - 1.0 / 3.0).abs() < 1e-12);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chi_square_zero_when_counts_match_target() {
        let units = vec![
            unit("a", 0, 0.0, 0.25, 25),
            unit("b", 0, 0.0, 0.25, 25),
            unit("c", 1, 0.0, 0.5, 50),
        ];
        assert!(chi_square(&units) < 1e-12);
        let skewed = vec![
            unit("a", 0, 0.0, 0.25, 50),
            unit("b", 0, 0.0, 0.25, 0),
            unit("c", 1, 0.0, 0.5, 50),
        ];
        assert!(chi_square(&skewed) > 10.0);
        assert_eq!(chi_square(&[unit("a", 0, 0.0, 1.0, 0)]), 0.0);
    }

    #[test]
    fn estimator_gates_cold_start_and_counts_scored_steps() {
        let mut est = VarianceEstimator::new();
        // cold start: identical scores => uniform target, not counted
        let cold = vec![
            unit("a", 0, 0.0, 0.5, 0),
            unit("b", 1, 0.0, 0.5, 0),
        ];
        let s0 = est.record(&cold, &[1.0, 3.0]);
        assert!(!s0.counted);
        assert_eq!(est.counted_steps(), 0);
        assert_eq!(est.steps(), 1);
        // differentiated scores, target tilted toward the larger norm
        let warm = vec![
            unit("a", 0, 0.2, 0.3, 3),
            unit("b", 1, 0.9, 0.7, 7),
        ];
        let s1 = est.record(&warm, &[1.0, 3.0]);
        assert!(s1.counted);
        assert!(s1.ratio < 1.0, "tilted target must beat uniform: {}", s1.ratio);
        assert_eq!(est.counted_steps(), 1);
        assert!(est.mean_ratio() < 1.0);
        assert!(est.ratio_of_means() < 1.0);
        assert!((est.last().ratio - s1.ratio).abs() < 1e-15);
    }

    /// Empirical selection frequencies converge to the importance
    /// weights over many rounds (the Fig. 11 sanity check, satellite
    /// test): equal-numel modules, δ budget admitting one per round.
    #[test]
    fn empirical_frequency_converges_to_importance_weights() {
        use crate::optim::sampler::{ImportanceSampler, SamplerConfig};
        let b = 4;
        let numel = vec![100u64; b];
        let n_model = 400 * 3;
        let cfg = SamplerConfig {
            // budget fits exactly one 100-elem module per round
            delta: 100.0 / n_model as f64,
            ..SamplerConfig::default()
        };
        let mut sampler = ImportanceSampler::new(cfg, numel, n_model);
        sampler.set_static_scores(vec![0.1, 0.4, 0.9, 1.6]);
        let probs = sampler.probabilities();
        let mut rng = Rng::new(42);
        let rounds = 20_000;
        for _ in 0..rounds {
            sampler.select(&mut rng);
        }
        let total: u64 = sampler.counts.iter().sum();
        assert_eq!(total, rounds); // one module per round
        for (i, &c) in sampler.counts.iter().enumerate() {
            let freq = c as f64 / total as f64;
            assert!(
                (freq - probs[i]).abs() < 0.02,
                "module {i}: freq {freq} vs target {}",
                probs[i]
            );
        }
        // the chi-square drift over the telemetry snapshot is modest
        // when frequencies track the target (E[chi2] ≈ B−1)
        let units: Vec<SamplingUnit> = (0..b)
            .map(|i| unit(&format!("m{i}"), 0, 0.0, probs[i], sampler.counts[i]))
            .collect();
        assert!(chi_square(&units) < 30.0, "{}", chi_square(&units));
    }

    #[test]
    fn report_renders_valid_shape() {
        let mut rep = TrainReport::new("tiny", "MISA(d=3%,T=20)");
        rep.push(StepRecord {
            step: 0,
            loss: 4.5,
            var_sampled: 1.0,
            var_layerwise: 2.0,
            var_ratio: 0.5,
            grad_sq_norm: 9.0,
            optim_state_bytes: 1024,
            activation_scratch_bytes: 2048,
        });
        rep.push(StepRecord {
            step: 1,
            loss: f64::NAN, // non-finite renders as null, not NaN
            var_sampled: 0.0,
            var_layerwise: 0.0,
            var_ratio: 1.0,
            grad_sq_norm: 0.0,
            optim_state_bytes: 0,
            activation_scratch_bytes: 0,
        });
        let mut est = VarianceEstimator::new();
        let units = vec![
            unit("layers.0.\"wq\"", 0, 0.5, 0.6, 3),
            unit("layers.1.wq", 1, 0.1, 0.4, 1),
        ];
        est.record(&units, &[1.0, 2.0]);
        let json = rep.to_json(&est, &units, 4);
        // balanced braces/brackets and the fields the CI smoke greps
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "{json}"
        );
        assert_eq!(
            json.matches('[').count(),
            json.matches(']').count(),
            "{json}"
        );
        for key in [
            "\"per_step\"",
            "\"var_sampled\"",
            "\"var_layerwise\"",
            "\"var_ratio\"",
            "\"optim_state_bytes\"",
            "\"activation_scratch_bytes\"",
            "\"summary\"",
            "\"variance\"",
            "\"sampler\"",
            "\"modules\"",
            "\"memory\"",
            "\"chi_square\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.contains("null"), "NaN loss must render as null");
        // quotes inside module names are escaped
        assert!(json.contains("layers.0.\\\"wq\\\""), "{json}");
        // non-sampling methods render a null sampler table
        let json2 = rep.to_json(&est, &[], 0);
        assert!(json2.contains("\"sampler\": null"), "{json2}");
    }
}
