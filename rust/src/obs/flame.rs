//! Flame-graph and roofline exporters for the sampling profiler.
//!
//! Two artifacts fall out of [`crate::obs::profile`]:
//!
//! - **Folded stacks** ([`FoldedStacks`]): each sampler hit of a
//!   thread's published span stack becomes one `frame;frame;frame`
//!   key; [`FoldedStacks::render_folded`] emits the classic
//!   `stack count` line format that `flamegraph.pl` consumes directly
//!   and speedscope imports as "Brendan Gregg collapsed stacks". No
//!   symbolization is involved — frames *are* span names, so the
//!   flame graph speaks the repo's own vocabulary (`sched_tick`,
//!   `gemm_nn`, `pool_task`, ...).
//!
//! - **Roofline attribution** ([`KernelStats`]): the GEMM cores know
//!   their exact arithmetic (`m·k·n` multiply-accumulates = `2·m·k·n`
//!   FLOPs) and time themselves while profiling is on. Joining the
//!   two gives *achieved* GFLOP/s per core; the best single-call rate
//!   ever observed is that core's measured *peak* (an empirical
//!   roofline — no clock-speed guessing), so `achieved ≤ peak` holds
//!   by construction and the gap is attributable. Each call also tags
//!   the enclosing span (`ragged_forward`, `decode_batch`,
//!   `fwd_bwd`, ...), so the JSON breaks every core down by the model
//!   module that issued it — "who is below roofline" in one file.
//!
//! Both accumulators are process-global behind mutexes that are only
//! touched from the sampler thread (folded stacks) or once per GEMM
//! *call* — never per tile, never inside the pool's task hot loop —
//! while profiling is enabled; with the profiler off neither is ever
//! locked.

use std::collections::BTreeMap;

/// Accumulated folded-stack sample counts plus sampler health
/// counters.
#[derive(Clone, Debug, Default)]
pub struct FoldedStacks {
    /// `frame;frame;...` → number of sampler hits.
    counts: BTreeMap<String, u64>,
    /// Total successful stack samples folded in.
    pub samples: u64,
    /// Snapshots dropped because a publication raced the read (the
    /// seqlock was odd or moved); bounded sampler bias, made visible.
    pub torn: u64,
}

impl FoldedStacks {
    /// Fold one sampled stack (outermost frame first) in.
    pub fn add(&mut self, frames: &[&str]) {
        if frames.is_empty() {
            return;
        }
        *self.counts.entry(frames.join(";")).or_insert(0) += 1;
        self.samples += 1;
    }

    /// Number of distinct stacks observed.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Sampler hits whose folded key equals `stack`.
    pub fn count(&self, stack: &str) -> u64 {
        self.counts.get(stack).copied().unwrap_or(0)
    }

    /// Render in `flamegraph.pl` collapsed form: one `stack count`
    /// line per distinct stack, lexicographically ordered (the order
    /// is irrelevant to consumers but keeps the artifact diffable).
    pub fn render_folded(&self) -> String {
        let mut out = String::with_capacity(self.counts.len() * 48);
        for (stack, n) in &self.counts {
            out.push_str(stack);
            out.push(' ');
            out.push_str(&n.to_string());
            out.push('\n');
        }
        out
    }
}

/// Per-module attribution within one kernel core.
#[derive(Clone, Debug, Default)]
struct ModuleAgg {
    flops: u64,
    ns: u64,
    calls: u64,
}

/// One GEMM core's accumulated work, time, and empirical peak.
#[derive(Clone, Debug, Default)]
pub struct KernelAgg {
    /// Total floating-point operations (2 × MACs) across calls.
    pub flops: u64,
    /// Total wall nanoseconds across calls (caller-side, spans the
    /// whole pool dispatch).
    pub ns: u64,
    /// Timed calls.
    pub calls: u64,
    /// Best single-call GFLOP/s ever observed — the empirical peak
    /// this core demonstrably reaches on this machine.
    pub peak_gflops: f64,
    /// Per enclosing-span breakdown (module name → share).
    by_module: BTreeMap<&'static str, ModuleAgg>,
}

impl KernelAgg {
    /// Aggregate achieved GFLOP/s (total FLOPs over total time). A
    /// time-weighted mean of per-call rates, hence `≤ peak_gflops`.
    pub fn achieved_gflops(&self) -> f64 {
        self.flops as f64 / self.ns.max(1) as f64
    }
}

/// Process-global kernel → [`KernelAgg`] table.
#[derive(Clone, Debug, Default)]
pub struct KernelStats {
    cores: BTreeMap<&'static str, KernelAgg>,
}

impl KernelStats {
    /// Fold one timed kernel call in. `module` is the span enclosing
    /// the call site (`None` folds under `"untracked"`).
    pub fn record(
        &mut self,
        core: &'static str,
        module: Option<&'static str>,
        macs: u64,
        ns: u64,
    ) {
        let flops = macs.saturating_mul(2);
        // clamp each call to ≥ 1 ns *before* accumulating, so achieved
        // (a time-weighted mean of exactly these per-call rates) can
        // never exceed peak even for sub-resolution timings
        let ns = ns.max(1);
        let agg = self.cores.entry(core).or_default();
        agg.flops += flops;
        agg.ns += ns;
        agg.calls += 1;
        let rate = flops as f64 / ns as f64; // FLOPs/ns == GFLOP/s
        if rate > agg.peak_gflops {
            agg.peak_gflops = rate;
        }
        let m = agg.by_module.entry(module.unwrap_or("untracked")).or_default();
        m.flops += flops;
        m.ns += ns;
        m.calls += 1;
    }

    /// Whether any call was recorded.
    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// The aggregate for one core, if it ever ran timed.
    pub fn core(&self, name: &str) -> Option<&KernelAgg> {
        self.cores.get(name)
    }

    /// Render the roofline JSON:
    /// `{"cores": [{"core", "calls", "flops", "busy_ms",
    /// "gflops_achieved", "gflops_peak", "modules": [{"module",
    /// "calls", "flops", "busy_ms", "gflops", "flop_share"}]}]}`.
    /// `gflops_achieved ≤ gflops_peak` holds per core by construction
    /// (CI asserts it).
    pub fn render_roofline_json(&self) -> String {
        let mut out = String::from("{\"cores\":[");
        for (i, (core, agg)) in self.cores.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n{{\"core\":\"{}\",\"calls\":{},\"flops\":{},\"busy_ms\":{:.3},\
                 \"gflops_achieved\":{:.3},\"gflops_peak\":{:.3},\"modules\":[",
                crate::util::bench::escape(core),
                agg.calls,
                agg.flops,
                agg.ns as f64 / 1e6,
                agg.achieved_gflops(),
                agg.peak_gflops,
            ));
            for (j, (module, m)) in agg.by_module.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n  {{\"module\":\"{}\",\"calls\":{},\"flops\":{},\"busy_ms\":{:.3},\
                     \"gflops\":{:.3},\"flop_share\":{:.4}}}",
                    crate::util::bench::escape(module),
                    m.calls,
                    m.flops,
                    m.ns as f64 / 1e6,
                    m.flops as f64 / m.ns.max(1) as f64,
                    m.flops as f64 / agg.flops.max(1) as f64,
                ));
            }
            out.push_str("]}");
        }
        out.push_str("\n]}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folded_stacks_fold_and_render() {
        let mut f = FoldedStacks::default();
        f.add(&["main", "tick", "gemm_nn"]);
        f.add(&["main", "tick", "gemm_nn"]);
        f.add(&["main", "tick"]);
        f.add(&[]);
        assert_eq!(f.samples, 3);
        assert_eq!(f.distinct(), 2);
        assert_eq!(f.count("main;tick;gemm_nn"), 2);
        let text = f.render_folded();
        assert!(text.contains("main;tick;gemm_nn 2\n"), "{text}");
        assert!(text.contains("main;tick 1\n"), "{text}");
        // every line is `stack count`
        for line in text.lines() {
            let (_, n) = line.rsplit_once(' ').expect("stack count");
            n.parse::<u64>().expect("count is a number");
        }
    }

    #[test]
    fn kernel_achieved_never_exceeds_peak() {
        let mut k = KernelStats::default();
        // one fast call, one slow call: achieved sits between them
        k.record("gemm_nn", Some("fwd"), 1_000_000, 500_000);
        k.record("gemm_nn", Some("decode"), 1_000_000, 2_000_000);
        k.record("gemm_nt", None, 10, 0); // zero-duration guard
        let agg = k.core("gemm_nn").unwrap();
        assert_eq!(agg.calls, 2);
        assert_eq!(agg.flops, 4_000_000);
        assert!(agg.achieved_gflops() <= agg.peak_gflops);
        assert!(agg.achieved_gflops() > 0.0);
        let nt = k.core("gemm_nt").unwrap();
        assert!(nt.achieved_gflops().is_finite());
        assert!(nt.achieved_gflops() <= nt.peak_gflops);
    }

    #[test]
    fn roofline_json_parses_and_orders_cores() {
        let mut k = KernelStats::default();
        k.record("gemm_nn", Some("fwd_bwd"), 500, 1000);
        k.record("gemm_tn", Some("fwd_bwd"), 500, 1000);
        let doc = crate::util::json::Json::parse(&k.render_roofline_json()).unwrap();
        let cores = doc.arr_field("cores").unwrap();
        assert_eq!(cores.len(), 2);
        for c in cores {
            let achieved = c.f64_field("gflops_achieved").unwrap();
            let peak = c.f64_field("gflops_peak").unwrap();
            assert!(achieved <= peak + 1e-9);
            let modules = c.arr_field("modules").unwrap();
            assert_eq!(modules[0].str_field("module").unwrap(), "fwd_bwd");
            assert!(modules[0].f64_field("flop_share").unwrap() > 0.99);
        }
    }
}
