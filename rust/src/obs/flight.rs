//! Crash-forensics flight recorder: a fixed-size lock-free ring of
//! recent structured events, dumped to JSON when something dies.
//!
//! The spans/metrics/timeline stack answers *what happened* over a
//! whole run; the flight recorder answers *what the system was doing
//! right before it died*. Every interesting transition — span
//! open/close digests, scheduler ticks, admission/cancel decisions,
//! pool dispatches, metric deltas — lands as one [`FlightEvent`] in a
//! ring of [`CAP`] slots. The ring never grows, never locks, and
//! never allocates after first touch: recording is one `fetch_add` to
//! claim a sequence number, one CAS to claim the slot's stamp, then a
//! handful of relaxed stores closed by a release store of the even
//! stamp (the same seqlock discipline as `span::PubStack`, the
//! profiler's published stack mirror, hardened for multiple writers).
//!
//! Dumps happen on four triggers:
//!
//! - **panic** — [`install_panic_hook`] chains the previous hook and
//!   writes the ring to the configured path before the default hook
//!   prints the backtrace;
//! - **fuzz failure** — [`crate::fuzz::run_target`] writes a dump next
//!   to its seed-replay line, so every violation ships its forensics;
//! - **on demand** — the `--flight-out FILE` flag dumps at process
//!   exit (`finish_obs`), pass or fail;
//! - **programmatic** — [`dump_json`] / [`dump_to`] for tests and
//!   embedders.
//!
//! Like every obs facility here, recording is computation-read-only:
//! events carry clock readings and counters, never tensor data, so
//! all bit-parity suites pass with the recorder on. Off (the default)
//! costs one relaxed atomic load per call site. Memory bound: the
//! ring is `CAP` slots × 9 machine words ≈ 288 KiB, allocated once at
//! the first enabled record and never freed or grown.
//!
//! ## Torn slots
//!
//! At most one writer ever owns a slot: a claimant must CAS the stamp
//! from a *completed* (even, older) value to its own odd value, so a
//! wrap-around racer (≥ [`CAP`] records between one writer's claim
//! and its final store) fails the CAS and drops its event instead of
//! interleaving stores with the in-flight writer. Readers additionally
//! re-check the stamp after copying the fields, dropping any slot
//! whose owner was still mid-write. A dump may therefore miss a
//! handful of in-flight events but can never contain a fabricated or
//! mixed one.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{Context, Result};

/// Ring capacity (power of two). Sized so a dump comfortably covers
/// the ≥ 256 most recent scheduler/span operations the forensics
/// contract promises, with slack for chatty phases.
pub const CAP: usize = 4096;
const MASK: u64 = (CAP as u64) - 1;

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Next sequence number to claim; total events ever recorded.
static HEAD: AtomicU64 = AtomicU64::new(0);

/// `MISA_FLIGHT` is folded in exactly once, before the first
/// enabled-check; later [`enable`]/[`disable`] calls override it.
fn env_init() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("MISA_FLIGHT") {
            let v = v.trim();
            if !v.is_empty() && v != "0" {
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
    });
}

/// Whether [`record`] is currently keeping the ring (off by default;
/// `MISA_FLIGHT=1` or `--flight-out` turn it on).
pub fn enabled() -> bool {
    env_init();
    ENABLED.load(Ordering::Relaxed)
}

/// Switch recording on (idempotent). The ring keeps whatever it
/// already held.
pub fn enable() {
    env_init();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Switch recording off; the ring contents stay readable.
pub fn disable() {
    env_init();
    ENABLED.store(false, Ordering::Relaxed);
}

/// One ring slot. The stamp encodes both a seqlock phase and the
/// owning sequence number: `2·seq + 1` while the claimant is writing,
/// `2·seq + 2` once the fields are that claim's. `0` means
/// never written.
struct Slot {
    stamp: AtomicU64,
    t_us: AtomicU64,
    tid: AtomicU64,
    kind_ptr: AtomicUsize,
    kind_len: AtomicUsize,
    name_ptr: AtomicUsize,
    name_len: AtomicUsize,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    const fn new() -> Self {
        Slot {
            stamp: AtomicU64::new(0),
            t_us: AtomicU64::new(0),
            tid: AtomicU64::new(0),
            kind_ptr: AtomicUsize::new(0),
            kind_len: AtomicUsize::new(0),
            name_ptr: AtomicUsize::new(0),
            name_len: AtomicUsize::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

fn ring() -> &'static [Slot] {
    static RING: OnceLock<Vec<Slot>> = OnceLock::new();
    RING.get_or_init(|| (0..CAP).map(|_| Slot::new()).collect())
}

/// Append one event to the ring. `kind` is a coarse channel
/// (`"span_open"`, `"span_close"`, `"sched"`, `"pool"`, `"metric"`),
/// `name` the specific operation or object, `a`/`b` two
/// kind-dependent payload words (depth/duration, request id/cost,
/// ...). No-op while disabled. Both strings must be `'static` —
/// readers reconstruct them from raw `(ptr, len)` pairs.
pub fn record(kind: &'static str, name: &'static str, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let seq = HEAD.fetch_add(1, Ordering::Relaxed);
    let slot = &ring()[(seq & MASK) as usize];
    // Claim the slot before touching any field. A plain store would
    // let a wrap-around racer (≥ CAP claims behind or ahead of us)
    // write the same slot concurrently, interleaving fields from two
    // records behind a self-consistent stamp. The CAS admits exactly
    // one writer: it only succeeds from a *completed* (even) stamp
    // that is older than our claim. `cur` odd means another claimant
    // is mid-write; `cur > 2·seq + 1` means the slot was already
    // recycled by a newer claim. Either way we drop the event — a
    // dump may miss it but can never mix two records.
    let cur = slot.stamp.load(Ordering::Relaxed);
    if cur & 1 == 1
        || cur > 2 * seq + 1
        || slot
            .stamp
            .compare_exchange(cur, 2 * seq + 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
    {
        return;
    }
    // Order the odd stamp before every field store: without the fence
    // the relaxed stores below may become visible *before* the stamp
    // turns odd on weakly-ordered targets, letting a reader validate
    // torn fields against the old even stamp.
    std::sync::atomic::fence(Ordering::Release);
    slot.t_us.store(crate::obs::span::now_us(), Ordering::Relaxed);
    slot.tid.store(crate::obs::span::thread_id(), Ordering::Relaxed);
    slot.kind_ptr.store(kind.as_ptr() as usize, Ordering::Relaxed);
    slot.kind_len.store(kind.len(), Ordering::Relaxed);
    slot.name_ptr.store(name.as_ptr() as usize, Ordering::Relaxed);
    slot.name_len.store(name.len(), Ordering::Relaxed);
    slot.a.store(a, Ordering::Relaxed);
    slot.b.store(b, Ordering::Relaxed);
    slot.stamp.store(2 * seq + 2, Ordering::Release); // even: complete
}

/// Total sequence numbers ever claimed (including events already
/// overwritten and the rare wrap-race drops).
pub fn recorded() -> u64 {
    HEAD.load(Ordering::Relaxed)
}

/// One decoded ring entry.
#[derive(Clone, Debug)]
pub struct FlightEvent {
    /// Global record ordinal (monotone; gaps mean torn slots).
    pub seq: u64,
    /// Microseconds since the trace epoch.
    pub t_us: u64,
    /// Dense span thread-id of the recording thread.
    pub tid: u64,
    /// Event channel (`"span_open"`, `"sched"`, ...).
    pub kind: &'static str,
    /// Operation or object name.
    pub name: &'static str,
    /// First payload word (kind-dependent).
    pub a: u64,
    /// Second payload word (kind-dependent).
    pub b: u64,
}

/// Snapshot every consistent slot, oldest first. Concurrent writers
/// may tear a few slots (skipped, see module docs); the result is
/// still strictly ordered by sequence number.
pub fn snapshot() -> Vec<FlightEvent> {
    let mut out = Vec::with_capacity(CAP);
    for slot in ring() {
        let s1 = slot.stamp.load(Ordering::Acquire);
        if s1 == 0 || s1 & 1 == 1 {
            continue;
        }
        let t_us = slot.t_us.load(Ordering::Relaxed);
        let tid = slot.tid.load(Ordering::Relaxed);
        let kp = slot.kind_ptr.load(Ordering::Relaxed);
        let kl = slot.kind_len.load(Ordering::Relaxed);
        let np = slot.name_ptr.load(Ordering::Relaxed);
        let nl = slot.name_len.load(Ordering::Relaxed);
        let a = slot.a.load(Ordering::Relaxed);
        let b = slot.b.load(Ordering::Relaxed);
        // Keep the relaxed field loads above from sinking below the
        // validating stamp re-read (an acquire *load* alone does not
        // pin earlier loads before it on weakly-ordered targets).
        std::sync::atomic::fence(Ordering::Acquire);
        if slot.stamp.load(Ordering::Acquire) != s1 || kp == 0 || np == 0 {
            continue; // a writer raced us — drop the slot
        }
        // SAFETY: the stamp was even and unchanged across the field
        // reads, so each (ptr, len) pair is exactly what one `record`
        // stored from a `&'static str`; reconstructing reads 'static
        // memory (the same argument as `PubStack::sample`).
        let (kind, name) = unsafe {
            (
                std::str::from_utf8_unchecked(std::slice::from_raw_parts(kp as *const u8, kl)),
                std::str::from_utf8_unchecked(std::slice::from_raw_parts(np as *const u8, nl)),
            )
        };
        out.push(FlightEvent { seq: s1 / 2 - 1, t_us, tid, kind, name, a, b });
    }
    out.sort_by_key(|e| e.seq);
    out
}

/// Render the current ring as a JSON document:
/// `{"cap", "recorded", "events": [{seq, t_us, tid, kind, name, a, b}]}`.
/// Events are oldest-first; `recorded` minus the highest `seq + 1`
/// tells a reader how many events were overwritten or torn.
pub fn dump_json() -> String {
    render_json(&snapshot())
}

fn render_json(events: &[FlightEvent]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push_str(&format!(
        "{{\"cap\":{CAP},\"recorded\":{},\"events\":[",
        recorded()
    ));
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n{{\"seq\":{},\"t_us\":{},\"tid\":{},\"kind\":\"{}\",\"name\":\"{}\",\"a\":{},\"b\":{}}}",
            e.seq,
            e.t_us,
            e.tid,
            crate::util::bench::escape(e.kind),
            crate::util::bench::escape(e.name),
            e.a,
            e.b,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// Write [`dump_json`] to `path`; returns the number of events
/// written.
pub fn dump_to(path: &Path) -> Result<usize> {
    let events = snapshot();
    std::fs::write(path, render_json(&events))
        .with_context(|| format!("writing flight dump {path:?}"))?;
    Ok(events.len())
}

fn configured() -> &'static Mutex<Option<PathBuf>> {
    static PATH: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    PATH.get_or_init(|| {
        Mutex::new(std::env::var_os("MISA_FLIGHT_OUT").map(PathBuf::from))
    })
}

/// Set the dump destination used by the panic hook and the fuzz
/// failure path (the `--flight-out` flag; `MISA_FLIGHT_OUT` seeds it).
pub fn set_dump_path(path: &Path) {
    *configured().lock().unwrap_or_else(|e| e.into_inner()) = Some(path.to_path_buf());
}

/// The configured dump destination, if any.
pub fn dump_path() -> Option<PathBuf> {
    configured().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Write the ring to the configured path (no-op returning `None` when
/// recording is off or no path was configured). Returns the path and
/// the number of events written on success; I/O failures are
/// swallowed — forensics must never turn a diagnosable failure into a
/// different one.
pub fn dump_to_configured() -> Option<(PathBuf, usize)> {
    if !enabled() {
        return None;
    }
    let path = dump_path()?;
    dump_to(&path).ok().map(|n| (path, n))
}

/// Install a panic hook (once per process) that writes the ring to
/// the configured dump path before chaining to the previous hook, so
/// every panic ships its own black box. Safe to call repeatedly; the
/// hook itself never panics and does nothing while recording is off
/// or no path is set.
pub fn install_panic_hook() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if let Some((path, events)) = dump_to_configured() {
                eprintln!("flight dump: {} ({events} events)", path.display());
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // Flight state is process-global; serialize with every other test
    // that toggles obs flags.
    use crate::obs::span::TEST_GATE as GATE;

    #[test]
    fn disabled_recording_is_a_noop() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        disable();
        let before = recorded();
        record("test", "noop", 1, 2);
        assert_eq!(recorded(), before);
    }

    #[test]
    fn ring_keeps_the_most_recent_events_in_order() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        enable();
        // overfill: only the newest CAP survive
        for i in 0..(CAP as u64 + 123) {
            record("test", "fill", i, i * 2);
        }
        disable();
        let evs = snapshot();
        assert_eq!(evs.len(), CAP);
        // strictly ascending, contiguous sequence numbers (quiescent
        // ring: no torn slots survive)
        for w in evs.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1);
        }
        assert_eq!(evs.last().unwrap().seq + 1, recorded());
        // payload words survive the round trip; filter to this test's
        // own events — concurrent lib tests may interleave span events
        // while the recorder is enabled
        let mine: Vec<_> = evs.iter().filter(|e| e.kind == "test" && e.name == "fill").collect();
        assert!(!mine.is_empty());
        let last = mine.last().unwrap();
        assert_eq!(last.b, last.a * 2);
    }

    #[test]
    fn dump_json_is_parseable_and_complete() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        enable();
        for i in 0..10u64 {
            record("test", "json", i, 0);
        }
        disable();
        let doc = crate::util::json::Json::parse(&dump_json()).unwrap();
        assert_eq!(doc.f64_field("cap").unwrap() as usize, CAP);
        let events = doc.arr_field("events").unwrap();
        assert!(!events.is_empty());
        let mut prev = -1.0;
        for e in events {
            let seq = e.f64_field("seq").unwrap();
            assert!(seq > prev, "events out of order");
            prev = seq;
            e.str_field("kind").unwrap();
            e.str_field("name").unwrap();
        }
    }

    #[test]
    fn concurrent_writers_never_produce_fabricated_events() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        enable();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    for i in 0..2000u64 {
                        record("test", "race", t, i);
                    }
                });
            }
        });
        disable();
        // foreign events from concurrently running tests may share the
        // ring; every event *we* wrote must round-trip intact
        let mine: Vec<_> =
            snapshot().into_iter().filter(|e| e.kind == "test" && e.name == "race").collect();
        assert!(!mine.is_empty());
        for e in &mine {
            assert!(e.a < 4 && e.b < 2000);
        }
    }
}
