//! Per-request lifecycle timelines and latency distributions.
//!
//! Every serving request carries a [`Timeline`] from enqueue to
//! completion: the scheduler stamps admission, prefill completion,
//! first token, every later emission, and finish. From those stamps
//! fall out the two latencies the serving roadmap cares about —
//! **TTFT** (submit → first token) and **ITL** (gap between emitted
//! tokens) — as raw sample vectors, so `bench-serve` reports exact
//! p50/p90/p99, not just means.
//!
//! ITL semantics under speculative decoding: a verify tick can emit
//! `n > 1` tokens at once; [`Timeline::emit`] then records the gap
//! divided by `n`, once per token. Every emitted token after the
//! first contributes exactly one sample, so spec on/off produce
//! comparable distributions (`samples == tokens - 1` either way).
//!
//! Timelines only read `Instant` — like spans, they cannot perturb
//! the deterministic token streams they annotate.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::metrics::percentile_exact;

/// Lifecycle stamps + inter-token gaps for one request.
#[derive(Clone, Debug)]
pub struct Timeline {
    /// When the request entered the scheduler queue.
    pub enqueued: Instant,
    /// When it was admitted to a slot (left the queue).
    pub admitted: Option<Instant>,
    /// When chunked prefill covered the full prompt.
    pub prefilled: Option<Instant>,
    /// When the first token was sampled.
    pub first_token: Option<Instant>,
    /// When the request was cancelled, if it was — a terminal stamp
    /// set together with `finished` by [`Timeline::cancel`], so a
    /// cancelled lifecycle closes as cleanly as a completed one and
    /// downstream pooling can tell the two apart.
    pub cancelled: Option<Instant>,
    /// When the request completed.
    pub finished: Option<Instant>,
    /// Per-token inter-token gaps in milliseconds (see module docs).
    pub itl_ms: Vec<f64>,
    last_emit: Option<Instant>,
}

impl Timeline {
    /// Start a timeline at `now` (request submission).
    pub fn start() -> Self {
        Timeline {
            enqueued: Instant::now(),
            admitted: None,
            prefilled: None,
            first_token: None,
            cancelled: None,
            finished: None,
            itl_ms: Vec::new(),
            last_emit: None,
        }
    }

    /// Stamp admission (idempotent: first call wins).
    pub fn admit(&mut self) {
        self.admitted.get_or_insert_with(Instant::now);
    }

    /// Stamp prefill completion (idempotent).
    pub fn prefill_done(&mut self) {
        self.prefilled.get_or_insert_with(Instant::now);
    }

    /// Stamp the first sampled token and arm the inter-token clock.
    pub fn mark_first_token(&mut self) {
        let now = Instant::now();
        self.first_token.get_or_insert(now);
        self.last_emit = Some(now);
    }

    /// Record the emission of `n >= 1` tokens in one tick: the gap
    /// since the previous emission, divided by `n`, recorded `n`
    /// times (no-op before [`Self::mark_first_token`]).
    pub fn emit(&mut self, n: usize) {
        let Some(prev) = self.last_emit else { return };
        if n == 0 {
            return;
        }
        let now = Instant::now();
        let gap_ms = now.saturating_duration_since(prev).as_secs_f64() * 1e3;
        let per_tok = gap_ms / n as f64;
        for _ in 0..n {
            self.itl_ms.push(per_tok);
        }
        self.last_emit = Some(now);
    }

    /// Stamp completion (idempotent).
    pub fn finish(&mut self) {
        self.finished.get_or_insert_with(Instant::now);
    }

    /// Terminate the lifecycle by cancellation: one instant stamps
    /// both `cancelled` and `finished` (idempotent), so a cancelled
    /// timeline still satisfies every ordering invariant and is
    /// distinguishable from a completed one via [`Self::was_cancelled`].
    pub fn cancel(&mut self) {
        let now = Instant::now();
        self.cancelled.get_or_insert(now);
        self.finished.get_or_insert(now);
    }

    /// Whether this lifecycle ended in cancellation.
    pub fn was_cancelled(&self) -> bool {
        self.cancelled.is_some()
    }

    /// Submit → first-token latency in milliseconds, if reached.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token
            .map(|t| t.saturating_duration_since(self.enqueued).as_secs_f64() * 1e3)
    }

    /// Check the ordering invariants: enqueued ≤ admitted ≤ prefilled
    /// ≤ first_token ≤ cancelled ≤ finished for every stamp present,
    /// a cancellation stamp only on a finished lifecycle, and no ITL
    /// samples without a first token.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.cancelled.is_none() || self.finished.is_some(),
            "timeline: cancelled but never finished"
        );
        let mut prev = ("enqueued", self.enqueued);
        for (name, stamp) in [
            ("admitted", self.admitted),
            ("prefilled", self.prefilled),
            ("first_token", self.first_token),
            ("cancelled", self.cancelled),
            ("finished", self.finished),
        ] {
            if let Some(t) = stamp {
                ensure!(t >= prev.1, "timeline: {name} precedes {}", prev.0);
                prev = (name, t);
            }
        }
        ensure!(
            self.itl_ms.is_empty() || self.first_token.is_some(),
            "timeline: ITL samples without a first token"
        );
        ensure!(
            self.itl_ms.iter().all(|&g| g >= 0.0 && g.is_finite()),
            "timeline: negative or non-finite ITL gap"
        );
        Ok(())
    }
}

/// Raw latency samples pooled across completed requests.
#[derive(Clone, Debug, Default)]
pub struct Latencies {
    /// One TTFT sample (ms) per completed request.
    pub ttft_ms: Vec<f64>,
    /// One ITL sample (ms) per emitted token after each request's
    /// first.
    pub itl_ms: Vec<f64>,
}

impl Latencies {
    /// Fold one completed request's timeline into the pool.
    pub fn absorb(&mut self, ttft_ms: Option<f64>, itl_ms: &[f64]) {
        if let Some(t) = ttft_ms {
            self.ttft_ms.push(t);
        }
        self.itl_ms.extend_from_slice(itl_ms);
    }

    /// Exact percentile summary of the TTFT samples.
    pub fn ttft(&self) -> LatencySummary {
        LatencySummary::of(&self.ttft_ms)
    }

    /// Exact percentile summary of the ITL samples.
    pub fn itl(&self) -> LatencySummary {
        LatencySummary::of(&self.itl_ms)
    }
}

/// Exact distribution summary over raw samples (rank `ceil(q·n)`,
/// the same convention the bucketed histograms approximate).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    /// Number of samples (all other fields are 0.0 when this is 0).
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Exact median.
    pub p50: f64,
    /// Exact 90th percentile.
    pub p90: f64,
    /// Exact 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl LatencySummary {
    /// Summarize `xs` (need not be sorted).
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return LatencySummary::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        LatencySummary {
            count: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: percentile_exact(&sorted, 0.50),
            p90: percentile_exact(&sorted, 0.90),
            p99: percentile_exact(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamps_preserve_ordering() {
        let mut tl = Timeline::start();
        tl.admit();
        tl.prefill_done();
        tl.mark_first_token();
        tl.emit(1);
        tl.emit(3);
        tl.finish();
        tl.validate().unwrap();
        assert_eq!(tl.itl_ms.len(), 4);
        assert!(tl.ttft_ms().unwrap() >= 0.0);
    }

    #[test]
    fn emit_before_first_token_is_noop() {
        let mut tl = Timeline::start();
        tl.emit(5);
        assert!(tl.itl_ms.is_empty());
        tl.validate().unwrap();
    }

    #[test]
    fn stamps_are_idempotent() {
        let mut tl = Timeline::start();
        tl.admit();
        let first = tl.admitted;
        std::thread::sleep(std::time::Duration::from_millis(1));
        tl.admit();
        assert_eq!(tl.admitted, first);
    }

    #[test]
    fn multi_token_emit_splits_gap() {
        let mut tl = Timeline::start();
        tl.mark_first_token();
        std::thread::sleep(std::time::Duration::from_millis(2));
        tl.emit(4);
        assert_eq!(tl.itl_ms.len(), 4);
        let g = tl.itl_ms[0];
        assert!(tl.itl_ms.iter().all(|&x| (x - g).abs() < 1e-12));
        assert!(g > 0.0);
    }

    #[test]
    fn cancel_terminates_cleanly_at_every_stage() {
        // queued-only cancellation
        let mut tl = Timeline::start();
        tl.cancel();
        assert!(tl.was_cancelled());
        assert!(tl.finished.is_some());
        tl.validate().unwrap();
        // mid-decode cancellation keeps every earlier stamp ordered
        let mut tl = Timeline::start();
        tl.admit();
        tl.prefill_done();
        tl.mark_first_token();
        tl.emit(2);
        tl.cancel();
        tl.validate().unwrap();
        assert!(tl.ttft_ms().is_some());
        assert_eq!(tl.itl_ms.len(), 2);
        // idempotent: a second cancel (or finish) changes nothing
        let stamped = tl.cancelled;
        tl.cancel();
        tl.finish();
        assert_eq!(tl.cancelled, stamped);
    }

    #[test]
    fn cancelled_without_finish_is_invalid() {
        let mut tl = Timeline::start();
        tl.cancelled = Some(Instant::now());
        assert!(tl.validate().is_err());
        tl.finish();
        tl.validate().unwrap();
    }

    #[test]
    fn summary_is_exact_on_known_samples() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-12);
        let empty = LatencySummary::of(&[]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.p99, 0.0);
    }

    #[test]
    fn latencies_pool_absorbs() {
        let mut lat = Latencies::default();
        lat.absorb(Some(10.0), &[1.0, 2.0]);
        lat.absorb(None, &[3.0]);
        assert_eq!(lat.ttft_ms.len(), 1);
        assert_eq!(lat.itl_ms.len(), 3);
        assert_eq!(lat.itl().count, 3);
    }
}
