//! The metrics registry: counters, gauges, and log-bucketed latency
//! histograms with percentile extraction, plus the Prometheus-style
//! text exporter.
//!
//! One process-global registry (serde-free, hand-rolled like the rest
//! of `util`) collects everything the instrumented hot paths emit.
//! Recording is a name lookup plus an integer update under one mutex —
//! cheap against the multi-millisecond forwards it measures, and
//! deliberately *outside* every numeric code path so instrumentation
//! can never perturb a result (the bit-parity suites re-run with it
//! fully enabled).
//!
//! Histograms are log-bucketed: 256 geometric buckets growing by
//! `2^(1/8)` (~9%) per bucket from `1e-3`, so one histogram spans
//! microsecond spikes to minute-long stalls when fed milliseconds.
//! [`Histogram::percentile`] returns the geometric midpoint of the
//! bucket holding the requested rank — within one bucket ratio
//! (≤ ~4.4%) of the exact order statistic, which `rust/tests/obs.rs`
//! pins against a sorted-vec oracle. Exact percentiles over raw
//! samples (the serving TTFT/ITL report) go through
//! [`percentile_exact`] instead.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Smallest bucketed histogram value; below it samples land in the
/// underflow bucket and percentiles report the observed minimum.
const HIST_MIN: f64 = 1e-3;
/// Number of geometric buckets.
const HIST_BUCKETS: usize = 256;
/// Buckets per doubling: bucket width is `2^(1/8)` (~9% growth).
const BUCKETS_PER_OCTAVE: f64 = 8.0;

/// A log-bucketed histogram of non-negative samples (unit-agnostic;
/// the serving and training paths feed milliseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    underflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            underflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index of `v`, or `None` for the underflow bucket.
    fn bucket(v: f64) -> Option<usize> {
        if v < HIST_MIN {
            return None;
        }
        let i = ((v / HIST_MIN).log2() * BUCKETS_PER_OCTAVE).floor();
        Some((i.max(0.0) as usize).min(HIST_BUCKETS - 1))
    }

    /// Geometric midpoint of bucket `i` — the value [`Self::percentile`]
    /// reports for ranks landing in it.
    fn representative(i: usize) -> f64 {
        HIST_MIN * 2f64.powf((i as f64 + 0.5) / BUCKETS_PER_OCTAVE)
    }

    /// Upper bound of bucket `i` (Prometheus `le` label).
    fn upper(i: usize) -> f64 {
        HIST_MIN * 2f64.powf((i as f64 + 1.0) / BUCKETS_PER_OCTAVE)
    }

    /// Record one sample. Negative and NaN samples are counted in the
    /// underflow bucket rather than dropped silently.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        match Self::bucket(v) {
            Some(i) => self.counts[i] += 1,
            None => self.underflow += 1,
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all finite samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest finite sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 || !self.min.is_finite() {
            0.0
        } else {
            self.min
        }
    }

    /// Largest finite sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 || !self.max.is_finite() {
            0.0
        } else {
            self.max
        }
    }

    /// Mean of all finite samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// The `q`-quantile (`0.0..=1.0`): the geometric midpoint of the
    /// bucket holding the rank-`ceil(q·count)` sample, clamped to the
    /// observed `[min, max]`. Within one bucket ratio (`2^(1/8)`,
    /// ~9%; midpoint error ≤ ~4.4%) of the exact order statistic —
    /// test-pinned against a sorted-vec oracle. Returns 0.0 when
    /// empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank <= self.underflow {
            return self.min();
        }
        let mut cum = self.underflow;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank <= cum {
                return Self::representative(i).clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// The interval histogram `self − earlier`: bucketwise count
    /// difference (saturating, so a registry reset between snapshots
    /// degrades to an empty interval instead of underflowing). `sum`
    /// subtracts exactly; `min`/`max` are *approximated* from the
    /// interval's populated bucket edges (the exact extrema of only
    /// the interval's samples are not recoverable from bucket counts),
    /// so interval percentiles keep the same one-bucket error bound as
    /// live ones.
    fn delta_from(&self, earlier: &Histogram) -> Histogram {
        let mut d = Histogram::new();
        for i in 0..HIST_BUCKETS {
            d.counts[i] = self.counts[i].saturating_sub(earlier.counts[i]);
        }
        d.underflow = self.underflow.saturating_sub(earlier.underflow);
        d.count = self.count.saturating_sub(earlier.count);
        d.sum = if d.count == 0 { 0.0 } else { self.sum - earlier.sum };
        if d.underflow > 0 {
            d.min = 0.0;
            d.max = HIST_MIN;
        }
        for (i, &c) in d.counts.iter().enumerate() {
            if c > 0 {
                // lower edge of the first populated bucket...
                d.min = d.min.min(HIST_MIN * 2f64.powf(i as f64 / BUCKETS_PER_OCTAVE));
                // ...upper edge of the last one
                d.max = d.max.max(Self::upper(i));
            }
        }
        d
    }

    /// `(upper_bound, cumulative_count)` for every non-empty bucket,
    /// ascending — the Prometheus exposition shape.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = self.underflow;
        if self.underflow > 0 {
            out.push((HIST_MIN, cum));
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((Self::upper(i), cum));
            }
        }
        out
    }
}

/// Exact `q`-quantile of an ascending-sorted slice: the sample at rank
/// `ceil(q·n)` (1-based, clamped) — the same rank convention
/// [`Histogram::percentile`] approximates. Returns 0.0 when empty.
pub fn percentile_exact(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// A stats struct that can publish itself into the registry as flat
/// `(name, value)` gauges — `CacheStats` and `SpecStats` implement
/// this, so the serving counters land in the same Prometheus dump as
/// the histograms.
pub trait MetricSource {
    /// Flat, fully-namespaced `(name, value)` pairs (e.g.
    /// `serve.cache.hits`).
    fn metric_kvs(&self) -> Vec<(String, f64)>;
}

/// Publish every key of a [`MetricSource`] as a gauge.
pub fn publish(src: &dyn MetricSource) {
    for (k, v) in src.metric_kvs() {
        gauge_set(&k, v);
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

fn registry() -> &'static Mutex<Inner> {
    static REG: OnceLock<Mutex<Inner>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Inner::default()))
}

fn with_registry<T>(f: impl FnOnce(&mut Inner) -> T) -> T {
    let mut g = registry().lock().unwrap_or_else(|e| e.into_inner());
    f(&mut g)
}

/// Add `delta` to the named monotonic counter (created at 0).
pub fn counter_add(name: &str, delta: u64) {
    with_registry(|r| *r.counters.entry(name.to_string()).or_insert(0) += delta);
}

/// Current value of a counter (0 if never written).
pub fn counter(name: &str) -> u64 {
    with_registry(|r| r.counters.get(name).copied().unwrap_or(0))
}

/// Set the named gauge to `v` (last write wins).
pub fn gauge_set(name: &str, v: f64) {
    with_registry(|r| {
        r.gauges.insert(name.to_string(), v);
    });
}

/// Current value of a gauge, if ever written.
pub fn gauge(name: &str) -> Option<f64> {
    with_registry(|r| r.gauges.get(name).copied())
}

/// Record one sample into the named histogram (created empty).
pub fn observe(name: &str, v: f64) {
    with_registry(|r| r.hists.entry(name.to_string()).or_default().observe(v));
}

/// Snapshot of the named histogram, if ever written.
pub fn histogram(name: &str) -> Option<Histogram> {
    with_registry(|r| r.hists.get(name).cloned())
}

/// Clear every counter, gauge and histogram (tests, bench re-runs).
pub fn reset() {
    with_registry(|r| *r = Inner::default());
}

/// A point-in-time copy of the whole registry. Two snapshots bracket
/// an interval; [`Snapshot::delta`] recovers exactly what happened in
/// between, so windowed reporting doesn't need process-lifetime
/// counters or a disruptive [`reset`].
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

/// Capture the registry as it is right now.
pub fn snapshot() -> Snapshot {
    with_registry(|r| Snapshot {
        counters: r.counters.clone(),
        gauges: r.gauges.clone(),
        hists: r.hists.clone(),
    })
}

impl Snapshot {
    /// Counter value at capture time (0 if never written).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value at capture time, if ever written.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram at capture time, if ever written.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// The interval `self − earlier`: counters subtract (saturating),
    /// histograms subtract bucketwise (see `Histogram::delta_from`),
    /// and gauges keep `self`'s point-in-time values — a gauge is a
    /// level, not a flow, so "activity between snapshots" means its
    /// latest reading. Names absent from `earlier` are treated as
    /// starting from zero/empty.
    pub fn delta(&self, earlier: &Snapshot) -> Snapshot {
        let empty = Histogram::new();
        Snapshot {
            counters: self
                .counters
                .iter()
                .map(|(k, &v)| {
                    (k.clone(), v.saturating_sub(earlier.counter(k)))
                })
                .collect(),
            gauges: self.gauges.clone(),
            hists: self
                .hists
                .iter()
                .map(|(k, h)| {
                    (k.clone(), h.delta_from(earlier.hists.get(k).unwrap_or(&empty)))
                })
                .collect(),
        }
    }
}

/// Sanitize a metric name into the Prometheus charset and prefix it
/// with `misa_` (dots and dashes become underscores).
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("misa_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn prom_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "NaN".to_string()
    } else if v > 0.0 {
        "+Inf".to_string()
    } else {
        "-Inf".to_string()
    }
}

/// Render the whole registry as a Prometheus-style text exposition:
/// `# TYPE` lines, counters and gauges as bare samples, histograms as
/// cumulative `_bucket{le="..."}` series plus `_sum`/`_count`, and
/// quantile gauges (`p50`/`p90`/`p99`) precomputed for dashboards
/// without a quantile engine.
pub fn prometheus_dump() -> String {
    with_registry(|r| {
        let mut out = String::new();
        for (k, v) in &r.counters {
            let n = prom_name(k);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (k, v) in &r.gauges {
            let n = prom_name(k);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", prom_f64(*v)));
        }
        for (k, h) in &r.hists {
            let n = prom_name(k);
            out.push_str(&format!("# TYPE {n} histogram\n"));
            for (le, cum) in h.cumulative_buckets() {
                out.push_str(&format!("{n}_bucket{{le=\"{}\"}} {cum}\n", prom_f64(le)));
            }
            out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{n}_sum {}\n", prom_f64(h.sum())));
            out.push_str(&format!("{n}_count {}\n", h.count()));
            for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                out.push_str(&format!(
                    "{n}_quantile{{q=\"{label}\"}} {}\n",
                    prom_f64(h.percentile(q))
                ));
            }
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that reset or read it
    // serialize through one mutex so they can't clobber each other.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn histogram_counts_and_moments() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 10.0).abs() < 1e-12);
        assert!((h.mean() - 2.5).abs() < 1e-12);
        assert!((h.min() - 1.0).abs() < 1e-12);
        assert!((h.max() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentile_brackets_exact_value() {
        let mut h = Histogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.37).collect();
        for &v in &xs {
            h.observe(v);
        }
        let bucket_ratio = 2f64.powf(1.0 / BUCKETS_PER_OCTAVE);
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            let exact = percentile_exact(&xs, q); // xs is already ascending
            let approx = h.percentile(q);
            assert!(
                approx <= exact * bucket_ratio * 1.0001
                    && approx >= exact / bucket_ratio / 1.0001,
                "q={q}: approx {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn histogram_edge_cases() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.count(), 0);
        let mut h = Histogram::new();
        h.observe(0.0); // underflow bucket
        h.observe(-1.0); // negative: counted, not dropped
        h.observe(f64::NAN); // non-finite: counted, excluded from moments
        assert_eq!(h.count(), 3);
        assert_eq!(h.percentile(0.5), h.min());
        let mut h = Histogram::new();
        h.observe(42.0);
        // a single sample clamps every quantile to itself
        assert!((h.percentile(0.5) - 42.0).abs() < 1e-12);
        assert!((h.percentile(0.99) - 42.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_exact_matches_rank_convention() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_exact(&xs, 0.5), 3.0);
        assert_eq!(percentile_exact(&xs, 0.0), 1.0);
        assert_eq!(percentile_exact(&xs, 1.0), 5.0);
        assert_eq!(percentile_exact(&[], 0.5), 0.0);
    }

    #[test]
    fn registry_counters_gauges_histograms() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        counter_add("t.count", 2);
        counter_add("t.count", 3);
        assert_eq!(counter("t.count"), 5);
        gauge_set("t.gauge", 1.5);
        assert_eq!(gauge("t.gauge"), Some(1.5));
        observe("t.lat", 10.0);
        observe("t.lat", 20.0);
        let h = histogram("t.lat").unwrap();
        assert_eq!(h.count(), 2);
        reset();
        assert_eq!(counter("t.count"), 0);
        assert!(histogram("t.lat").is_none());
    }

    #[test]
    fn prometheus_dump_is_well_formed() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        counter_add("t.reqs", 7);
        gauge_set("t.depth", 3.0);
        observe("t.ms", 5.0);
        observe("t.ms", 50.0);
        let dump = prometheus_dump();
        assert!(dump.contains("# TYPE misa_t_reqs counter"), "{dump}");
        assert!(dump.contains("misa_t_reqs 7"), "{dump}");
        assert!(dump.contains("# TYPE misa_t_depth gauge"), "{dump}");
        assert!(dump.contains("# TYPE misa_t_ms histogram"), "{dump}");
        assert!(dump.contains("misa_t_ms_count 2"), "{dump}");
        assert!(dump.contains("_bucket{le=\"+Inf\"} 2"), "{dump}");
        assert!(dump.contains("misa_t_ms_quantile{q=\"0.99\"}"), "{dump}");
        // cumulative bucket counts are ascending
        let mut last = 0u64;
        for line in dump.lines().filter(|l| l.starts_with("misa_t_ms_bucket")) {
            let c: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(c >= last, "{dump}");
            last = c;
        }
        reset();
    }

    #[test]
    fn snapshot_delta_equals_interval_activity() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        // deltas are insensitive to whatever state preceded the first
        // snapshot, so no reset() — unique names avoid cross-talk
        counter_add("t.snap.c", 10);
        observe("t.snap.h", 4.0);
        gauge_set("t.snap.g", 1.0);
        let s1 = snapshot();
        counter_add("t.snap.c", 3);
        counter_add("t.snap.new", 2); // born inside the interval
        observe("t.snap.h", 8.0);
        observe("t.snap.h", 16.0);
        gauge_set("t.snap.g", 7.5);
        let s2 = snapshot();
        let d = s2.delta(&s1);
        // counters: exactly the interval's increments
        assert_eq!(d.counter("t.snap.c"), 3);
        assert_eq!(d.counter("t.snap.new"), 2);
        assert_eq!(d.counter("t.snap.never"), 0);
        // gauges: the later point-in-time level
        assert_eq!(d.gauge("t.snap.g"), Some(7.5));
        // histograms: only the interval's samples
        let h = d.histogram("t.snap.h").unwrap();
        assert_eq!(h.count(), 2);
        assert!((h.sum() - 24.0).abs() < 1e-12, "{}", h.sum());
        assert!((h.mean() - 12.0).abs() < 1e-12);
        // interval percentiles keep the one-bucket error bound
        let ratio = 2f64.powf(1.0 / BUCKETS_PER_OCTAVE);
        let p = h.percentile(1.0);
        assert!(p <= 16.0 * ratio && p >= 16.0 / ratio, "p100 {p}");
        let p = h.percentile(0.5);
        assert!(p <= 8.0 * ratio && p >= 8.0 / ratio, "p50 {p}");
        // an idle interval deltas to zero activity
        let s3 = snapshot();
        let idle = s3.delta(&s2);
        assert_eq!(idle.counter("t.snap.c"), 0);
        assert_eq!(idle.histogram("t.snap.h").unwrap().count(), 0);
        assert_eq!(idle.histogram("t.snap.h").unwrap().percentile(0.9), 0.0);
    }

    #[test]
    fn metric_source_publishes_gauges() {
        struct S;
        impl MetricSource for S {
            fn metric_kvs(&self) -> Vec<(String, f64)> {
                vec![("t.src.a".to_string(), 1.0), ("t.src.b".to_string(), 2.0)]
            }
        }
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        publish(&S);
        assert_eq!(gauge("t.src.a"), Some(1.0));
        assert_eq!(gauge("t.src.b"), Some(2.0));
        reset();
    }
}
