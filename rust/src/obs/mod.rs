//! Observability: scoped spans, metrics, per-request timelines,
//! leveled logging — zero dependencies, deterministic by
//! construction.
//!
//! Four pieces, one design rule — **instrumentation reads clocks and
//! counters, never the computation**, so every bit-parity invariant
//! in the repo holds with observability fully enabled:
//!
//! - [`span`] — hierarchical RAII spans on the hot paths (GEMM
//!   dispatch, backend forwards, scheduler phases, trainer steps),
//!   exported as Chrome trace-event JSON (`--trace-out`, Perfetto).
//!   Off by default; one relaxed atomic load per disabled call site.
//! - [`metrics`] — process-global registry of counters, gauges and
//!   log-bucketed latency histograms with p50/p90/p99 extraction;
//!   Prometheus-style text export (`--metrics-out`). `CacheStats`
//!   and `SpecStats` publish into it as [`MetricSource`]s.
//! - [`timeline`] — per-request lifecycle stamps (enqueue → admit →
//!   prefill → first token → finish) and exact TTFT/ITL percentile
//!   summaries for `bench-serve` and `generate`.
//! - [`logger`] — `MISA_LOG`-leveled stderr logging replacing raw
//!   `eprintln!` diagnostics; timestamps opt-in (`MISA_LOG_TS=1`) so
//!   test output stays stable.
//! - [`optstats`] — module-sampling telemetry for the training path:
//!   per-module importance scores, empirical vs. target sampling
//!   frequencies (chi-square drift), and the online single-draw
//!   gradient-variance estimator pricing MISA's distribution against
//!   the uniform layer-wise counterfactual from the same norms
//!   (`train --report-out`, `bench --variance-report`).
//! - [`memory`] — byte-accounting gauges: optimizer-state residency,
//!   activation scratch, COW-deduplicated KV-cache bytes, process
//!   RSS/HWM high-water marks.
//! - [`profile`] — sampling wall-clock profiler: a background thread
//!   snapshots every thread's seqlock-published span stack at
//!   `MISA_PROF_HZ`, folding hits into flame-graph counts, and the
//!   GEMM cores time themselves against their known MAC counts.
//! - [`flame`] — exporters for the profiler: folded stacks
//!   (`--profile-out`, flamegraph.pl / speedscope) and per-core ×
//!   per-module roofline JSON (`--roofline-out`, achieved vs
//!   empirical-peak GFLOP/s).
//! - [`flight`] — crash-forensics flight recorder: a fixed lock-free
//!   ring of recent structured events (span digests, scheduler ops,
//!   pool dispatches) dumped to JSON on panic, fuzz failure, or
//!   `--flight-out`.
//!
//! See DESIGN.md §7 "Observability architecture" for the span model,
//! overhead budget, and exporter formats, §8 "Training telemetry"
//! for the variance-estimator math and memory categories, and §10
//! "Profiling & forensics" for the sampler, roofline, and
//! flight-ring designs.

pub mod flame;
pub mod flight;
pub mod logger;
pub mod memory;
pub mod metrics;
pub mod optstats;
pub mod profile;
pub mod span;
pub mod timeline;

pub use flame::{FoldedStacks, KernelStats};
pub use flight::FlightEvent;
pub use logger::Level;
pub use memory::MemCategory;
pub use metrics::{percentile_exact, Histogram, MetricSource};
pub use optstats::{TrainReport, VarianceEstimator, VarianceSample};
pub use profile::ProfileReport;
pub use span::{SpanEvent, SpanGuard};
pub use timeline::{Latencies, LatencySummary, Timeline};
