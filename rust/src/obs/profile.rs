//! Sampling wall-clock profiler piggybacking on the span machinery.
//!
//! A background thread (`misa-prof`) wakes at a configurable rate and
//! snapshots every registered thread's *published span stack* (the
//! seqlock mirrors `span.rs` maintains while profiling is on — see
//! its "Stack publication" docs). Each consistent snapshot folds into
//! a process-global [`FoldedStacks`] accumulator; torn snapshots are
//! counted, not retried, so the sampler never spins against a busy
//! publisher. Hot-path cost is the *publication* (a handful of
//! relaxed stores per span push/pop, only while profiling), never
//! sampling — threads are never stopped, signaled, or locked.
//!
//! Alongside wall-clock samples the profiler collects **kernel
//! attribution**: the GEMM cores open a [`KernelTimer`] around each
//! dispatch (their MAC counts are known exactly), feeding the
//! [`crate::obs::flame::KernelStats`] roofline table. Both artifacts
//! export through [`report`] → `--profile-out` (folded stacks) and
//! `--roofline-out` (JSON); the sampling rate comes from
//! `MISA_PROF_HZ` (default [`DEFAULT_HZ`]).
//!
//! Like spans, the profiler is computation-read-only: it reads
//! clocks and name pointers, never tensors or RNG streams, so every
//! bit-parity suite passes with profiling on (`rust/tests/obs.rs`
//! re-runs them under an active sampler to pin that).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use super::flame::{FoldedStacks, KernelStats};
use super::span;

/// Default sampling rate when `MISA_PROF_HZ` is unset: prime (so the
/// sampler never phase-locks to a periodic workload), ~10 ms period.
pub const DEFAULT_HZ: u64 = 97;

/// Sampling rate resolved from `MISA_PROF_HZ` (clamped to
/// `1..=10_000`), else [`DEFAULT_HZ`].
pub fn default_hz() -> u64 {
    env_hz().unwrap_or(DEFAULT_HZ)
}

/// `MISA_PROF_HZ` parsed, if set to a positive number.
pub(crate) fn env_hz() -> Option<u64> {
    static ENV: OnceLock<Option<u64>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("MISA_PROF_HZ")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|&hz| hz > 0)
            .map(|hz| hz.clamp(1, 10_000))
    })
}

struct Sampler {
    stop: &'static AtomicBool,
    join: std::thread::JoinHandle<()>,
}

static STOP: AtomicBool = AtomicBool::new(false);

fn sampler() -> &'static Mutex<Option<Sampler>> {
    static S: OnceLock<Mutex<Option<Sampler>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(None))
}

fn folded() -> &'static Mutex<FoldedStacks> {
    static F: OnceLock<Mutex<FoldedStacks>> = OnceLock::new();
    F.get_or_init(|| Mutex::new(FoldedStacks::default()))
}

fn kernels() -> &'static Mutex<KernelStats> {
    static K: OnceLock<Mutex<KernelStats>> = OnceLock::new();
    K.get_or_init(|| Mutex::new(KernelStats::default()))
}

/// Wall-clock samples taken (successful + torn), for overhead math.
static TICKS: AtomicU64 = AtomicU64::new(0);

/// Whether the sampler thread is currently running.
pub fn running() -> bool {
    sampler().lock().unwrap_or_else(|e| e.into_inner()).is_some()
}

/// Start the background sampler at `hz` samples/sec and switch span
/// publication on. Idempotent while running (the first rate wins);
/// errors on a nonsensical rate.
pub fn start(hz: u64) -> Result<()> {
    ensure!((1..=10_000).contains(&hz), "profiler rate {hz} Hz out of range (1..=10000)");
    let mut guard = sampler().lock().unwrap_or_else(|e| e.into_inner());
    if guard.is_some() {
        return Ok(());
    }
    span::set_profiling(true);
    STOP.store(false, Ordering::Relaxed);
    let period = Duration::from_nanos(1_000_000_000 / hz);
    let join = std::thread::Builder::new()
        .name("misa-prof".to_string())
        .spawn(move || sample_loop(period))
        .expect("spawning profiler sampler");
    *guard = Some(Sampler { stop: &STOP, join });
    Ok(())
}

fn sample_loop(period: Duration) {
    let mut buf: Vec<&'static str> = Vec::with_capacity(span::PUB_MAX_DEPTH);
    let mut next = Instant::now() + period;
    while !STOP.load(Ordering::Relaxed) {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        // fixed cadence even when a sweep overruns: skip missed slots
        // rather than bursting, so sample counts stay ∝ wall time
        next += period;
        let behind = Instant::now();
        while next < behind {
            next += period;
        }
        TICKS.fetch_add(1, Ordering::Relaxed);
        let mut acc = folded().lock().unwrap_or_else(|e| e.into_inner());
        for ps in span::registered_stacks() {
            if ps.sample(&mut buf) {
                acc.add(&buf); // empty stacks (idle threads) fold to nothing
            } else {
                acc.torn += 1;
            }
        }
    }
}

/// Stop the sampler (joining its thread) and drop span publication
/// back to the `MISA_PROF_HZ` environment default. No-op when not
/// running. Accumulated samples and kernel stats survive — take them
/// with [`report`].
pub fn stop() {
    let taken = sampler().lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(s) = taken {
        s.stop.store(true, Ordering::Relaxed);
        let _ = s.join.join();
    }
    span::set_profiling(env_hz().is_some());
}

/// Everything the profiler accumulated so far.
#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    /// Folded wall-clock samples.
    pub folded: FoldedStacks,
    /// Kernel FLOP/time attribution (roofline input).
    pub kernels: KernelStats,
    /// Sampler wakeups (a sweep over all registered stacks each).
    pub ticks: u64,
}

/// Snapshot (without resetting) the accumulated profile.
pub fn report() -> ProfileReport {
    ProfileReport {
        folded: folded().lock().unwrap_or_else(|e| e.into_inner()).clone(),
        kernels: kernels().lock().unwrap_or_else(|e| e.into_inner()).clone(),
        ticks: TICKS.load(Ordering::Relaxed),
    }
}

/// Reset the accumulators (tests; the CLI exports once at exit).
pub fn reset() {
    *folded().lock().unwrap_or_else(|e| e.into_inner()) = FoldedStacks::default();
    *kernels().lock().unwrap_or_else(|e| e.into_inner()) = KernelStats::default();
    TICKS.store(0, Ordering::Relaxed);
}

/// RAII timer a kernel core opens around one timed dispatch. Open it
/// **before** the core's own span so the captured module is the
/// *enclosing* span (`ragged_forward`, `fwd_bwd`, ...), not the
/// kernel itself.
pub struct KernelTimer {
    core: &'static str,
    module: Option<&'static str>,
    macs: u64,
    start: Instant,
}

/// Start timing one kernel call of `macs` multiply-accumulates;
/// returns `None` (zero cost beyond one relaxed load) unless
/// profiling is on.
pub fn kernel_timer(core: &'static str, macs: u64) -> Option<KernelTimer> {
    if !span::profiling_enabled() {
        return None;
    }
    Some(KernelTimer { core, module: span::current(), macs, start: Instant::now() })
}

impl Drop for KernelTimer {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as u64;
        kernels()
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .record(self.core, self.module, self.macs, ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Profiling toggles process-global span state; serialize with
    // every other obs test.
    use crate::obs::span::TEST_GATE as GATE;

    #[test]
    fn sampler_folds_live_span_stacks() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        start(2000).unwrap();
        assert!(running());
        assert!(span::profiling_enabled());
        {
            let _outer = crate::span!("prof_outer", "test");
            let _inner = crate::span!("prof_inner", "test");
            // hold the stack open long enough for several sampler hits
            let t0 = Instant::now();
            while report().folded.count("prof_outer;prof_inner") == 0 {
                assert!(t0.elapsed() < Duration::from_secs(5), "sampler never hit the stack");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        stop();
        assert!(!running());
        let rep = report();
        assert!(rep.ticks > 0);
        assert!(rep.folded.count("prof_outer;prof_inner") >= 1);
        let text = rep.folded.render_folded();
        assert!(text.contains("prof_outer;prof_inner"), "{text}");
        reset();
    }

    #[test]
    fn kernel_timer_is_inert_without_profiling() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        span::set_profiling(false);
        assert!(kernel_timer("gemm_nn", 1000).is_none());
    }

    #[test]
    fn kernel_timer_attributes_to_the_enclosing_span() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        span::set_profiling(true);
        {
            let _sp = crate::span!("prof_module", "test");
            // a private core name: concurrent lib tests may time real
            // gemm_* calls into the shared table while profiling is on
            let t = kernel_timer("prof_test_core", 4096).expect("profiling on");
            drop(t);
        }
        span::set_profiling(false);
        let rep = report();
        let agg = rep.kernels.core("prof_test_core").expect("timed call recorded");
        assert_eq!(agg.calls, 1);
        assert_eq!(agg.flops, 8192);
        assert!(agg.achieved_gflops() <= agg.peak_gflops);
        let json = rep.kernels.render_roofline_json();
        assert!(json.contains("\"module\":\"prof_module\""), "{json}");
        reset();
    }

    #[test]
    fn start_rejects_silly_rates() {
        assert!(start(0).is_err());
        assert!(start(1_000_000).is_err());
    }
}
