//! Byte-accounting for the paper's memory claims: lock-free gauges of
//! what the process actually holds *right now*, by category, plus the
//! high-water marks the training report and `bench-serve` print.
//!
//! Three tracked categories ([`MemCategory`]):
//!
//! - **OptimStates** — bytes of Adam moments currently resident
//!   (published by the trainer from the optimizer's `mem_profile`,
//!   i.e. which modules hold `m`/`v` right now — the quantity MISA's
//!   Alg. 1 line 17 state-clearing shrinks).
//! - **ActivationScratch** — bytes of forward/backward traces and the
//!   decode workspace held by `HostBackend` (published at the point of
//!   maximum extent, before the retained-envelope shrink).
//! - **KvCache** — resident KV bytes across all live caches, COW-aware
//!   (shared `Arc` chunks counted once — see
//!   `runtime::kv_resident_bytes`), published by the scheduler tick.
//!
//! Values live in plain relaxed atomics: `set_current` stores the
//! instantaneous value and folds it into a `fetch_max` peak. Readers
//! ([`current`], [`peak`], [`publish`]) never block writers. Like the
//! rest of `obs`, this layer only *copies sizes already known* to the
//! code that allocates — it never measures by interfering.
//!
//! Process-level ground truth comes from `/proc/self/status`
//! ([`process_rss_bytes`] / [`process_peak_rss_bytes`]); on platforms
//! without procfs those return `None` and the gauges are omitted.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::metrics;

/// A tracked memory category (array index into the static gauges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemCategory {
    /// Resident optimizer state (Adam m/v + sampler bookkeeping).
    OptimStates = 0,
    /// Backend activation traces + decode workspace.
    ActivationScratch = 1,
    /// Resident KV-cache bytes (COW-deduplicated).
    KvCache = 2,
}

const N_CATEGORIES: usize = 3;

impl MemCategory {
    /// All categories, index order.
    pub const ALL: [MemCategory; N_CATEGORIES] = [
        MemCategory::OptimStates,
        MemCategory::ActivationScratch,
        MemCategory::KvCache,
    ];

    /// Stable snake_case label used in metric names and reports.
    pub fn label(self) -> &'static str {
        match self {
            MemCategory::OptimStates => "optim_states",
            MemCategory::ActivationScratch => "activation_scratch",
            MemCategory::KvCache => "kv_cache",
        }
    }
}

static CURRENT: [AtomicU64; N_CATEGORIES] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
static PEAK: [AtomicU64; N_CATEGORIES] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Record the instantaneous byte residency of `cat` and fold it into
/// the category's high-water mark. Relaxed atomics — safe from any
/// thread, never blocks.
pub fn set_current(cat: MemCategory, bytes: u64) {
    CURRENT[cat as usize].store(bytes, Ordering::Relaxed);
    PEAK[cat as usize].fetch_max(bytes, Ordering::Relaxed);
}

/// Last recorded residency of `cat` (bytes).
pub fn current(cat: MemCategory) -> u64 {
    CURRENT[cat as usize].load(Ordering::Relaxed)
}

/// High-water mark of `cat` since start / last [`reset`] (bytes).
pub fn peak(cat: MemCategory) -> u64 {
    PEAK[cat as usize].load(Ordering::Relaxed)
}

/// Zero every current value and peak (tests, bench re-runs).
pub fn reset() {
    for i in 0..N_CATEGORIES {
        CURRENT[i].store(0, Ordering::Relaxed);
        PEAK[i].store(0, Ordering::Relaxed);
    }
}

/// Publish every category as `mem.<label>.bytes` /
/// `mem.<label>.peak_bytes` gauges, plus `mem.process.rss_bytes` /
/// `mem.process.peak_rss_bytes` when procfs is available.
pub fn publish() {
    for cat in MemCategory::ALL {
        metrics::gauge_set(&format!("mem.{}.bytes", cat.label()), current(cat) as f64);
        metrics::gauge_set(&format!("mem.{}.peak_bytes", cat.label()), peak(cat) as f64);
    }
    if let Some(rss) = process_rss_bytes() {
        metrics::gauge_set("mem.process.rss_bytes", rss as f64);
    }
    if let Some(hwm) = process_peak_rss_bytes() {
        metrics::gauge_set("mem.process.peak_rss_bytes", hwm as f64);
    }
}

/// Parse a `kB` field out of `/proc/self/status` (Linux; `None`
/// elsewhere or on any parse failure).
fn proc_status_bytes(key: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let kib: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kib * 1024);
        }
    }
    None
}

/// Current process resident set size (`VmRSS`), bytes.
pub fn process_rss_bytes() -> Option<u64> {
    proc_status_bytes("VmRSS:")
}

/// Process peak resident set size (`VmHWM`), bytes.
pub fn process_peak_rss_bytes() -> Option<u64> {
    proc_status_bytes("VmHWM:")
}

#[cfg(test)]
mod tests {
    use super::*;

    // The gauges are process-global and other tests feed them
    // concurrently, so assertions use sentinel values far above any
    // real workload instead of exact-state equality.
    const BIG: u64 = 1 << 60;

    #[test]
    fn peak_tracking_and_publish() {
        // one test (not two) so our own reset() can't race our asserts
        set_current(MemCategory::OptimStates, BIG);
        assert!(peak(MemCategory::OptimStates) >= BIG);
        // lowering current never lowers the peak
        set_current(MemCategory::OptimStates, 1);
        assert!(peak(MemCategory::OptimStates) >= BIG);
        set_current(MemCategory::OptimStates, BIG + 7);
        assert!(peak(MemCategory::OptimStates) >= BIG + 7);

        set_current(MemCategory::KvCache, BIG + 1);
        publish();
        for cat in MemCategory::ALL {
            let cur = crate::obs::metrics::gauge(&format!("mem.{}.bytes", cat.label()));
            let pk = crate::obs::metrics::gauge(&format!("mem.{}.peak_bytes", cat.label()));
            assert!(cur.is_some(), "missing current gauge for {}", cat.label());
            assert!(pk.is_some(), "missing peak gauge for {}", cat.label());
        }

        reset();
        assert!(peak(MemCategory::OptimStates) < BIG);
        assert!(peak(MemCategory::KvCache) < BIG);
    }

    #[test]
    fn procfs_readers_agree_with_reality_on_linux() {
        if !std::path::Path::new("/proc/self/status").exists() {
            return; // non-Linux: readers return None by design
        }
        let rss = process_rss_bytes().expect("VmRSS parses");
        let hwm = process_peak_rss_bytes().expect("VmHWM parses");
        assert!(rss > 0);
        assert!(hwm >= rss, "peak {hwm} < current {rss}");
    }
}
