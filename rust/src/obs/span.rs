//! Hierarchical scoped spans with Chrome trace-event export.
//!
//! A span is an RAII guard: [`span`] (or the [`crate::span!`] macro)
//! pushes a name onto a thread-local stack and records a start
//! timestamp; dropping the guard pops the stack and appends one
//! completed [`SpanEvent`] to a process-global buffer. Nesting falls
//! out of the stack — each event remembers its parent's name and its
//! depth at open time.
//!
//! **Disabled is the default and costs one relaxed atomic load per
//! call site**: when tracing is off, [`span`] returns an inert guard
//! without touching the clock, the thread-local stack, or the event
//! buffer. Enable with `MISA_TRACE=1` (read once at first use) or
//! programmatically with [`enable_tracing`] (the `--trace-out` flag).
//!
//! **Spans never perturb computation.** A guard only reads `Instant`
//! — never an RNG stream, never a tensor — so every bit-parity
//! invariant (spec ≡ plain, scheduled ≡ solo, threads 1 vs 4) holds
//! verbatim with tracing fully enabled; `rust/tests/obs.rs` re-runs
//! those suites under tracing to pin it.
//!
//! The persistent worker pool's threads do not share the submitter's
//! thread-local stack — and, being long-lived, one worker serves many
//! differently-parented jobs over its lifetime — so the pool captures
//! [`current`] on the submitting thread **per job** and opens one
//! `pool_task` span per task with [`span_child`], keeping the tree
//! connected across the fan-out no matter which participant (worker
//! or the caller itself) ends up executing a given task.
//!
//! The buffer is bounded at [`MAX_EVENTS`]; once full, further events
//! increment a visible drop counter instead of growing without bound
//! or silently vanishing ([`take_events`] reports the count).
//!
//! ## Stack publication (the sampling profiler's view)
//!
//! [`crate::obs::profile`]'s background sampler needs to read *other*
//! threads' live span stacks without stopping them. Each thread
//! therefore mirrors its stack into a `PubStack` — a seqlock-guarded
//! snapshot of `(ptr, len)` halves of the `&'static str` frame names —
//! registered once in a global list at the thread's first span. The
//! owner republishes the full snapshot on every push/pop (a handful of
//! relaxed stores bracketed by two release stores of the sequence
//! counter); the sampler validates the sequence was even and unchanged
//! across its reads before reconstructing any `&str`, so it can never
//! observe a torn name. Publication only happens while
//! [`profiling_enabled`] — with the profiler off the mirror costs
//! nothing, and with *only* the profiler on (tracing off) guards
//! maintain the stack mirror but skip the clock and the event buffer
//! entirely, so pinned span/event counts never change.

use std::cell::{Cell, OnceCell, RefCell};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

/// Hard cap on buffered events (~72 MiB at the `SpanEvent` size);
/// beyond it events are counted as dropped, not stored.
pub const MAX_EVENTS: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static PROFILING: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// `MISA_TRACE` (and `MISA_PROF_HZ`, which forces stack publication
/// on so a whole test suite can run published) are folded into the
/// flags exactly once, before the first enabled-check; later
/// [`enable_tracing`]/[`disable_tracing`]/[`set_profiling`] calls
/// override them.
fn env_init() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("MISA_TRACE") {
            let v = v.trim();
            if !v.is_empty() && v != "0" {
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
        if crate::obs::profile::env_hz().is_some() {
            PROFILING.store(true, Ordering::Relaxed);
        }
    });
}

/// Whether span guards are currently recording.
pub fn tracing_enabled() -> bool {
    env_init();
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on (idempotent).
pub fn enable_tracing() {
    env_init();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn span recording off; buffered events stay until
/// [`take_events`].
pub fn disable_tracing() {
    env_init();
    ENABLED.store(false, Ordering::Relaxed);
}

/// Whether the sampling profiler is consuming published span stacks
/// (toggled by [`crate::obs::profile::start`] / `stop`; `MISA_PROF_HZ`
/// forces it on for the whole process).
pub fn profiling_enabled() -> bool {
    env_init();
    PROFILING.load(Ordering::Relaxed)
}

/// Switch per-thread stack publication on or off (profiler lifecycle
/// only — see [`profiling_enabled`]).
pub(crate) fn set_profiling(on: bool) {
    PROFILING.store(on, Ordering::Relaxed);
}

/// Process-wide trace epoch: all timestamps are microseconds since
/// the first span (or export) touched the clock.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

pub(crate) fn now_us() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_micros() as u64
}

fn events() -> &'static Mutex<Vec<SpanEvent>> {
    static EVENTS: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    EVENTS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    /// Small dense per-thread id (std's `ThreadId` has no stable
    /// numeric accessor), assigned on a thread's first span.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    /// The open-span stack this thread is inside.
    static STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
    /// This thread's published stack mirror, registered globally at
    /// first use (profiling only).
    static PUB: OnceCell<Arc<PubStack>> = const { OnceCell::new() };
    /// Cross-thread parent in effect while this thread's stack is
    /// rooted in a [`span_child`] (the pool-task case): published as a
    /// synthetic bottom frame so folded stacks stay connected across
    /// the fan-out, mirroring what the Chrome trace does with
    /// `parent`.
    static PUB_BASE: Cell<Option<&'static str>> = const { Cell::new(None) };
}

/// This thread's dense span thread-id (assigned at first use).
pub(crate) fn thread_id() -> u64 {
    TID.with(|t| *t)
}

/// Deepest stack the published mirror can represent; deeper frames are
/// truncated (far beyond any real nesting in this codebase).
pub(crate) const PUB_MAX_DEPTH: usize = 64;

/// One thread's seqlock-published span-stack snapshot. The owning
/// thread is the only writer; the profiler's sampler thread reads it
/// lock-free (see the module docs for the protocol).
pub(crate) struct PubStack {
    /// Odd while the owner is rewriting the snapshot, even when
    /// stable; bumped twice per publication.
    seq: AtomicU64,
    /// Dense span thread-id of the owning thread.
    pub(crate) tid: u64,
    /// Number of valid frames.
    depth: AtomicUsize,
    /// Frame-name pointer halves (`&'static str::as_ptr`).
    ptrs: [AtomicUsize; PUB_MAX_DEPTH],
    /// Frame-name length halves.
    lens: [AtomicUsize; PUB_MAX_DEPTH],
}

impl PubStack {
    fn new(tid: u64) -> Self {
        PubStack {
            seq: AtomicU64::new(0),
            tid,
            depth: AtomicUsize::new(0),
            ptrs: std::array::from_fn(|_| AtomicUsize::new(0)),
            lens: std::array::from_fn(|_| AtomicUsize::new(0)),
        }
    }

    /// Owner side: republish the full snapshot (`base` becomes a
    /// synthetic bottom frame when present).
    fn publish(&self, base: Option<&'static str>, stack: &[&'static str]) {
        let s = self.seq.load(Ordering::Relaxed);
        self.seq.store(s.wrapping_add(1), Ordering::Release); // odd: in progress
        // Order the odd store before the frame stores: a release
        // *store* only orders earlier accesses before itself, so
        // without this fence the relaxed frame stores below could
        // become visible before the sequence turns odd on
        // weakly-ordered targets, and the sampler could validate torn
        // frames against the old even sequence.
        std::sync::atomic::fence(Ordering::Release);
        let mut d = 0usize;
        if let Some(b) = base {
            self.store_frame(d, b);
            d += 1;
        }
        for &f in stack.iter().take(PUB_MAX_DEPTH - d) {
            self.store_frame(d, f);
            d += 1;
        }
        self.depth.store(d, Ordering::Relaxed);
        self.seq.store(s.wrapping_add(2), Ordering::Release); // even: stable
    }

    fn store_frame(&self, i: usize, name: &'static str) {
        self.ptrs[i].store(name.as_ptr() as usize, Ordering::Relaxed);
        self.lens[i].store(name.len(), Ordering::Relaxed);
    }

    /// Sampler side: copy a consistent snapshot into `out`. Returns
    /// `false` (leaving `out` empty) when a publication raced the
    /// read — the sampler just drops that sample.
    pub(crate) fn sample(&self, out: &mut Vec<&'static str>) -> bool {
        out.clear();
        let s1 = self.seq.load(Ordering::Acquire);
        if s1 & 1 == 1 {
            return false;
        }
        let depth = self.depth.load(Ordering::Acquire).min(PUB_MAX_DEPTH);
        let mut frames = [(0usize, 0usize); PUB_MAX_DEPTH];
        for (f, (p, l)) in frames.iter_mut().zip(self.ptrs.iter().zip(&self.lens)).take(depth)
        {
            *f = (p.load(Ordering::Acquire), l.load(Ordering::Acquire));
        }
        if self.seq.load(Ordering::Acquire) != s1 {
            out.clear();
            return false;
        }
        for &(p, l) in &frames[..depth] {
            if p == 0 {
                out.clear();
                return false;
            }
            // SAFETY: the sequence counter was even and unchanged
            // across the reads, so each (ptr, len) pair is exactly
            // what one `store_frame` wrote from a `&'static str` —
            // reconstructing it reads 'static memory.
            out.push(unsafe {
                std::str::from_utf8_unchecked(std::slice::from_raw_parts(p as *const u8, l))
            });
        }
        true
    }
}

/// Global registry of every thread's published stack (grows by one
/// entry per thread that ever opened a span while profiling; threads
/// that die leave a stable empty snapshot behind).
fn pub_stacks() -> &'static Mutex<Vec<Arc<PubStack>>> {
    static STACKS: OnceLock<Mutex<Vec<Arc<PubStack>>>> = OnceLock::new();
    STACKS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Snapshot the registry for the sampler thread.
pub(crate) fn registered_stacks() -> Vec<Arc<PubStack>> {
    pub_stacks().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Republish this thread's stack mirror (owner side; registers the
/// mirror globally on the thread's first publication).
fn publish_stack() {
    PUB.with(|cell| {
        let ps = cell.get_or_init(|| {
            let ps = Arc::new(PubStack::new(thread_id()));
            pub_stacks()
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(Arc::clone(&ps));
            ps
        });
        let base = PUB_BASE.with(|b| b.get());
        STACK.with(|s| ps.publish(base, &s.borrow()));
    });
}

/// One completed span, ready for Chrome trace-event export.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Span name (the trace-event `name`).
    pub name: &'static str,
    /// Coarse subsystem category (`tensor`, `backend`, `serve`, ...).
    pub cat: &'static str,
    /// Name of the enclosing span at open time, if any.
    pub parent: Option<&'static str>,
    /// Nesting depth at open time (0 = root).
    pub depth: u32,
    /// Dense per-thread id (see module docs).
    pub tid: u64,
    /// Microseconds since the trace epoch at open.
    pub start_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
}

struct ActiveSpan {
    name: &'static str,
    cat: &'static str,
    parent: Option<&'static str>,
    depth: u32,
    tid: u64,
    start_us: u64,
    /// Append a [`SpanEvent`] on drop (tracing was on at open);
    /// profiling- or flight-only guards maintain the stack without
    /// recording, so pinned event counts never change.
    record: bool,
    /// The open published the stack mirror — the drop must too, even
    /// if profiling switched off mid-span, so a mirror never retains
    /// phantom frames.
    published: bool,
}

/// RAII span guard: records a [`SpanEvent`] when dropped. Inert (and
/// nearly free) when tracing, profiling and the flight recorder are
/// all disabled.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        let emptied = STACK.with(|s| {
            let mut s = s.borrow_mut();
            s.pop();
            s.is_empty()
        });
        if emptied {
            PUB_BASE.with(|b| b.set(None));
        }
        if a.published || profiling_enabled() {
            publish_stack();
        }
        let dur_us = if a.record { now_us().saturating_sub(a.start_us) } else { 0 };
        if crate::obs::flight::enabled() {
            crate::obs::flight::record("span_close", a.name, a.depth as u64, dur_us);
        }
        if !a.record {
            return;
        }
        let ev = SpanEvent {
            name: a.name,
            cat: a.cat,
            parent: a.parent,
            depth: a.depth,
            tid: a.tid,
            start_us: a.start_us,
            dur_us,
        };
        let mut buf = events().lock().unwrap_or_else(|e| e.into_inner());
        if buf.len() < MAX_EVENTS {
            buf.push(ev);
        } else {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn open(name: &'static str, cat: &'static str, forced_parent: Option<&'static str>) -> SpanGuard {
    let record = tracing_enabled();
    let profiling = profiling_enabled();
    let flight = crate::obs::flight::enabled();
    if !record && !profiling && !flight {
        return SpanGuard { active: None };
    }
    let tid = TID.with(|t| *t);
    let (parent, depth) = STACK.with(|s| {
        let mut s = s.borrow_mut();
        let parent = s.last().copied().or(forced_parent);
        // a forced parent lives on another thread's stack; count it
        let depth = s.len() as u32 + u32::from(s.is_empty() && forced_parent.is_some());
        if s.is_empty() {
            PUB_BASE.with(|b| b.set(forced_parent));
        }
        s.push(name);
        (parent, depth)
    });
    if profiling {
        publish_stack();
    }
    if flight {
        crate::obs::flight::record("span_open", name, depth as u64, 0);
    }
    SpanGuard {
        active: Some(ActiveSpan {
            name,
            cat,
            parent,
            depth,
            tid,
            start_us: if record { now_us() } else { 0 },
            record,
            published: profiling,
        }),
    }
}

/// Open a span nested under this thread's current span (if any).
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    open(name, cat, None)
}

/// Open a span whose parent was captured on *another* thread — the
/// scoped-worker case, where thread-locals don't cross the spawn.
pub fn span_child(
    name: &'static str,
    cat: &'static str,
    parent: Option<&'static str>,
) -> SpanGuard {
    open(name, cat, parent)
}

/// Name of the innermost open span on this thread, if any (capture
/// before spawning workers, pass to [`span_child`]).
pub fn current() -> Option<&'static str> {
    if !tracing_enabled() && !profiling_enabled() {
        return None;
    }
    STACK.with(|s| s.borrow().last().copied())
}

/// Drain the buffered events, returning `(events, dropped_count)` and
/// resetting both.
pub fn take_events() -> (Vec<SpanEvent>, u64) {
    let evs = std::mem::take(&mut *events().lock().unwrap_or_else(|e| e.into_inner()));
    (evs, DROPPED.swap(0, Ordering::Relaxed))
}

/// Number of events buffered so far (diagnostics, tests).
pub fn event_count() -> usize {
    events().lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// Render events as Chrome trace-event JSON (complete `"ph": "X"`
/// events) — loadable in Perfetto / `chrome://tracing`.
pub fn render_chrome_trace(events: &[SpanEvent], dropped: u64) -> String {
    let mut out = String::with_capacity(128 + events.len() * 128);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for ev in events {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "\n{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{\"parent\":{},\"depth\":{}}}}}",
            crate::util::bench::escape(ev.name),
            crate::util::bench::escape(ev.cat),
            ev.start_us,
            ev.dur_us,
            ev.tid,
            match ev.parent {
                Some(p) => format!("\"{}\"", crate::util::bench::escape(p)),
                None => "null".to_string(),
            },
            ev.depth,
        ));
    }
    out.push_str("\n],");
    out.push_str(&format!("\"displayTimeUnit\":\"ms\",\"misa_dropped_events\":{dropped}}}\n"));
    out
}

/// Drain the buffer and write it to `path` as Chrome trace-event
/// JSON; returns the number of events written. Buffer overflow is
/// surfaced, not silent: the drop count lands in the
/// `trace.dropped_events` counter (`misa_trace_dropped_events` in the
/// Prometheus dump) and, when non-zero, one warning on stderr.
pub fn export_chrome_trace(path: &Path) -> Result<usize> {
    let (evs, dropped) = take_events();
    crate::obs::metrics::counter_add("trace.dropped_events", dropped);
    if dropped > 0 {
        crate::log_warn!(
            "trace buffer overflowed: {dropped} span event(s) dropped (cap {MAX_EVENTS}); \
             the exported trace is truncated"
        );
    }
    let body = render_chrome_trace(&evs, dropped);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {path:?}"))?;
    f.write_all(body.as_bytes())
        .with_context(|| format!("writing trace file {path:?}"))?;
    Ok(evs.len())
}

/// Serializes unit tests (across this crate's test-binary modules)
/// that toggle process-global tracing/profiling/flight state — span
/// guards observe those flags, so concurrent toggling makes
/// assertions racy.
#[cfg(test)]
pub(crate) static TEST_GATE: Mutex<()> = Mutex::new(());

/// Open a scoped span: `span!("name")` or `span!("name", "category")`.
/// Bind the result (`let _sp = span!(...)`) — dropping it closes the
/// span.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::span($name, "misa")
    };
    ($name:expr, $cat:expr) => {
        $crate::obs::span::span($name, $cat)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // Span tests share process-global state (the enabled flag, the
    // event buffer) with integration tests; within this unit-test
    // binary, serialize through the crate-wide gate.
    use super::TEST_GATE as GATE;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        // force *all three* consumers off — this test asserts the
        // fully-disabled fast path even when MISA_PROF_HZ/MISA_FLIGHT
        // env-forced them on for the rest of the suite
        disable_tracing();
        set_profiling(false);
        crate::obs::flight::disable();
        let before = event_count();
        {
            let _sp = span("t_disabled", "test");
            assert!(current().is_none());
        }
        assert_eq!(event_count(), before);
    }

    #[test]
    fn nested_spans_record_parent_and_depth() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let _ = take_events();
        enable_tracing();
        {
            let _outer = span("t_outer", "test");
            assert_eq!(current(), Some("t_outer"));
            {
                let _inner = span("t_inner", "test");
                assert_eq!(current(), Some("t_inner"));
            }
            assert_eq!(current(), Some("t_outer"));
        }
        disable_tracing();
        let (evs, dropped) = take_events();
        assert_eq!(dropped, 0);
        // inner closes before outer
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "t_inner");
        assert_eq!(evs[0].parent, Some("t_outer"));
        assert_eq!(evs[0].depth, 1);
        assert_eq!(evs[1].name, "t_outer");
        assert_eq!(evs[1].parent, None);
        assert_eq!(evs[1].depth, 0);
        assert_eq!(evs[0].tid, evs[1].tid);
        assert!(evs[1].dur_us >= evs[0].dur_us);
    }

    #[test]
    fn span_child_links_across_threads() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let _ = take_events();
        enable_tracing();
        {
            let _outer = span("t_root", "test");
            let parent = current();
            std::thread::scope(|s| {
                s.spawn(move || {
                    let _w = span_child("t_worker", "test", parent);
                });
            });
        }
        disable_tracing();
        let (evs, _) = take_events();
        let worker = evs.iter().find(|e| e.name == "t_worker").unwrap();
        let root = evs.iter().find(|e| e.name == "t_root").unwrap();
        assert_eq!(worker.parent, Some("t_root"));
        assert_eq!(worker.depth, 1);
        assert_ne!(worker.tid, root.tid);
    }

    #[test]
    fn chrome_render_escapes_and_reports_drops() {
        let evs = vec![SpanEvent {
            name: "a",
            cat: "test",
            parent: None,
            depth: 0,
            tid: 1,
            start_us: 10,
            dur_us: 5,
        }];
        let body = render_chrome_trace(&evs, 3);
        assert!(body.contains("\"traceEvents\":["), "{body}");
        assert!(body.contains("\"name\":\"a\""), "{body}");
        assert!(body.contains("\"ph\":\"X\""), "{body}");
        assert!(body.contains("\"misa_dropped_events\":3"), "{body}");
        assert!(body.contains("\"parent\":null"), "{body}");
    }
}
