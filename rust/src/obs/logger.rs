//! Leveled stderr logger replacing the scattered `eprintln!`
//! diagnostics.
//!
//! Level resolves from `MISA_LOG` (`off|error|warn|info|debug`, read
//! once; default `info`) and can be overridden programmatically with
//! [`set_level`]. Timestamps are **off by default** so test output and
//! CI greps stay byte-stable; `MISA_LOG_TS=1` prefixes each line with
//! seconds since the logger's first use.
//!
//! Diagnostics go to **stderr**; machine-read data output (the
//! `tokens:` line, bench summaries, JSON records) stays on stdout and
//! never routes through here.
//!
//! Call sites use the [`crate::log_error!`] / [`crate::log_warn!`] /
//! [`crate::log_info!`] / [`crate::log_debug!`] macros, which build
//! `format_args!` lazily — a disabled level costs one atomic load and
//! never formats.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered so `level as u8` comparisons work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Suppress everything.
    Off = 0,
    /// Unrecoverable or surprising failures.
    Error = 1,
    /// Degraded-but-continuing conditions.
    Warn = 2,
    /// Run lifecycle milestones (default).
    Info = 3,
    /// Per-step / per-tick detail.
    Debug = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// u8::MAX = "unset, resolve from the environment".
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static TIMESTAMPS: AtomicBool = AtomicBool::new(false);

fn env_level() -> u8 {
    static ENV: OnceLock<u8> = OnceLock::new();
    *ENV.get_or_init(|| {
        if std::env::var("MISA_LOG_TS").map(|v| v.trim() == "1").unwrap_or(false) {
            TIMESTAMPS.store(true, Ordering::Relaxed);
        }
        std::env::var("MISA_LOG")
            .ok()
            .and_then(|v| Level::parse(&v))
            .unwrap_or(Level::Info) as u8
    })
}

/// The active log level.
pub fn level() -> Level {
    let v = match LEVEL.load(Ordering::Relaxed) {
        u8::MAX => env_level(),
        v => v,
    };
    match v {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        _ => Level::Debug,
    }
}

/// Override the log level (e.g. a future `--log` flag or tests).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether a message at `l` would currently be emitted.
pub fn enabled(l: Level) -> bool {
    l != Level::Off && l <= level()
}

fn logger_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Emit one line at level `l` (macro back-end; formatting already
/// deferred by `format_args!` at the call site).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    if TIMESTAMPS.load(Ordering::Relaxed) {
        let t = Instant::now().saturating_duration_since(logger_epoch()).as_secs_f64();
        eprintln!("[{t:9.3}s {}] {args}", l.tag());
    } else {
        eprintln!("[{}] {args}", l.tag());
    }
}

/// Log at [`Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::Level::Error, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`] with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::obs::logger::log($crate::obs::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse(" WARN "), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn levels_order_and_gate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        // set_level/enabled are process-global; exercise and restore
        let before = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        set_level(before);
    }
}
