//! PJRT runtime: load AOT artifacts, manage device-resident parameters,
//! execute the training/eval/optimizer graphs.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Parameters live as device buffers (`PjRtBuffer`) and are passed by
//! reference on every step — only changed modules are re-uploaded, and
//! only the output tuple (loss, grads, norms) crosses back to the host.

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::modelspec::{Manifest, ModelSpec, ModuleKind};
use crate::util::Rng;

/// Wrapper over the PJRT CPU client + compiled-executable cache.
pub struct Engine {
    pub client: PjRtClient,
    pub manifest: Manifest,
    exe_cache: HashMap<String, Rc<PjRtLoadedExecutable>>,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, manifest, exe_cache: HashMap::new() })
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&mut self, file: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if !self.exe_cache.contains_key(file) {
            let path = self.manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
            self.exe_cache.insert(file.to_string(), Rc::new(exe));
        }
        Ok(Rc::clone(self.exe_cache.get(file).unwrap()))
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }
}

/// Output of one fwd/bwd execution.
pub struct StepOutput {
    pub loss: f32,
    /// per-parameter gradients, registry order
    pub grads: Vec<Vec<f32>>,
    /// per-parameter squared Frobenius norms (Pallas by-product)
    pub sq_norms: Vec<f32>,
}

/// Output of one predict execution.
pub struct EvalOutput {
    pub loss: f32,
    /// [b*s] 1.0 where argmax == target
    pub correct: Vec<f32>,
}

/// A model session: device-resident parameters + the compiled graphs.
pub struct Session {
    pub spec: ModelSpec,
    /// host mirror of the parameters, registry order
    pub host: Vec<Vec<f32>>,
    /// device-resident parameter buffers, registry order
    device: Vec<PjRtBuffer>,
    fwd_bwd: Rc<PjRtLoadedExecutable>,
    predict: Rc<PjRtLoadedExecutable>,
    /// fused-Adam executable per shape key
    adam: HashMap<String, Rc<PjRtLoadedExecutable>>,
    /// momentum-tail executable per shape key
    tail: HashMap<String, Rc<PjRtLoadedExecutable>>,
    client: PjRtClient,
}

impl Session {
    /// Build a session for `config`, initializing parameters from `seed`.
    pub fn create(engine: &mut Engine, config: &str, seed: u64) -> Result<Self> {
        let spec = engine.manifest.model(config)?.clone();
        let host = init_params(&spec, seed);
        Self::with_params(engine, spec, host)
    }

    /// Build a session around existing host parameters (checkpoint load).
    pub fn with_params(engine: &mut Engine, spec: ModelSpec, host: Vec<Vec<f32>>) -> Result<Self> {
        anyhow::ensure!(host.len() == spec.params.len(), "param count mismatch");
        let fwd_bwd = {
            let f = spec.graphs.get("fwd_bwd").ok_or_else(|| anyhow!("no fwd_bwd graph"))?;
            engine.load(&f.clone())?
        };
        let predict = {
            let f = spec.graphs.get("predict").ok_or_else(|| anyhow!("no predict graph"))?;
            engine.load(&f.clone())?
        };
        let mut adam = HashMap::new();
        let mut tail = HashMap::new();
        for p in &spec.params {
            let key = p.shape_key();
            if !adam.contains_key(&key) {
                if let Some(f) = spec.graphs.get(&format!("adam.{key}")) {
                    adam.insert(key.clone(), engine.load(&f.clone())?);
                }
                if let Some(f) = spec.graphs.get(&format!("tail.{key}")) {
                    tail.insert(key.clone(), engine.load(&f.clone())?);
                }
            }
        }
        let mut device = Vec::with_capacity(host.len());
        for (p, data) in spec.params.iter().zip(&host) {
            device.push(engine.upload_f32(data, &p.shape)?);
        }
        Ok(Session {
            spec,
            host,
            device,
            fwd_bwd,
            predict,
            adam,
            tail,
            client: engine.client.clone(),
        })
    }

    /// Re-upload one parameter from its host mirror.
    pub fn sync_param(&mut self, idx: usize) -> Result<()> {
        let p = &self.spec.params[idx];
        self.device[idx] = self
            .client
            .buffer_from_host_buffer(&self.host[idx], &p.shape, None)
            .map_err(|e| anyhow!("sync {}: {e:?}", p.name))?;
        Ok(())
    }

    /// Re-upload a set of parameters.
    pub fn sync_params(&mut self, indices: &[usize]) -> Result<()> {
        for &i in indices {
            self.sync_param(i)?;
        }
        Ok(())
    }

    /// Overwrite one parameter (host + device).
    pub fn set_param(&mut self, idx: usize, data: Vec<f32>) -> Result<()> {
        anyhow::ensure!(data.len() == self.spec.params[idx].numel(), "size mismatch");
        self.host[idx] = data;
        self.sync_param(idx)
    }

    fn batch_buffers(&self, batch: &crate::data::Batch) -> Result<[PjRtBuffer; 3]> {
        let dims = [batch.batch, batch.seq_len];
        let t = self
            .client
            .buffer_from_host_buffer(&batch.tokens, &dims, None)
            .map_err(|e| anyhow!("tokens upload: {e:?}"))?;
        let g = self
            .client
            .buffer_from_host_buffer(&batch.targets, &dims, None)
            .map_err(|e| anyhow!("targets upload: {e:?}"))?;
        let m = self
            .client
            .buffer_from_host_buffer(&batch.mask, &dims, None)
            .map_err(|e| anyhow!("mask upload: {e:?}"))?;
        Ok([t, g, m])
    }

    /// One fwd/bwd step: returns loss, all grads, and the Pallas-computed
    /// per-parameter squared gradient norms.
    pub fn fwd_bwd(&self, batch: &crate::data::Batch) -> Result<StepOutput> {
        let [t, g, m] = self.batch_buffers(batch)?;
        let mut args: Vec<&PjRtBuffer> = self.device.iter().collect();
        args.push(&t);
        args.push(&g);
        args.push(&m);
        let out = self
            .fwd_bwd
            .execute_b(&args)
            .map_err(|e| anyhow!("fwd_bwd execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fwd_bwd output: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let n = self.spec.params.len();
        anyhow::ensure!(parts.len() == n + 2, "unexpected output arity {}", parts.len());
        let loss = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let mut grads = Vec::with_capacity(n);
        for part in &parts[1..=n] {
            grads.push(part.to_vec::<f32>().map_err(|e| anyhow!("grad: {e:?}"))?);
        }
        let sq_norms = parts[n + 1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("sq_norms: {e:?}"))?;
        Ok(StepOutput { loss, grads, sq_norms })
    }

    /// One eval step via the predict graph.
    pub fn predict(&self, batch: &crate::data::Batch) -> Result<EvalOutput> {
        let [t, g, m] = self.batch_buffers(batch)?;
        let mut args: Vec<&PjRtBuffer> = self.device.iter().collect();
        args.push(&t);
        args.push(&g);
        args.push(&m);
        let out = self
            .predict
            .execute_b(&args)
            .map_err(|e| anyhow!("predict execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("predict output: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let loss = parts[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let correct = parts[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("correct: {e:?}"))?;
        Ok(EvalOutput { loss, correct })
    }

    /// Fused Adam update (Pallas kernel) of parameter `idx` on the hot
    /// path: consumes grad + moments, updates host+device param in place,
    /// returns (m', v', sum(g^2)).
    pub fn adam_update(
        &mut self,
        idx: usize,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let p = &self.spec.params[idx];
        let key = p.shape_key();
        let exe = self
            .adam
            .get(&key)
            .ok_or_else(|| anyhow!("no adam graph for shape {key}"))?;
        let shape = &p.shape;
        let gbuf = self.client.buffer_from_host_buffer(grad, shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let mbuf = self.client.buffer_from_host_buffer(m, shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let vbuf = self.client.buffer_from_host_buffer(v, shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let lrbuf = self.client.buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let args: Vec<&PjRtBuffer> = vec![&self.device[idx], &gbuf, &mbuf, &vbuf, &lrbuf];
        let out = exe.execute_b(&args).map_err(|e| anyhow!("adam execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        let p_new = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let m_new = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let v_new = parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let sq = parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        self.host[idx] = p_new;
        self.sync_param(idx)?;
        Ok((m_new, v_new, sq))
    }

    /// The additional momentum step (Alg. 1 line 16) via the Pallas tail
    /// kernel.
    pub fn tail_update(&mut self, idx: usize, m: &[f32], v: &[f32], lr: f32) -> Result<()> {
        let p = &self.spec.params[idx];
        let key = p.shape_key();
        let exe = self
            .tail
            .get(&key)
            .ok_or_else(|| anyhow!("no tail graph for shape {key}"))?;
        let shape = &p.shape;
        let mbuf = self.client.buffer_from_host_buffer(m, shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let vbuf = self.client.buffer_from_host_buffer(v, shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let lrbuf = self.client.buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let args: Vec<&PjRtBuffer> = vec![&self.device[idx], &mbuf, &vbuf, &lrbuf];
        let out = exe.execute_b(&args).map_err(|e| anyhow!("tail execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let p_new = lit
            .to_tuple1()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        self.host[idx] = p_new;
        self.sync_param(idx)
    }
}

/// Initialize host parameters (norms = 1, matrices = N(0, fan_in^-1/2),
/// embed/head = N(0, 0.02) — mirrors python/compile/model.init_params).
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    spec.params
        .iter()
        .map(|p| {
            let mut data = vec![0.0f32; p.numel()];
            match p.kind {
                ModuleKind::Norm => data.fill(1.0),
                ModuleKind::Embed | ModuleKind::Head => rng.fill_normal(&mut data, 0.02),
                _ => {
                    let std = (p.shape[0] as f32).powf(-0.5);
                    rng.fill_normal(&mut data, std);
                }
            }
            data
        })
        .collect()
}

/// Helper: extract a Literal's f32 data.
pub fn literal_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal_f32: {e:?}"))
}
