//! Runtime: execution engines, model sessions, parameter initialization.
//!
//! The runtime is split into a thin coordinator-facing layer (this
//! module: [`Engine`], [`Session`], [`StepOutput`], [`EvalOutput`]) and
//! the pluggable [`backend`] subsystem that actually executes the
//! compute:
//!
//! - [`backend::HostBackend`] (default) — pure-Rust transformer
//!   fwd/bwd + fused optimizer math; no artifacts, runs anywhere.
//! - `backend::PjrtBackend` (cargo feature `pjrt`) — the AOT path:
//!   PJRT client, compiled HLO executables, device-resident parameters.
//!
//! `Session` owns the host parameter mirror (the source of truth) and a
//! `Box<dyn Backend>`; the trainer and every optimizer are
//! backend-agnostic.

pub mod backend;

use std::path::Path;

use anyhow::Result;

use crate::modelspec::{Manifest, ModelSpec, ModuleKind};
use crate::util::Rng;

pub use backend::{kv_resident_bytes, Backend, BackendKind, HostBackend, KvCache};
#[cfg(feature = "pjrt")]
pub use backend::pjrt::PjrtBackend;

/// Output of one fwd/bwd execution.
pub struct StepOutput {
    pub loss: f32,
    /// per-parameter gradients, registry order
    pub grads: Vec<Vec<f32>>,
    /// per-parameter squared Frobenius norms (kernel by-product)
    pub sq_norms: Vec<f32>,
}

/// Output of one predict execution.
pub struct EvalOutput {
    pub loss: f32,
    /// [b*s] 1.0 where argmax == target
    pub correct: Vec<f32>,
}

/// The execution engine: model registry + backend factory.
///
/// With the host backend the registry comes from `artifacts/manifest.txt`
/// when present and falls back to the builtin registry (the Rust mirror
/// of python/compile/configs.py) otherwise, so a fresh checkout trains
/// with no compiled-graph sidecar. The PJRT backend requires a real
/// manifest plus the `pjrt` cargo feature.
pub struct Engine {
    pub manifest: Manifest,
    pub kind: BackendKind,
    #[cfg(feature = "pjrt")]
    compiler: Option<backend::pjrt::PjrtCompiler>,
}

impl Engine {
    /// Host-backend engine rooted at `artifact_dir` (manifest optional).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Self::with_backend(artifact_dir, BackendKind::Host)
    }

    /// Host-backend engine on the builtin registry (tests, benches).
    pub fn host() -> Self {
        Engine {
            manifest: Manifest::builtin(),
            kind: BackendKind::Host,
            #[cfg(feature = "pjrt")]
            compiler: None,
        }
    }

    /// Engine with an explicit backend selection.
    pub fn with_backend(artifact_dir: &Path, kind: BackendKind) -> Result<Self> {
        match kind {
            BackendKind::Host => {
                let manifest = Manifest::load_or_builtin(artifact_dir)?;
                Ok(Engine {
                    manifest,
                    kind,
                    #[cfg(feature = "pjrt")]
                    compiler: None,
                })
            }
            BackendKind::Pjrt => Self::new_pjrt(artifact_dir),
        }
    }

    #[cfg(feature = "pjrt")]
    fn new_pjrt(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let compiler = backend::pjrt::PjrtCompiler::new(artifact_dir)?;
        Ok(Engine { manifest, kind: BackendKind::Pjrt, compiler: Some(compiler) })
    }

    #[cfg(not(feature = "pjrt"))]
    fn new_pjrt(_artifact_dir: &Path) -> Result<Self> {
        anyhow::bail!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt` or use the host backend"
        )
    }

    pub fn backend_name(&self) -> &'static str {
        self.kind.as_str()
    }

    /// Construct the session backend for `spec`, uploading `host` where
    /// the backend keeps device-resident parameters.
    fn make_backend(&mut self, spec: &ModelSpec, host: &[Vec<f32>]) -> Result<Box<dyn Backend>> {
        match self.kind {
            BackendKind::Host => {
                let _ = host; // host backend executes from the session mirror
                Ok(Box::new(HostBackend::new(spec.clone())?))
            }
            BackendKind::Pjrt => self.make_pjrt_backend(spec, host),
        }
    }

    #[cfg(feature = "pjrt")]
    fn make_pjrt_backend(&mut self, spec: &ModelSpec, host: &[Vec<f32>])
                         -> Result<Box<dyn Backend>> {
        let comp = self
            .compiler
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("pjrt engine has no compiler"))?;
        Ok(Box::new(backend::pjrt::PjrtBackend::create(comp, spec, host)?))
    }

    #[cfg(not(feature = "pjrt"))]
    fn make_pjrt_backend(&mut self, _spec: &ModelSpec, _host: &[Vec<f32>])
                         -> Result<Box<dyn Backend>> {
        anyhow::bail!("built without the `pjrt` feature")
    }
}

/// A model session: the host parameter mirror + the execution backend.
pub struct Session {
    pub spec: ModelSpec,
    /// host mirror of the parameters, registry order (source of truth)
    pub host: Vec<Vec<f32>>,
    backend: Box<dyn Backend>,
}

impl Session {
    /// Build a session for `config`, initializing parameters from `seed`.
    pub fn create(engine: &mut Engine, config: &str, seed: u64) -> Result<Self> {
        let spec = engine.manifest.model(config)?.clone();
        let host = init_params(&spec, seed);
        Self::with_params(engine, spec, host)
    }

    /// Build a session around existing host parameters (checkpoint load).
    pub fn with_params(engine: &mut Engine, spec: ModelSpec, host: Vec<Vec<f32>>) -> Result<Self> {
        anyhow::ensure!(host.len() == spec.params.len(), "param count mismatch");
        for (p, data) in spec.params.iter().zip(&host) {
            anyhow::ensure!(data.len() == p.numel(), "param {} size mismatch", p.name);
        }
        let backend = engine.make_backend(&spec, &host)?;
        Ok(Session { spec, host, backend })
    }

    /// Name of the executing backend ("host" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Re-upload one parameter from its host mirror.
    pub fn sync_param(&mut self, idx: usize) -> Result<()> {
        self.backend.sync_param(idx, &self.host[idx])
    }

    /// Re-upload a set of parameters.
    pub fn sync_params(&mut self, indices: &[usize]) -> Result<()> {
        for &i in indices {
            self.sync_param(i)?;
        }
        Ok(())
    }

    /// Overwrite one parameter (host mirror + backend copy).
    pub fn set_param(&mut self, idx: usize, data: Vec<f32>) -> Result<()> {
        anyhow::ensure!(data.len() == self.spec.params[idx].numel(), "size mismatch");
        self.host[idx] = data;
        self.sync_param(idx)
    }

    /// One fwd/bwd step: returns loss, all grads, and the per-parameter
    /// squared gradient norms (the sampler's importance indicator).
    pub fn fwd_bwd(&self, batch: &crate::data::Batch) -> Result<StepOutput> {
        self.backend.fwd_bwd(&self.host, batch)
    }

    /// One eval step via the predict graph.
    pub fn predict(&self, batch: &crate::data::Batch) -> Result<EvalOutput> {
        self.backend.predict(&self.host, batch)
    }

    /// A KV cache shaped for this session's model, holding `capacity`
    /// positions (one per concurrent generation stream).
    pub fn kv_cache(&self, capacity: usize) -> Result<KvCache> {
        KvCache::new(&self.spec, capacity)
    }

    /// Serve: run a prompt chunk through the model, appending K/V into
    /// `cache`; returns the final position's logits `[vocab]`.
    pub fn prefill(&self, tokens: &[i32], cache: &mut KvCache) -> Result<Vec<f32>> {
        self.backend.prefill(&self.host, tokens, cache)
    }

    /// Serve: prefill several slots in one stacked ragged-batch forward
    /// (slot `i`: `chunks[i]` appended to `caches[i]` at absolute
    /// positions `caches[i].len()..`); returns one final-position
    /// logits row `[vocab]` per slot.
    pub fn prefill_batch(
        &self,
        chunks: &[&[i32]],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend.prefill_batch(&self.host, chunks, caches)
    }

    /// Serve: decode one token at absolute position `pos`
    /// (= `cache.len()`); returns the next-token logits `[vocab]`.
    pub fn decode_step(&self, token: i32, pos: usize, cache: &mut KvCache)
                       -> Result<Vec<f32>> {
        self.backend.decode_step(&self.host, token, pos, cache)
    }

    /// Serve: decode one token for each scheduler slot in a single
    /// batched forward (slot `i`: `tokens[i]` at `positions[i]` =
    /// `caches[i].len()`); returns one `[vocab]` logits row per slot.
    pub fn decode_batch(
        &self,
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend.decode_batch(&self.host, tokens, positions, caches)
    }

    /// Serve: speculative verification — run each slot's
    /// `[last_token, draft...]` chunk through one multi-token cached
    /// forward (slot `i`: `chunks[i]` at absolute positions
    /// `positions[i]..` = `caches[i].len()..`) and return logits at
    /// **every** chunk position (`chunks[i].len() * vocab` floats per
    /// slot, position-major). The caller accepts the longest verified
    /// draft prefix and rolls rejected K/V back with
    /// [`KvCache::truncate`].
    pub fn verify_step(
        &self,
        chunks: &[&[i32]],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend.verify_step(&self.host, chunks, positions, caches)
    }

    /// Fused Adam update of parameter `idx` on the hot path: consumes
    /// grad + moments, updates the parameter in place (host mirror and
    /// any backend copy), returns (m', v', sum(g^2)).
    pub fn adam_update(
        &mut self,
        idx: usize,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let mut p = std::mem::take(&mut self.host[idx]);
        let result = self.backend.adam_update(idx, &mut p, grad, m, v, lr);
        self.host[idx] = p;
        result
    }

    /// The additional momentum step (Alg. 1 line 16).
    pub fn tail_update(&mut self, idx: usize, m: &[f32], v: &[f32], lr: f32) -> Result<()> {
        let mut p = std::mem::take(&mut self.host[idx]);
        let result = self.backend.tail_update(idx, &mut p, m, v, lr);
        self.host[idx] = p;
        result
    }
}

/// Initialize host parameters (norms = 1, matrices = N(0, fan_in^-1/2),
/// embed/head = N(0, 0.02) — mirrors python/compile/model.init_params).
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    spec.params
        .iter()
        .map(|p| {
            let mut data = vec![0.0f32; p.numel()];
            match p.kind {
                ModuleKind::Norm => data.fill(1.0),
                ModuleKind::Embed | ModuleKind::Head => rng.fill_normal(&mut data, 0.02),
                _ => {
                    let std = (p.shape[0] as f32).powf(-0.5);
                    rng.fill_normal(&mut data, std);
                }
            }
            data
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_host_serves_builtin_models() {
        let mut eng = Engine::host();
        assert_eq!(eng.backend_name(), "host");
        let sess = Session::create(&mut eng, "tiny", 0).unwrap();
        assert_eq!(sess.backend_name(), "host");
        assert_eq!(sess.host.len(), sess.spec.params.len());
    }

    #[test]
    fn engine_new_falls_back_without_artifacts() {
        let eng = Engine::new(Path::new("/definitely/not/artifacts")).unwrap();
        assert!(eng.manifest.model("small").is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_feature() {
        let err = match Engine::with_backend(Path::new("artifacts"), BackendKind::Pjrt) {
            Ok(_) => panic!("pjrt must be rejected without the feature"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("pjrt"));
    }

    #[test]
    fn init_params_shapes_and_norm_fill() {
        let spec = crate::modelspec::Manifest::builtin().model("tiny").unwrap().clone();
        let host = init_params(&spec, 7);
        for (p, data) in spec.params.iter().zip(&host) {
            assert_eq!(data.len(), p.numel());
            if p.kind == ModuleKind::Norm {
                assert!(data.iter().all(|&x| x == 1.0));
            }
        }
    }
}
