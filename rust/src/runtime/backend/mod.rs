//! Execution backends — the subsystem that runs the compute graphs.
//!
//! The coordinator (trainer, optimizers, experiments) speaks one small
//! execution ABI, [`Backend`]: fwd/bwd, predict, the fused-Adam update,
//! the momentum-tail update, and parameter upload. Two implementations
//! exist:
//!
//! - [`HostBackend`] (default): the full transformer forward/backward,
//!   masked cross-entropy, per-parameter squared gradient norms, and
//!   fused Adam in pure Rust — numerically mirroring the JAX oracles in
//!   `python/compile/kernels/ref.py` and `python/compile/model.py`.
//!   Runs anywhere, deterministically, with no compiled-graph sidecar.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): the original
//!   AOT-artifact path — PJRT client + compiled HLO executables with
//!   device-resident parameters.
//!
//! `Session` owns a `Box<dyn Backend>`; everything above it is
//! backend-agnostic.

pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use host::HostBackend;

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::runtime::{EvalOutput, StepOutput};

/// Which backend a run executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust host execution (default; no artifacts required).
    Host,
    /// PJRT + AOT HLO artifacts (requires the `pjrt` cargo feature and
    /// an `artifacts/` directory produced by `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(BackendKind::Host),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (expected \"host\" or \"pjrt\")"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// The execution ABI between the coordinator and the compute substrate.
///
/// `host` is the registry-ordered host mirror of the parameters owned by
/// `Session`; backends that keep device-resident copies (PJRT) ignore it
/// on the execute calls and refresh their copies through `sync_param`.
pub trait Backend {
    /// Human-readable backend name ("host" / "pjrt").
    fn name(&self) -> &'static str;

    /// (Re)upload one parameter from its host mirror. No-op on backends
    /// that execute directly from host memory.
    fn sync_param(&mut self, idx: usize, data: &[f32]) -> Result<()>;

    /// One fwd/bwd step: loss, all grads (registry order), and the
    /// per-parameter squared Frobenius gradient norms.
    fn fwd_bwd(&self, host: &[Vec<f32>], batch: &Batch) -> Result<StepOutput>;

    /// One eval step: masked loss + per-position teacher-forced hits.
    fn predict(&self, host: &[Vec<f32>], batch: &Batch) -> Result<EvalOutput>;

    /// Fused Adam update of parameter `idx` (Algorithm 1 lines 9-11, no
    /// bias correction): updates `p` in place and returns
    /// `(m', v', sum(g^2))` — the `ref.py::adam_ref` contract.
    fn adam_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// The additional momentum step (Algorithm 1 line 16): updates `p`
    /// in place — the `ref.py::momentum_tail_ref` contract.
    fn tail_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrip() {
        assert_eq!(BackendKind::parse("host").unwrap(), BackendKind::Host);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Host.as_str(), "host");
        assert_eq!(BackendKind::Pjrt.as_str(), "pjrt");
    }
}
