//! Execution backends — the subsystem that runs the compute graphs.
//!
//! The coordinator (trainer, optimizers, experiments) speaks one small
//! execution ABI, [`Backend`]: fwd/bwd, predict, the fused-Adam update,
//! the momentum-tail update, parameter upload, and the serving entry
//! points ([`Backend::prefill`] / [`Backend::prefill_batch`] /
//! [`Backend::decode_step`] / [`Backend::decode_batch`] over per-slot
//! [`KvCache`]s, which fork cheaply via [`KvCache::fork_from`] for
//! prompt-prefix reuse). Two implementations exist:
//!
//! - [`HostBackend`] (default): the full transformer forward/backward,
//!   masked cross-entropy, per-parameter squared gradient norms, and
//!   fused Adam in pure Rust — numerically mirroring the JAX oracles in
//!   `python/compile/kernels/ref.py` and `python/compile/model.py`.
//!   Runs anywhere, deterministically, with no compiled-graph sidecar.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): the original
//!   AOT-artifact path — PJRT client + compiled HLO executables with
//!   device-resident parameters.
//!
//! `Session` owns a `Box<dyn Backend>`; everything above it is
//! backend-agnostic.

pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use host::HostBackend;

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::data::Batch;
use crate::modelspec::ModelSpec;
use crate::runtime::{EvalOutput, StepOutput};

/// Which backend a run executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust host execution (default; no artifacts required).
    Host,
    /// PJRT + AOT HLO artifacts (requires the `pjrt` cargo feature and
    /// an `artifacts/` directory produced by `make artifacts`).
    Pjrt,
}

impl BackendKind {
    /// Parse a `--backend` CLI value ("host" / "pjrt").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(BackendKind::Host),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (expected \"host\" or \"pjrt\")"),
        }
    }

    /// The CLI spelling of this backend kind.
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Ring positions per copy-on-write chunk of a [`KvCache`] layer.
///
/// Forks share whole chunks; a write to a shared chunk clones just that
/// chunk (`Arc::make_mut`), so the COW granularity — and the marginal
/// memory cost of a diverging fork — is `CHUNK_POSITIONS * kv_dim`
/// floats per layer, not the whole ring.
pub(crate) const CHUNK_POSITIONS: usize = 16;

/// Per-layer key/value ring buffers for incremental decode.
///
/// One cache belongs to one generation stream (one scheduler slot). Each
/// layer holds `[capacity, kv_dim]` K and V rings where `kv_dim =
/// n_kv_heads * head_dim` — GQA-sized, so a cache is `n_heads /
/// n_kv_heads` times smaller than the full attention residency. Absolute
/// position `p` lives in ring slot `p % capacity`; once `len > capacity`
/// decode degrades gracefully to sliding-window attention over the last
/// `capacity` positions (RoPE still uses absolute positions).
///
/// Storage is split into `CHUNK_POSITIONS` (16) position chunks behind
/// `Arc`s, so [`KvCache::fork_from`] (and `clone`) share every chunk
/// with the parent in O(capacity / CHUNK_POSITIONS) pointer copies;
/// chunks are cloned lazily, one at a time, when either side writes —
/// the same keep-only-what-diverges idea MISA applies to optimizer
/// state, applied to KV memory across requests.
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    kv_dim: usize,
    capacity: usize,
    /// absolute positions appended so far (== the next decode position)
    len: usize,
    /// per-layer keys: chunks of `[CHUNK_POSITIONS * kv_dim]`
    k: Vec<Vec<Arc<Vec<f32>>>>,
    /// per-layer values: chunks of `[CHUNK_POSITIONS * kv_dim]`
    v: Vec<Vec<Arc<Vec<f32>>>>,
}

impl KvCache {
    /// Cache for `spec` holding up to `capacity` positions.
    pub fn new(spec: &ModelSpec, capacity: usize) -> Result<Self> {
        let mc = &spec.config;
        ensure!(capacity > 0, "kv cache capacity must be > 0");
        let kv_dim = mc.kv_dim();
        let n_chunks = capacity.div_ceil(CHUNK_POSITIONS);
        let alloc = || -> Vec<Arc<Vec<f32>>> {
            (0..n_chunks).map(|_| Arc::new(vec![0.0; CHUNK_POSITIONS * kv_dim])).collect()
        };
        Ok(KvCache {
            n_layers: mc.n_layers,
            kv_dim,
            capacity,
            len: 0,
            k: (0..mc.n_layers).map(|_| alloc()).collect(),
            v: (0..mc.n_layers).map(|_| alloc()).collect(),
        })
    }

    /// Fork a child cache off `parent` at `len` resident positions: the
    /// child sees `parent`'s first `len` positions (prompt-prefix reuse)
    /// and appends from there, while every K/V chunk stays shared until
    /// one side writes into it (copy-on-write) — forking is O(chunks)
    /// pointer copies, never a K/V memcpy, and never recomputes a
    /// position.
    ///
    /// The child keeps the parent's capacity (chunk sharing requires one
    /// ring layout). Fails if `len` exceeds the parent's length or if
    /// the parent's ring has already wrapped over a position the child's
    /// first attention window (query at `len`) would need — forking a
    /// wrapped parent is only possible at (or next to) its tip.
    pub fn fork_from(parent: &KvCache, len: usize) -> Result<Self> {
        ensure!(
            len <= parent.len,
            "fork at {len} positions but the parent holds only {}",
            parent.len
        );
        // the child's first query (position `len`) attends over
        // [lo, len); every one of those positions must still be resident
        // in the parent's ring, i.e. not overwritten by a later wrap
        let lo = (len + 1).saturating_sub(parent.capacity);
        ensure!(
            parent.len <= lo + parent.capacity,
            "fork at {len}: the parent ring (capacity {}, {} positions written) has \
             already evicted part of that prefix",
            parent.capacity,
            parent.len
        );
        let mut child = parent.clone(); // shares every chunk Arc
        child.len = len;
        Ok(child)
    }

    /// Copy `parent`'s first `len` positions into a fresh ring of
    /// `capacity` positions — the layout-converting sibling of
    /// [`KvCache::fork_from`] for when chunk sharing is impossible
    /// because the ring capacities differ. A row memcpy (never a
    /// recompute), off the decode hot path: the prompt store uses it
    /// once per newly seen prompt to convert a right-sized request
    /// ring into a store-layout entry.
    ///
    /// Requires the copied prefix to be fully resident, which means an
    /// unwrapped parent (`parent.len() <= parent.capacity()`).
    pub fn copy_prefix(parent: &KvCache, len: usize, capacity: usize) -> Result<Self> {
        ensure!(
            len <= parent.len,
            "copy_prefix of {len} positions but the parent holds only {}",
            parent.len
        );
        ensure!(len <= capacity, "copy_prefix: {len} positions exceed capacity {capacity}");
        ensure!(
            parent.len <= parent.capacity,
            "copy_prefix from a wrapped ring (capacity {}, {} positions written) would \
             read evicted positions",
            parent.capacity,
            parent.len
        );
        ensure!(capacity > 0, "kv cache capacity must be > 0");
        let n_chunks = capacity.div_ceil(CHUNK_POSITIONS);
        let alloc = || -> Vec<Arc<Vec<f32>>> {
            (0..n_chunks)
                .map(|_| Arc::new(vec![0.0; CHUNK_POSITIONS * parent.kv_dim]))
                .collect()
        };
        let mut child = KvCache {
            n_layers: parent.n_layers,
            kv_dim: parent.kv_dim,
            capacity,
            len,
            k: (0..parent.n_layers).map(|_| alloc()).collect(),
            v: (0..parent.n_layers).map(|_| alloc()).collect(),
        };
        // both rings are unwrapped over [0, len): slot == position
        for layer in 0..parent.n_layers {
            for pos in 0..len {
                child.write_kv(layer, pos, parent.k_row(layer, pos), parent.v_row(layer, pos));
            }
        }
        Ok(child)
    }

    /// Positions appended so far — the next decode position.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no position has been appended yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum resident positions before the ring wraps.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Transformer layer count this cache is shaped for.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Row width of one K (or V) position: `n_kv_heads * head_dim`.
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    /// Logical K/V bytes (both rings, all layers) — the scheduler's
    /// memory-accounting unit. Physical residency can be *lower* when
    /// forks still share chunks (copy-on-write) and is rounded up to
    /// `CHUNK_POSITIONS`-position chunk granularity.
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.capacity * self.kv_dim * std::mem::size_of::<f32>()
    }

    /// [`Self::bytes`] as a closed form, without building a cache.
    pub fn bytes_for(spec: &ModelSpec, capacity: usize) -> usize {
        let mc = &spec.config;
        2 * mc.n_layers * capacity * mc.kv_dim() * std::mem::size_of::<f32>()
    }

    /// Forget all cached positions (slot reuse).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll back to `len` resident positions — the speculative-decode
    /// rejection path: draft positions the verifier rejected are
    /// forgotten, and the next decode step overwrites their ring slots
    /// as if they were never written.
    ///
    /// No K/V rows are restored, because none need to be: rollback is
    /// only sound when the rolled-back writes did not overwrite any
    /// ring slot the retained attention window (queries at `len..`)
    /// still reads. A rolled-back position `p` clobbered position
    /// `p - capacity`, which the retained window needs iff
    /// `p >= len + 1` and `p >= capacity` — so truncation is refused
    /// (wrap-aware) when the ring has wrapped over retained positions,
    /// i.e. unless `self.len() <= capacity` (the draft never wrapped)
    /// or `self.len() <= len + 1` (at most the next slot, which frees
    /// exactly when its wrapped-out position leaves every window).
    ///
    /// Fork-aware by construction: the draft writes already went
    /// through `write_kv`'s copy-on-write, so chunks shared with a
    /// parent or child were cloned before being dirtied — truncating
    /// one side never exposes draft garbage to the other.
    pub fn truncate(&mut self, len: usize) -> Result<()> {
        ensure!(
            len <= self.len,
            "truncate to {len} positions but only {} are resident",
            self.len
        );
        ensure!(
            self.len <= self.capacity || self.len <= len + 1,
            "truncate to {len}: the ring (capacity {}, {} positions written) has \
             wrapped over retained positions — rolled-back rows cannot be restored",
            self.capacity,
            self.len
        );
        self.len = len;
        Ok(())
    }

    /// One layer's K row at ring slot `slot` (read path). Ring indexing
    /// is the backend's contract: absolute position `pos` lives at slot
    /// `pos % capacity`, and the attention window for a query at `pos`
    /// starts at `(pos + 1).saturating_sub(capacity)`.
    #[inline]
    pub(crate) fn k_row(&self, layer: usize, slot: usize) -> &[f32] {
        let off = (slot % CHUNK_POSITIONS) * self.kv_dim;
        &self.k[layer][slot / CHUNK_POSITIONS][off..off + self.kv_dim]
    }

    /// One layer's V row at ring slot `slot` (read path).
    #[inline]
    pub(crate) fn v_row(&self, layer: usize, slot: usize) -> &[f32] {
        let off = (slot % CHUNK_POSITIONS) * self.kv_dim;
        &self.v[layer][slot / CHUNK_POSITIONS][off..off + self.kv_dim]
    }

    /// Write absolute position `pos`'s K/V rows of one layer into their
    /// ring slot. Chunks shared with a fork are cloned here, lazily —
    /// the copy-on-write point.
    #[inline]
    pub(crate) fn write_kv(&mut self, layer: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        let slot = pos % self.capacity;
        let chunk = slot / CHUNK_POSITIONS;
        let off = (slot % CHUNK_POSITIONS) * self.kv_dim;
        Arc::make_mut(&mut self.k[layer][chunk])[off..off + self.kv_dim]
            .copy_from_slice(krow);
        Arc::make_mut(&mut self.v[layer][chunk])[off..off + self.kv_dim]
            .copy_from_slice(vrow);
    }

    /// Mark `t` freshly written positions as resident.
    pub(crate) fn advance(&mut self, t: usize) {
        self.len += t;
    }

    /// The cache must match the model it is used with.
    pub(crate) fn check_spec(&self, spec: &ModelSpec) -> Result<()> {
        let mc = &spec.config;
        ensure!(
            self.n_layers == mc.n_layers && self.kv_dim == mc.kv_dim(),
            "kv cache shape [{} layers, kv_dim {}] does not match model {:?} \
             [{} layers, kv_dim {}]",
            self.n_layers,
            self.kv_dim,
            mc.name,
            mc.n_layers,
            mc.kv_dim(),
        );
        Ok(())
    }
}

/// Bytes actually resident across a set of live caches, COW-aware:
/// chunks shared between forks (or between a scheduler slot and a
/// prefix-cache store entry) are counted **once**, by deduplicating on
/// the shared `Arc` allocation's address. This is the measured
/// counterpart of the analytic [`KvCache::bytes`] upper bound — with
/// heavy prefix sharing it can be far smaller than `Σ bytes()`.
/// Order-independent and read-only.
pub fn kv_resident_bytes<'a>(caches: impl IntoIterator<Item = &'a KvCache>) -> u64 {
    let mut seen: std::collections::HashSet<*const Vec<f32>> = std::collections::HashSet::new();
    let mut bytes = 0u64;
    for c in caches {
        for layer in c.k.iter().chain(c.v.iter()) {
            for chunk in layer {
                if seen.insert(Arc::as_ptr(chunk)) {
                    bytes += (chunk.len() * std::mem::size_of::<f32>()) as u64;
                }
            }
        }
    }
    bytes
}

/// The execution ABI between the coordinator and the compute substrate.
///
/// `host` is the registry-ordered host mirror of the parameters owned by
/// `Session`; backends that keep device-resident copies (PJRT) ignore it
/// on the execute calls and refresh their copies through `sync_param`.
pub trait Backend {
    /// Human-readable backend name ("host" / "pjrt").
    fn name(&self) -> &'static str;

    /// (Re)upload one parameter from its host mirror. No-op on backends
    /// that execute directly from host memory.
    fn sync_param(&mut self, idx: usize, data: &[f32]) -> Result<()>;

    /// One fwd/bwd step: loss, all grads (registry order), and the
    /// per-parameter squared Frobenius gradient norms.
    fn fwd_bwd(&self, host: &[Vec<f32>], batch: &Batch) -> Result<StepOutput>;

    /// One eval step: masked loss + per-position teacher-forced hits.
    fn predict(&self, host: &[Vec<f32>], batch: &Batch) -> Result<EvalOutput>;

    /// Fused Adam update of parameter `idx` (Algorithm 1 lines 9-11, no
    /// bias correction): updates `p` in place and returns
    /// `(m', v', sum(g^2))` — the `ref.py::adam_ref` contract.
    fn adam_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// The additional momentum step (Algorithm 1 line 16): updates `p`
    /// in place — the `ref.py::momentum_tail_ref` contract.
    fn tail_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<()>;

    /// Serving entry point: run `tokens` (one sequence, absolute
    /// positions `cache.len()..cache.len() + tokens.len()`), appending
    /// K/V into `cache`, and return the final position's logits `[v]`.
    fn prefill(&self, host: &[Vec<f32>], tokens: &[i32], cache: &mut KvCache)
               -> Result<Vec<f32>> {
        let _ = (host, tokens, cache);
        bail!("backend {:?} does not support incremental decode", self.name())
    }

    /// Serving entry point: prefill several slots in one stacked ragged
    /// `[batch, seq]` forward — slot `i` runs `chunks[i]` at absolute
    /// positions `caches[i].len()..`, appending K/V into its own cache,
    /// and slot `i`'s final-position logits come back as row `i`.
    ///
    /// Backends that can stack every slot's rows into one activation
    /// matrix (the host backend) override this so each layer runs one
    /// GEMM per projection across all admitted prompts instead of one
    /// per prompt; the default simply loops [`Backend::prefill`], which
    /// keeps the batched and per-slot admission paths semantically
    /// interchangeable.
    fn prefill_batch(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            chunks.len() == caches.len(),
            "prefill_batch: {} chunks, {} caches",
            chunks.len(),
            caches.len()
        );
        let mut out = Vec::with_capacity(chunks.len());
        for (tokens, cache) in chunks.iter().zip(caches.iter_mut()) {
            out.push(self.prefill(host, tokens, cache)?);
        }
        Ok(out)
    }

    /// Serving entry point: decode one token at absolute position `pos`
    /// (must equal `cache.len()`), appending its K/V, and return the
    /// next-token logits `[v]`.
    fn decode_step(&self, host: &[Vec<f32>], token: i32, pos: usize, cache: &mut KvCache)
                   -> Result<Vec<f32>> {
        let _ = (host, token, pos, cache);
        bail!("backend {:?} does not support incremental decode", self.name())
    }

    /// Serving entry point: decode one token for *each* of `caches`
    /// (scheduler slots) in a single forward — slot `i` decodes
    /// `tokens[i]` at absolute position `positions[i]`
    /// (= `caches[i].len()`), appending its K/V to its own cache, and
    /// slot `i`'s next-token logits come back as row `i`.
    ///
    /// Backends that can stack slots into one `[batch, hidden]`
    /// activation matrix (the host backend) override this so each layer
    /// runs one GEMM per projection instead of one per slot; the
    /// default simply loops [`Backend::decode_step`], which keeps the
    /// batched and per-slot paths semantically interchangeable.
    fn decode_batch(
        &self,
        host: &[Vec<f32>],
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            tokens.len() == positions.len() && tokens.len() == caches.len(),
            "decode_batch: {} tokens, {} positions, {} caches",
            tokens.len(),
            positions.len(),
            caches.len()
        );
        let mut out = Vec::with_capacity(tokens.len());
        for ((&tok, &pos), cache) in tokens.iter().zip(positions).zip(caches.iter_mut()) {
            out.push(self.decode_step(host, tok, pos, cache)?);
        }
        Ok(out)
    }

    /// Serving entry point for speculative decoding: a multi-token
    /// *cached* forward per slot that returns logits at **every**
    /// position of the chunk, not just the final one. Slot `i` runs
    /// `chunks[i]` (its last sampled token followed by the draft) at
    /// absolute positions `positions[i]..` (must equal
    /// `caches[i].len()`), appending each position's K/V to its own
    /// cache; row `i` of the result is slot `i`'s stacked logits,
    /// `chunks[i].len() * vocab` floats (position-major).
    ///
    /// K/V for *all* draft positions lands in the cache — the caller
    /// verifies the draft against the returned logits and rolls the
    /// rejected suffix back with [`KvCache::truncate`]. Backends that
    /// can stack every slot's rows into one ragged activation matrix
    /// (the host backend) override this so the whole tick is one GEMM
    /// per projection; the default loops [`Backend::decode_step`]
    /// position by position, which keeps the batched and per-token
    /// paths semantically interchangeable.
    fn verify_step(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            chunks.len() == positions.len() && chunks.len() == caches.len(),
            "verify_step: {} chunks, {} positions, {} caches",
            chunks.len(),
            positions.len(),
            caches.len()
        );
        let mut out = Vec::with_capacity(chunks.len());
        for ((tokens, &start), cache) in chunks.iter().zip(positions).zip(caches.iter_mut()) {
            ensure!(!tokens.is_empty(), "verify_step: empty token chunk");
            let mut rows = Vec::new();
            for (j, &tok) in tokens.iter().enumerate() {
                rows.extend_from_slice(&self.decode_step(host, tok, start + j, cache)?);
            }
            out.push(rows);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrip() {
        assert_eq!(BackendKind::parse("host").unwrap(), BackendKind::Host);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Host.as_str(), "host");
        assert_eq!(BackendKind::Pjrt.as_str(), "pjrt");
    }

    fn tiny_cache(capacity: usize) -> KvCache {
        let spec = crate::modelspec::Manifest::builtin().model("tiny").unwrap().clone();
        KvCache::new(&spec, capacity).unwrap()
    }

    /// Write `n` positions of recognizable rows (k = pos+1, v = -(pos+1))
    /// into every layer.
    fn fill(cache: &mut KvCache, n: usize) {
        let kd = cache.kv_dim();
        for p in cache.len()..cache.len() + n {
            let krow = vec![p as f32 + 1.0; kd];
            let vrow = vec![-(p as f32) - 1.0; kd];
            for layer in 0..cache.n_layers() {
                cache.write_kv(layer, p, &krow, &vrow);
            }
            cache.advance(1);
        }
    }

    #[test]
    fn fork_shares_prefix_and_diverges_on_write() {
        let mut parent = tiny_cache(40);
        fill(&mut parent, 3);
        let mut child = KvCache::fork_from(&parent, 2).unwrap();
        assert_eq!(child.len(), 2);
        assert_eq!(child.capacity(), parent.capacity());
        // shared prefix reads through to the parent's chunks
        assert_eq!(child.k_row(0, 1)[0], 2.0);
        assert_eq!(child.v_row(0, 1)[0], -2.0);
        // a divergent write in the child leaves the parent intact (COW)
        let kd = child.kv_dim();
        child.write_kv(0, 2, &vec![9.0; kd], &vec![9.0; kd]);
        child.advance(1);
        assert_eq!(child.k_row(0, 2)[0], 9.0);
        assert_eq!(parent.k_row(0, 2)[0], 3.0, "parent chunk must not be clobbered");
        // and vice versa: parent writes never reach the fork
        fill(&mut parent, 1); // position 3
        assert_eq!(child.len(), 3);
        assert_eq!(child.k_row(0, 2)[0], 9.0);
    }

    #[test]
    fn fork_rejects_evicted_prefixes_and_overlong_lens() {
        let mut parent = tiny_cache(4);
        fill(&mut parent, 6); // wrapped: positions 4, 5 overwrote 0, 1
        assert!(KvCache::fork_from(&parent, 7).is_err(), "beyond parent len");
        assert!(KvCache::fork_from(&parent, 6).is_ok(), "fork at the tip");
        assert!(KvCache::fork_from(&parent, 5).is_ok(), "one short of the tip");
        let err = KvCache::fork_from(&parent, 4).unwrap_err();
        assert!(format!("{err:#}").contains("evicted"), "{err:#}");
        // an unwrapped parent forks anywhere
        let mut flat = tiny_cache(8);
        fill(&mut flat, 6);
        for len in 0..=6 {
            assert!(KvCache::fork_from(&flat, len).is_ok(), "len {len}");
        }
    }

    #[test]
    fn resident_bytes_dedupes_cow_shared_chunks() {
        // capacity a chunk multiple, so physical chunks = analytic bytes
        let mut parent = tiny_cache(2 * CHUNK_POSITIONS);
        fill(&mut parent, 3);
        let solo = kv_resident_bytes([&parent]);
        assert_eq!(solo, parent.bytes() as u64, "single unwrapped cache = analytic bytes");
        // a fork shares every chunk: together they still occupy one cache
        let mut child = KvCache::fork_from(&parent, 2).unwrap();
        assert_eq!(kv_resident_bytes([&parent, &child]), solo);
        // order never matters
        assert_eq!(kv_resident_bytes([&child, &parent]), solo);
        // a divergent write copies exactly one k and one v chunk in one
        // layer — 2 chunks of divergence, everything else still shared
        let kd = child.kv_dim();
        child.write_kv(0, 2, &vec![9.0; kd], &vec![9.0; kd]);
        child.advance(1);
        let after = kv_resident_bytes([&parent, &child]);
        assert_eq!(after, solo + 2 * (CHUNK_POSITIONS * kd * 4) as u64);
        // independent caches simply sum
        let other = tiny_cache(2 * CHUNK_POSITIONS);
        assert_eq!(kv_resident_bytes([&parent, &other]), solo + other.bytes() as u64);
        assert_eq!(kv_resident_bytes(std::iter::empty()), 0);
    }

    #[test]
    fn copy_prefix_converts_ring_layouts() {
        let mut parent = tiny_cache(10);
        fill(&mut parent, 6);
        let child = KvCache::copy_prefix(&parent, 4, 64).unwrap();
        assert_eq!(child.len(), 4);
        assert_eq!(child.capacity(), 64);
        for p in 0..4 {
            assert_eq!(child.k_row(0, p)[0], p as f32 + 1.0);
            assert_eq!(child.v_row(0, p)[0], -(p as f32) - 1.0);
        }
        // rejects: beyond parent len, capacity too small, wrapped parent
        assert!(KvCache::copy_prefix(&parent, 7, 64).is_err());
        assert!(KvCache::copy_prefix(&parent, 6, 5).is_err());
        let mut wrapped = tiny_cache(4);
        fill(&mut wrapped, 6);
        let err = KvCache::copy_prefix(&wrapped, 4, 64).unwrap_err();
        assert!(format!("{err:#}").contains("wrapped"), "{err:#}");
    }

    #[test]
    fn truncate_rolls_back_across_a_chunk_boundary() {
        // capacity 40 = 3 chunks; fill past the first 16-position chunk,
        // roll back across the boundary, and re-decode different rows
        let mut cache = tiny_cache(40);
        fill(&mut cache, 20);
        cache.truncate(10).unwrap();
        assert_eq!(cache.len(), 10);
        // retained prefix is untouched
        assert_eq!(cache.k_row(0, 9)[0], 10.0);
        assert_eq!(cache.v_row(0, 9)[0], -10.0);
        // new writes land where the rolled-back rows were (both sides of
        // the chunk-1 boundary at slot 16)
        let kd = cache.kv_dim();
        for p in 10..18 {
            for layer in 0..cache.n_layers() {
                cache.write_kv(layer, p, &vec![100.0 + p as f32; kd], &vec![0.5; kd]);
            }
            cache.advance(1);
        }
        assert_eq!(cache.len(), 18);
        assert_eq!(cache.k_row(0, 12)[0], 112.0);
        assert_eq!(cache.k_row(0, 17)[0], 117.0);
        // rolling back below zero-length is fine; beyond len is not
        assert!(cache.truncate(19).is_err());
        cache.truncate(0).unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn truncate_is_wrap_aware() {
        // wrapped ring (6 positions into capacity 4): positions 4, 5
        // overwrote 0, 1 — rolling back one position is safe (slot
        // `len % capacity` frees exactly when its wrapped-out position
        // leaves every window) but deeper rollback cannot restore the
        // clobbered rows and must be refused
        let mut cache = tiny_cache(4);
        fill(&mut cache, 6);
        let err = cache.truncate(3).unwrap_err();
        assert!(format!("{err:#}").contains("wrapped"), "{err:#}");
        cache.truncate(5).unwrap();
        assert_eq!(cache.len(), 5);
        // an unwrapped ring rolls back anywhere
        let mut flat = tiny_cache(8);
        fill(&mut flat, 8); // full but never wrapped
        flat.truncate(2).unwrap();
        assert_eq!(flat.len(), 2);
        assert_eq!(flat.k_row(0, 1)[0], 2.0);
    }

    #[test]
    fn truncate_on_a_cow_fork_leaves_the_parent_intact() {
        let mut parent = tiny_cache(40);
        fill(&mut parent, 6);
        let mut child = KvCache::fork_from(&parent, 6).unwrap();
        // the child speculates: draft rows at positions 6..9, then the
        // verifier rejects them all
        let kd = child.kv_dim();
        for p in 6..9 {
            for layer in 0..child.n_layers() {
                child.write_kv(layer, p, &vec![9.9; kd], &vec![9.9; kd]);
            }
            child.advance(1);
        }
        child.truncate(6).unwrap();
        assert_eq!(child.len(), 6);
        // the parent never saw the draft writes (COW cloned the shared
        // chunk before it was dirtied) and keeps its own tip
        assert_eq!(parent.len(), 6);
        for p in 0..6 {
            assert_eq!(parent.k_row(0, p)[0], p as f32 + 1.0);
            assert_eq!(parent.v_row(0, p)[0], -(p as f32) - 1.0);
        }
        // the parent can keep appending from its tip as if never forked
        fill(&mut parent, 2);
        assert_eq!(parent.k_row(0, 7)[0], 8.0);
        // and the child's retained prefix still reads the shared rows
        assert_eq!(child.k_row(0, 5)[0], 6.0);
    }

    #[test]
    fn fork_at_tip_of_wrapped_parent_reads_resident_window() {
        let mut parent = tiny_cache(4);
        fill(&mut parent, 6);
        let child = KvCache::fork_from(&parent, 6).unwrap();
        // resident window is positions 2..6 at slots 2, 3, 0, 1
        assert_eq!(child.k_row(0, 2)[0], 3.0); // position 2
        assert_eq!(child.k_row(0, 0)[0], 5.0); // position 4 wrapped onto slot 0
        assert_eq!(child.k_row(0, 1)[0], 6.0); // position 5 wrapped onto slot 1
    }
}
