//! Execution backends — the subsystem that runs the compute graphs.
//!
//! The coordinator (trainer, optimizers, experiments) speaks one small
//! execution ABI, [`Backend`]: fwd/bwd, predict, the fused-Adam update,
//! the momentum-tail update, parameter upload, and the serving entry
//! points ([`Backend::prefill`] / [`Backend::decode_step`] /
//! [`Backend::decode_batch`] over per-slot [`KvCache`]s). Two
//! implementations exist:
//!
//! - [`HostBackend`] (default): the full transformer forward/backward,
//!   masked cross-entropy, per-parameter squared gradient norms, and
//!   fused Adam in pure Rust — numerically mirroring the JAX oracles in
//!   `python/compile/kernels/ref.py` and `python/compile/model.py`.
//!   Runs anywhere, deterministically, with no compiled-graph sidecar.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): the original
//!   AOT-artifact path — PJRT client + compiled HLO executables with
//!   device-resident parameters.
//!
//! `Session` owns a `Box<dyn Backend>`; everything above it is
//! backend-agnostic.

pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use host::HostBackend;

use anyhow::{bail, ensure, Result};

use crate::data::Batch;
use crate::modelspec::ModelSpec;
use crate::runtime::{EvalOutput, StepOutput};

/// Which backend a run executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust host execution (default; no artifacts required).
    Host,
    /// PJRT + AOT HLO artifacts (requires the `pjrt` cargo feature and
    /// an `artifacts/` directory produced by `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(BackendKind::Host),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (expected \"host\" or \"pjrt\")"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Per-layer key/value ring buffers for incremental decode.
///
/// One cache belongs to one generation stream (one scheduler slot). Each
/// layer holds `[capacity, kv_dim]` K and V buffers where `kv_dim =
/// n_kv_heads * head_dim` — GQA-sized, so a cache is `n_heads /
/// n_kv_heads` times smaller than the full attention residency. Absolute
/// position `p` lives in ring slot `p % capacity`; once `len > capacity`
/// decode degrades gracefully to sliding-window attention over the last
/// `capacity` positions (RoPE still uses absolute positions).
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    kv_dim: usize,
    capacity: usize,
    /// absolute positions appended so far (== the next decode position)
    len: usize,
    /// per-layer keys, `[capacity * kv_dim]` each
    k: Vec<Vec<f32>>,
    /// per-layer values, `[capacity * kv_dim]` each
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Cache for `spec` holding up to `capacity` positions.
    pub fn new(spec: &ModelSpec, capacity: usize) -> Result<Self> {
        let mc = &spec.config;
        ensure!(capacity > 0, "kv cache capacity must be > 0");
        let kv_dim = mc.kv_dim();
        Ok(KvCache {
            n_layers: mc.n_layers,
            kv_dim,
            capacity,
            len: 0,
            k: (0..mc.n_layers).map(|_| vec![0.0; capacity * kv_dim]).collect(),
            v: (0..mc.n_layers).map(|_| vec![0.0; capacity * kv_dim]).collect(),
        })
    }

    /// Positions appended so far — the next decode position.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum resident positions before the ring wraps.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    /// Resident K/V bytes (both buffers, all layers) — the scheduler's
    /// memory-accounting unit.
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.capacity * self.kv_dim * std::mem::size_of::<f32>()
    }

    /// [`Self::bytes`] as a closed form, without building a cache.
    pub fn bytes_for(spec: &ModelSpec, capacity: usize) -> usize {
        let mc = &spec.config;
        2 * mc.n_layers * capacity * mc.kv_dim() * std::mem::size_of::<f32>()
    }

    /// Forget all cached positions (slot reuse).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Mutable K/V buffers of one layer (backend read/write path).
    /// Ring indexing is the backend's contract: absolute position `pos`
    /// lives at slot `pos % capacity`, and the attention window for a
    /// query at `pos` starts at `(pos + 1).saturating_sub(capacity)`.
    pub(crate) fn layer_mut(&mut self, layer: usize) -> (&mut [f32], &mut [f32]) {
        (&mut self.k[layer], &mut self.v[layer])
    }

    /// Mark `t` freshly written positions as resident.
    pub(crate) fn advance(&mut self, t: usize) {
        self.len += t;
    }

    /// The cache must match the model it is used with.
    pub(crate) fn check_spec(&self, spec: &ModelSpec) -> Result<()> {
        let mc = &spec.config;
        ensure!(
            self.n_layers == mc.n_layers && self.kv_dim == mc.kv_dim(),
            "kv cache shape [{} layers, kv_dim {}] does not match model {:?} \
             [{} layers, kv_dim {}]",
            self.n_layers,
            self.kv_dim,
            mc.name,
            mc.n_layers,
            mc.kv_dim(),
        );
        Ok(())
    }
}

/// The execution ABI between the coordinator and the compute substrate.
///
/// `host` is the registry-ordered host mirror of the parameters owned by
/// `Session`; backends that keep device-resident copies (PJRT) ignore it
/// on the execute calls and refresh their copies through `sync_param`.
pub trait Backend {
    /// Human-readable backend name ("host" / "pjrt").
    fn name(&self) -> &'static str;

    /// (Re)upload one parameter from its host mirror. No-op on backends
    /// that execute directly from host memory.
    fn sync_param(&mut self, idx: usize, data: &[f32]) -> Result<()>;

    /// One fwd/bwd step: loss, all grads (registry order), and the
    /// per-parameter squared Frobenius gradient norms.
    fn fwd_bwd(&self, host: &[Vec<f32>], batch: &Batch) -> Result<StepOutput>;

    /// One eval step: masked loss + per-position teacher-forced hits.
    fn predict(&self, host: &[Vec<f32>], batch: &Batch) -> Result<EvalOutput>;

    /// Fused Adam update of parameter `idx` (Algorithm 1 lines 9-11, no
    /// bias correction): updates `p` in place and returns
    /// `(m', v', sum(g^2))` — the `ref.py::adam_ref` contract.
    fn adam_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// The additional momentum step (Algorithm 1 line 16): updates `p`
    /// in place — the `ref.py::momentum_tail_ref` contract.
    fn tail_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<()>;

    /// Serving entry point: run `tokens` (one sequence, absolute
    /// positions `cache.len()..cache.len() + tokens.len()`), appending
    /// K/V into `cache`, and return the final position's logits `[v]`.
    fn prefill(&self, host: &[Vec<f32>], tokens: &[i32], cache: &mut KvCache)
               -> Result<Vec<f32>> {
        let _ = (host, tokens, cache);
        bail!("backend {:?} does not support incremental decode", self.name())
    }

    /// Serving entry point: decode one token at absolute position `pos`
    /// (must equal `cache.len()`), appending its K/V, and return the
    /// next-token logits `[v]`.
    fn decode_step(&self, host: &[Vec<f32>], token: i32, pos: usize, cache: &mut KvCache)
                   -> Result<Vec<f32>> {
        let _ = (host, token, pos, cache);
        bail!("backend {:?} does not support incremental decode", self.name())
    }

    /// Serving entry point: decode one token for *each* of `caches`
    /// (scheduler slots) in a single forward — slot `i` decodes
    /// `tokens[i]` at absolute position `positions[i]`
    /// (= `caches[i].len()`), appending its K/V to its own cache, and
    /// slot `i`'s next-token logits come back as row `i`.
    ///
    /// Backends that can stack slots into one `[batch, hidden]`
    /// activation matrix (the host backend) override this so each layer
    /// runs one GEMM per projection instead of one per slot; the
    /// default simply loops [`Backend::decode_step`], which keeps the
    /// batched and per-slot paths semantically interchangeable.
    fn decode_batch(
        &self,
        host: &[Vec<f32>],
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            tokens.len() == positions.len() && tokens.len() == caches.len(),
            "decode_batch: {} tokens, {} positions, {} caches",
            tokens.len(),
            positions.len(),
            caches.len()
        );
        let mut out = Vec::with_capacity(tokens.len());
        for ((&tok, &pos), cache) in tokens.iter().zip(positions).zip(caches.iter_mut()) {
            out.push(self.decode_step(host, tok, pos, cache)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrip() {
        assert_eq!(BackendKind::parse("host").unwrap(), BackendKind::Host);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Host.as_str(), "host");
        assert_eq!(BackendKind::Pjrt.as_str(), "pjrt");
    }
}
