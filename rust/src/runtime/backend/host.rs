//! `HostBackend` — the pure-Rust execution backend.
//!
//! Implements the full training ABI with no AOT artifacts: the
//! LLaMA-architecture forward pass (RMSNorm → RoPE → GQA causal
//! attention → SwiGLU MLP), masked next-token cross-entropy, a
//! hand-derived backward pass producing gradients for **every**
//! registry parameter, the per-parameter squared Frobenius gradient
//! norms (the Pallas by-product that feeds the MISA sampler), and the
//! fused-Adam / momentum-tail updates.
//!
//! Numerics mirror the JAX oracles (`python/compile/model.py`,
//! `python/compile/kernels/ref.py`) so the Rust results are checkable
//! against the Python test suite: same RMSNorm epsilon, same RoPE pair
//! convention, same GQA head-repeat layout, same loss denominator
//! clamp, same Adam update (no bias correction). The finite-difference
//! gradient checks live in `rust/tests/host_backend.rs`.

#![allow(clippy::needless_range_loop)]

use anyhow::{anyhow, ensure, Result};

use std::borrow::Cow;
use std::sync::Mutex;

use crate::data::Batch;
use crate::modelspec::ModelSpec;
use crate::optim::adam::{AdamHyper, AdamState};
use crate::runtime::backend::{Backend, KvCache};
use crate::runtime::{EvalOutput, StepOutput};
use crate::tensor::{gemm_nn, gemm_nn_into, gemm_nt, gemm_tn_acc, par, simd};

/// RoPE base frequency (python/compile/configs.py default).
const ROPE_THETA: f32 = 10_000.0;

/// RMSNorm epsilon (python/compile/model.py `_rms_norm`).
const NORM_EPS: f32 = 1e-5;

/// Minimum number of positions the precomputed RoPE tables cover. The
/// tables are built once in [`HostBackend::new`] for
/// `max(config.seq_len, ROPE_MIN_POSITIONS)` so decode steps can index
/// them by absolute position well past the training sequence length;
/// positions beyond the tables fall back to computing the angle inline.
const ROPE_MIN_POSITIONS: usize = 2048;

/// Registry indices of one transformer layer's parameters.
struct LayerIdx {
    attn_norm: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    mlp_norm: usize,
    wgate: usize,
    wup: usize,
    wdown: usize,
}

/// Registry indices of the whole model.
struct Layout {
    layers: Vec<LayerIdx>,
    final_norm: usize,
    embed: usize,
    head: usize,
}

impl Layout {
    fn build(spec: &ModelSpec) -> Result<Layout> {
        let mc = &spec.config;
        let (d, f, v, kd) = (mc.dim, mc.ffn_dim, mc.vocab, mc.kv_dim());
        let find = |name: String, shape: &[usize]| -> Result<usize> {
            let idx = spec
                .param_index(&name)
                .ok_or_else(|| anyhow!("host backend: missing param {name:?}"))?;
            ensure!(
                spec.params[idx].shape.as_slice() == shape,
                "param {name:?} has shape {:?}, expected {shape:?}",
                spec.params[idx].shape
            );
            Ok(idx)
        };
        let mut layers = Vec::with_capacity(mc.n_layers);
        for i in 0..mc.n_layers {
            let p = |s: &str| format!("layers.{i}.{s}");
            layers.push(LayerIdx {
                attn_norm: find(p("attn_norm"), &[d])?,
                wq: find(p("wq"), &[d, d])?,
                wk: find(p("wk"), &[d, kd])?,
                wv: find(p("wv"), &[d, kd])?,
                wo: find(p("wo"), &[d, d])?,
                mlp_norm: find(p("mlp_norm"), &[d])?,
                wgate: find(p("wgate"), &[d, f])?,
                wup: find(p("wup"), &[d, f])?,
                wdown: find(p("wdown"), &[f, d])?,
            });
        }
        Ok(Layout {
            layers,
            final_norm: find("final_norm".into(), &[d])?,
            embed: find("embed".into(), &[v, d])?,
            head: find("head".into(), &[d, v])?,
        })
    }
}

/// Per-layer forward intermediates kept for the backward pass.
struct LayerTrace {
    /// residual stream entering the layer `[n, d]`
    x_in: Vec<f32>,
    /// rsqrt factors of the attention RMSNorm `[n]`
    r1: Vec<f32>,
    /// normalized attention input `[n, d]`
    h1: Vec<f32>,
    /// post-RoPE queries `[n, d]`
    q: Vec<f32>,
    /// post-RoPE keys `[n, kd]`
    k: Vec<f32>,
    /// values `[n, kd]`
    v: Vec<f32>,
    /// softmax probabilities `[b, nh, s, s]` (zero above the diagonal)
    att: Vec<f32>,
    /// concatenated head outputs `[n, d]`
    concat: Vec<f32>,
    /// residual stream after attention `[n, d]`
    x_mid: Vec<f32>,
    /// rsqrt factors of the MLP RMSNorm `[n]`
    r2: Vec<f32>,
    /// normalized MLP input `[n, d]`
    h2: Vec<f32>,
    /// gate pre-activation `[n, f]`
    gpre: Vec<f32>,
    /// up projection `[n, f]`
    up: Vec<f32>,
    /// silu(gpre) * up `[n, f]`
    act: Vec<f32>,
}

impl LayerTrace {
    /// Resident bytes of this layer's retained intermediates.
    fn bytes(&self) -> u64 {
        [
            &self.x_in, &self.r1, &self.h1, &self.q, &self.k, &self.v, &self.att,
            &self.concat, &self.x_mid, &self.r2, &self.h2, &self.gpre, &self.up,
            &self.act,
        ]
        .iter()
        .map(|b| b.len())
        .sum::<usize>() as u64
            * 4
    }
}

/// Whole-model forward intermediates.
struct Trace<'a> {
    layers: Vec<LayerTrace>,
    /// residual stream after the last layer `[n, d]`
    x_last: Vec<f32>,
    /// rsqrt factors of the final RMSNorm `[n]`
    rf: Vec<f32>,
    /// normalized head input `[n, d]`
    hf: Vec<f32>,
    /// logits `[n, v]`
    logits: Vec<f32>,
    /// RoPE tables: borrowed from the backend's precomputed buffers
    /// unless the batch is longer than they cover
    cos: Cow<'a, [f32]>,
    sin: Cow<'a, [f32]>,
    denom: f64,
    loss: f64,
}

impl Trace<'_> {
    /// Resident bytes of the whole forward trace — the activation
    /// memory the backward pass keeps alive. Counts owned buffers
    /// only; borrowed RoPE tables belong to the backend, not the step.
    fn bytes(&self) -> u64 {
        let layers: u64 = self.layers.iter().map(LayerTrace::bytes).sum();
        let top = (self.x_last.len() + self.rf.len() + self.hf.len() + self.logits.len())
            as u64
            * 4;
        let rope = [&self.cos, &self.sin]
            .iter()
            .map(|c| match c {
                Cow::Owned(v) => v.len(),
                Cow::Borrowed(_) => 0,
            })
            .sum::<usize>() as u64
            * 4;
        layers + top + rope
    }
}

/// Largest stacked-row count the shared workspace keeps warm. Decode
/// and speculative-verify ticks run `slots * (draft + 1)` rows — well
/// under this — so their scratch is never reallocated; a one-shot long
/// prefill may grow past it, and shrinks back afterwards so the
/// backend does not pin prefill-sized buffers for its lifetime.
const WS_RETAIN_ROWS: usize = 64;

/// Reusable scratch for the decode hot path. One decode step used to
/// allocate ~10 fresh `Vec`s per layer per token; at batch 1 that
/// allocation churn is a measurable slice of the step. The buffers are
/// `resize`d (a no-op once warm) and fully overwritten each call.
#[derive(Default)]
struct DecodeWorkspace {
    /// residual stream `[bsz, d]`
    x: Vec<f32>,
    /// RMSNorm output, reused for attn-, mlp- and final-norm `[bsz, d]`
    h: Vec<f32>,
    /// post-RoPE queries `[bsz, d]`
    q: Vec<f32>,
    /// post-RoPE keys `[bsz, kd]`
    k: Vec<f32>,
    /// values `[bsz, kd]`
    v: Vec<f32>,
    /// concatenated head outputs `[bsz, d]`
    concat: Vec<f32>,
    /// projection output, reused for attn-out and mlp-down `[bsz, d]`
    proj: Vec<f32>,
    /// gate pre-activation `[bsz, f]`
    gpre: Vec<f32>,
    /// up projection `[bsz, f]`
    up: Vec<f32>,
    /// silu(gpre) * up `[bsz, f]`
    act: Vec<f32>,
    /// LM-head output `[bsz, v]` — the largest per-token buffer; per-slot
    /// rows are copied out of it (the ABI returns owned rows) but the
    /// flat matrix itself is never reallocated
    logits: Vec<f32>,
}

impl DecodeWorkspace {
    /// Release capacity above a `rows`-row envelope. `shrink_to` only
    /// trims capacity, so the next call's `resize` still finds the
    /// retained envelope warm. (Attention-score scratch is per-thread
    /// — see `SCORES` — not part of this workspace.)
    fn shrink_to_rows(&mut self, rows: usize, d: usize, kd: usize, f: usize, v: usize) {
        fn cap(b: &mut Vec<f32>, n: usize) {
            b.truncate(n);
            b.shrink_to(n);
        }
        cap(&mut self.x, rows * d);
        cap(&mut self.h, rows * d);
        cap(&mut self.q, rows * d);
        cap(&mut self.k, rows * kd);
        cap(&mut self.v, rows * kd);
        cap(&mut self.concat, rows * d);
        cap(&mut self.proj, rows * d);
        cap(&mut self.gpre, rows * f);
        cap(&mut self.up, rows * f);
        cap(&mut self.act, rows * f);
        cap(&mut self.logits, rows * v);
    }

    /// Resident bytes across the scratch buffers — capacity, not
    /// length, because capacity is what stays allocated between calls.
    fn bytes(&self) -> u64 {
        [
            &self.x, &self.h, &self.q, &self.k, &self.v, &self.concat, &self.proj,
            &self.gpre, &self.up, &self.act, &self.logits,
        ]
        .iter()
        .map(|b| b.capacity())
        .sum::<usize>() as u64
            * 4
    }
}

/// The pure-Rust backend. Stateless beyond the model layout, the
/// precomputed RoPE tables and the reusable decode workspace: it
/// executes directly from the session's host parameter mirror.
pub struct HostBackend {
    spec: ModelSpec,
    layout: Layout,
    /// cos/sin tables `[rope_positions, head_dim/2]`, built once
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    rope_positions: usize,
    /// decode scratch; a Mutex (not RefCell) so the backend stays Sync
    ws: Mutex<DecodeWorkspace>,
}

impl HostBackend {
    pub fn new(spec: ModelSpec) -> Result<Self> {
        let mc = &spec.config;
        ensure!(mc.n_heads > 0 && mc.dim % mc.n_heads == 0,
                "dim {} not divisible by n_heads {}", mc.dim, mc.n_heads);
        ensure!(mc.n_kv_heads > 0 && mc.n_heads % mc.n_kv_heads == 0,
                "n_heads {} not divisible by n_kv_heads {}", mc.n_heads, mc.n_kv_heads);
        ensure!(mc.head_dim() % 2 == 0, "head_dim {} must be even for RoPE", mc.head_dim());
        let layout = Layout::build(&spec)?;
        // precompute the RoPE tables once, keyed by the max sequence
        // length this backend will see (training seq_len, or the serve
        // horizon, whichever is larger)
        let rope_positions = mc.seq_len.max(ROPE_MIN_POSITIONS);
        let (rope_cos, rope_sin) = rope_tables(rope_positions, mc.head_dim(), ROPE_THETA);
        Ok(HostBackend {
            spec,
            layout,
            rope_cos,
            rope_sin,
            rope_positions,
            ws: Mutex::new(DecodeWorkspace::default()),
        })
    }

    /// Precomputed cos/sin tables covering `s` positions; falls back to
    /// a fresh computation for batches longer than the precomputed span.
    fn rope_view(&self, s: usize) -> (Cow<'_, [f32]>, Cow<'_, [f32]>) {
        if s <= self.rope_positions {
            (Cow::Borrowed(&self.rope_cos[..]), Cow::Borrowed(&self.rope_sin[..]))
        } else {
            let (c, sn) = rope_tables(s, self.spec.config.head_dim(), ROPE_THETA);
            (Cow::Owned(c), Cow::Owned(sn))
        }
    }

    /// Rotate one row's heads at absolute position `pos` (decode path).
    fn rope_row(&self, row: &mut [f32], n_heads: usize, pos: usize) {
        let hd = self.spec.config.head_dim();
        let half = hd / 2;
        for h in 0..n_heads {
            let off = h * hd;
            for i in 0..half {
                let (c, sn) = if pos < self.rope_positions {
                    (self.rope_cos[pos * half + i], self.rope_sin[pos * half + i])
                } else {
                    let freq = ROPE_THETA.powf(-((2 * i) as f32) / hd as f32);
                    let ang = pos as f32 * freq;
                    (ang.cos(), ang.sin())
                };
                let e = row[off + 2 * i];
                let o = row[off + 2 * i + 1];
                row[off + 2 * i] = e * c - o * sn;
                row[off + 2 * i + 1] = e * sn + o * c;
            }
        }
    }

    /// Masked mean cross-entropy in f64 — the high-precision entry the
    /// finite-difference gradient checks probe.
    pub fn loss_f64(&self, host: &[Vec<f32>], batch: &Batch) -> Result<f64> {
        Ok(self.forward(host, batch)?.loss)
    }

    fn forward(&self, host: &[Vec<f32>], batch: &Batch) -> Result<Trace<'_>> {
        let mc = &self.spec.config;
        let (b, s) = (batch.batch, batch.seq_len);
        let n = b * s;
        let (d, v, f) = (mc.dim, mc.vocab, mc.ffn_dim);
        let (nh, nkv) = (mc.n_heads, mc.n_kv_heads);
        let hd = mc.head_dim();
        let kd = mc.kv_dim();
        ensure!(n > 0, "empty batch");
        ensure!(
            batch.tokens.len() == n && batch.targets.len() == n && batch.mask.len() == n,
            "batch buffers do not match shape [b={b}, s={s}]"
        );
        ensure!(host.len() == self.spec.params.len(), "param count mismatch");
        for (p, data) in self.spec.params.iter().zip(host) {
            ensure!(data.len() == p.numel(), "param {} size mismatch", p.name);
        }
        for &t in batch.tokens.iter().chain(&batch.targets) {
            ensure!(t >= 0 && (t as usize) < v, "token id {t} outside vocab {v}");
        }
        let (cos, sin) = self.rope_view(s);

        // token embedding
        let embed = &host[self.layout.embed];
        let mut x = vec![0.0f32; n * d];
        for t in 0..n {
            let tok = batch.tokens[t] as usize;
            x[t * d..(t + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        let mut layers = Vec::with_capacity(mc.n_layers);
        for lp in &self.layout.layers {
            let x_in = x;
            let (h1, r1) = rms_forward(&x_in, &host[lp.attn_norm], n, d);
            let mut q = gemm_nn(&h1, &host[lp.wq], n, d, d);
            let mut k = gemm_nn(&h1, &host[lp.wk], n, d, kd);
            let v_proj = gemm_nn(&h1, &host[lp.wv], n, d, kd);
            rope_apply(&mut q, n, nh, hd, s, &cos, &sin);
            rope_apply(&mut k, n, nkv, hd, s, &cos, &sin);
            let (att, concat) = attn_forward(&q, &k, &v_proj, b, s, nh, nkv, hd);
            let attn_out = gemm_nn(&concat, &host[lp.wo], n, d, d);
            let mut x_mid = x_in.clone();
            for i in 0..n * d {
                x_mid[i] += attn_out[i];
            }
            let (h2, r2) = rms_forward(&x_mid, &host[lp.mlp_norm], n, d);
            let gpre = gemm_nn(&h2, &host[lp.wgate], n, d, f);
            let up = gemm_nn(&h2, &host[lp.wup], n, d, f);
            let mut act = vec![0.0f32; n * f];
            for i in 0..n * f {
                act[i] = silu(gpre[i]) * up[i];
            }
            let mlp_out = gemm_nn(&act, &host[lp.wdown], n, f, d);
            let mut x_out = x_mid.clone();
            for i in 0..n * d {
                x_out[i] += mlp_out[i];
            }
            layers.push(LayerTrace {
                x_in,
                r1,
                h1,
                q,
                k,
                v: v_proj,
                att,
                concat,
                x_mid,
                r2,
                h2,
                gpre,
                up,
                act,
            });
            x = x_out;
        }

        let (hf, rf) = rms_forward(&x, &host[self.layout.final_norm], n, d);
        let logits = gemm_nn(&hf, &host[self.layout.head], n, d, v);

        let mask_sum: f64 = batch.mask.iter().map(|&m| m as f64).sum();
        let denom = mask_sum.max(1.0);
        let mut loss = 0.0f64;
        for t in 0..n {
            let m = batch.mask[t];
            if m == 0.0 {
                continue;
            }
            let row = &logits[t * v..(t + 1) * v];
            let lz = log_sum_exp(row);
            loss += (lz - row[batch.targets[t] as usize] as f64) * m as f64;
        }
        loss /= denom;
        Ok(Trace { layers, x_last: x, rf, hf, logits, cos, sin, denom, loss })
    }

    /// Uncached full-sequence forward over one prompt: all logits
    /// `[tokens.len(), vocab]` through the *training* forward pass. This
    /// is the numerics reference the KV-cache parity tests compare the
    /// incremental decode path against.
    pub fn full_logits(&self, host: &[Vec<f32>], tokens: &[i32]) -> Result<Vec<f32>> {
        ensure!(!tokens.is_empty(), "full_logits: empty token sequence");
        let batch = Batch {
            batch: 1,
            seq_len: tokens.len(),
            tokens: tokens.to_vec(),
            targets: vec![0; tokens.len()],
            mask: vec![1.0; tokens.len()],
            kinds: vec![None],
        };
        Ok(self.forward(host, &batch)?.logits)
    }

    /// Cache-aware forward over one token chunk *per slot*, all slots
    /// stacked into a single ragged `[total_tokens, hidden]` activation
    /// matrix: slot `i` runs `chunks[i]` at absolute positions
    /// `caches[i].len()..`, appending each position's K/V to its own
    /// ring buffers, and row `i` of the result is slot `i`'s
    /// final-position logits `[vocab]`.
    ///
    /// One-shot prefill is the batch-of-one case; a decode step is a
    /// batch-of-one chunk of one token. Every projection runs as one
    /// GEMM over the stacked rows — and because the blocked GEMM cores
    /// compute each output row independently in a fixed reduction
    /// order, each slot's rows are bit-identical to running its chunk
    /// alone. Attention stays per-slot, per-position
    /// ([`attend_position`]): slots share weights, never context.
    /// Per-row numerics are identical to the training forward pass
    /// (same GEMM cores, same softmax accumulation order), which is
    /// what makes the 1e-5 parity guarantee hold.
    fn prefill_many(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        let _sp = crate::span!("prefill_many", "backend");
        self.ragged_forward(host, chunks, caches, false)
    }

    /// The shared ragged stacked forward behind [`Backend::prefill`],
    /// [`Backend::prefill_batch`] and [`Backend::verify_step`]: slot
    /// `i` runs `chunks[i]` at absolute positions `caches[i].len()..`,
    /// appending each position's K/V to its own ring buffers. With
    /// `all_logits` false only each slot's final position feeds the LM
    /// head (prefill); with `all_logits` true every row does, and slot
    /// `i` gets back `chunks[i].len() * vocab` stacked logits — the
    /// verifier's view of the model at every draft position.
    ///
    /// Scratch comes from the same grow-only [`DecodeWorkspace`] the
    /// batched decode path uses (the buffers are `resize`d — a no-op
    /// once warm — and fully overwritten), so a speculative tick
    /// allocates nothing beyond its returned rows.
    fn ragged_forward(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        caches: &mut [&mut KvCache],
        all_logits: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let _sp = crate::span!("ragged_forward", "backend");
        let mc = &self.spec.config;
        let (d, v, f) = (mc.dim, mc.vocab, mc.ffn_dim);
        let (nh, nkv) = (mc.n_heads, mc.n_kv_heads);
        let hd = mc.head_dim();
        let kd = mc.kv_dim();
        let rep = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();
        let bsz = chunks.len();
        ensure!(bsz > 0, "serve: empty prefill batch");
        ensure!(
            caches.len() == bsz,
            "prefill_batch: {bsz} chunks, {} caches",
            caches.len()
        );
        ensure!(host.len() == self.spec.params.len(), "param count mismatch");
        for (p, data) in self.spec.params.iter().zip(host) {
            ensure!(data.len() == p.numel(), "param {} size mismatch", p.name);
        }
        // per-slot validation + row offsets into the stacked matrix
        let mut offs = Vec::with_capacity(bsz);
        let mut rows = 0usize;
        for (i, (tokens, cache)) in chunks.iter().zip(caches.iter()).enumerate() {
            cache.check_spec(&self.spec)?;
            ensure!(!tokens.is_empty(), "serve slot {i}: empty token chunk");
            ensure!(
                tokens.len() <= cache.capacity(),
                "serve slot {i}: chunk of {} tokens exceeds kv cache capacity {}",
                tokens.len(),
                cache.capacity()
            );
            for &tk in *tokens {
                ensure!(tk >= 0 && (tk as usize) < v, "token id {tk} outside vocab {v}");
            }
            offs.push(rows);
            rows += tokens.len();
        }
        let starts: Vec<usize> = caches.iter().map(|c| c.len()).collect();

        let mut guard = self.ws.lock().unwrap_or_else(|e| e.into_inner());
        let ws = &mut *guard;
        ws.x.resize(rows * d, 0.0);
        ws.h.resize(rows * d, 0.0);
        ws.q.resize(rows * d, 0.0);
        ws.k.resize(rows * kd, 0.0);
        ws.v.resize(rows * kd, 0.0);
        ws.concat.resize(rows * d, 0.0);
        ws.proj.resize(rows * d, 0.0);
        ws.gpre.resize(rows * f, 0.0);
        ws.up.resize(rows * f, 0.0);
        ws.act.resize(rows * f, 0.0);

        // token embedding: one stacked [rows, d] residual stream
        let embed = &host[self.layout.embed];
        {
            let mut r = 0;
            for tokens in chunks {
                for &tk in *tokens {
                    let tok = tk as usize;
                    ws.x[r * d..(r + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
                    r += 1;
                }
            }
        }

        for (li, lp) in self.layout.layers.iter().enumerate() {
            rms_forward_into(&ws.x, &host[lp.attn_norm], rows, d, &mut ws.h);
            gemm_nn_into(&ws.h, &host[lp.wq], rows, d, d, &mut ws.q);
            gemm_nn_into(&ws.h, &host[lp.wk], rows, d, kd, &mut ws.k);
            gemm_nn_into(&ws.h, &host[lp.wv], rows, d, kd, &mut ws.v);
            for i in 0..bsz {
                for j in 0..chunks[i].len() {
                    let r = offs[i] + j;
                    self.rope_row(&mut ws.q[r * d..(r + 1) * d], nh, starts[i] + j);
                    self.rope_row(&mut ws.k[r * kd..(r + 1) * kd], nkv, starts[i] + j);
                }
            }
            // causal attention over each slot's resident window,
            // fanned out one pool task per slot — slots touch disjoint
            // caches and disjoint `concat` rows, and each slot's
            // in-order walk is untouched, so any fan-out width is
            // bit-identical to the serial loop. Within a slot, each
            // position's K/V is written into its ring right before its
            // own query attends: writing one position at a time means a
            // wrapping chunk never clobbers a slot an earlier in-chunk
            // query still needs — ring slot `p % capacity` frees exactly
            // when position `p - capacity` has left every remaining
            // window.
            ws.concat.fill(0.0);
            {
                let attn_macs: usize = (0..bsz)
                    .map(|i| {
                        (0..chunks[i].len())
                            .map(|j| {
                                let win = (starts[i] + j + 1).min(caches[i].capacity());
                                2 * win * nh * hd
                            })
                            .sum::<usize>()
                    })
                    .sum();
                let workers = par::plan_workers(bsz, attn_macs);
                let concat = par::SendPtr(ws.concat.as_mut_ptr());
                let cache_ptrs =
                    par::SendPtrs(caches.iter_mut().map(|c| &mut **c as *mut KvCache).collect());
                let (q, kk, vv) = (&ws.q, &ws.k, &ws.v);
                let (offs, starts) = (&offs, &starts);
                par::run_tasks(workers, bsz, |i| {
                    // SAFETY: task `i` is the only one touching cache
                    // `i` and `concat` rows `offs[i]..offs[i+1]`, and
                    // both outlive the dispatch (the submitter blocks
                    // until every task completes).
                    let cache = unsafe { &mut *cache_ptrs.0[i] };
                    for j in 0..chunks[i].len() {
                        let r = offs[i] + j;
                        let p = starts[i] + j;
                        cache.write_kv(
                            li,
                            p,
                            &kk[r * kd..(r + 1) * kd],
                            &vv[r * kd..(r + 1) * kd],
                        );
                        let orow =
                            unsafe { std::slice::from_raw_parts_mut(concat.0.add(r * d), d) };
                        attend_position(
                            &q[r * d..(r + 1) * d],
                            p,
                            cache,
                            li,
                            orow,
                            (nh, rep, hd, kd),
                            scale,
                        );
                    }
                });
            }
            gemm_nn_into(&ws.concat, &host[lp.wo], rows, d, d, &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
            rms_forward_into(&ws.x, &host[lp.mlp_norm], rows, d, &mut ws.h);
            gemm_nn_into(&ws.h, &host[lp.wgate], rows, d, f, &mut ws.gpre);
            gemm_nn_into(&ws.h, &host[lp.wup], rows, d, f, &mut ws.up);
            for ((a, &g), &u) in ws.act.iter_mut().zip(&ws.gpre).zip(&ws.up) {
                *a = silu(g) * u;
            }
            gemm_nn_into(&ws.act, &host[lp.wdown], rows, f, d, &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
        }
        for (cache, tokens) in caches.iter_mut().zip(chunks) {
            cache.advance(tokens.len());
        }

        let out: Vec<Vec<f32>> = if all_logits {
            // every position feeds the LM head: slot `i` gets its
            // chunk's stacked logits back for draft verification
            rms_forward_into(&ws.x, &host[self.layout.final_norm], rows, d, &mut ws.h);
            ws.logits.resize(rows * v, 0.0);
            gemm_nn_into(&ws.h, &host[self.layout.head], rows, d, v, &mut ws.logits);
            (0..bsz)
                .map(|i| ws.logits[offs[i] * v..(offs[i] + chunks[i].len()) * v].to_vec())
                .collect()
        } else {
            // only each slot's final position feeds the LM head
            // (`concat` is free after the layer loop and doubles as the
            // [bsz, d] gather buffer)
            for i in 0..bsz {
                let r = offs[i] + chunks[i].len() - 1;
                let (dst, src) = (&mut ws.concat, &ws.x);
                dst[i * d..(i + 1) * d].copy_from_slice(&src[r * d..(r + 1) * d]);
            }
            rms_forward_into(
                &ws.concat[..bsz * d],
                &host[self.layout.final_norm],
                bsz,
                d,
                &mut ws.h[..bsz * d],
            );
            ws.logits.resize(bsz * v, 0.0);
            gemm_nn_into(&ws.h[..bsz * d], &host[self.layout.head], bsz, d, v, &mut ws.logits);
            ws.logits[..bsz * v].chunks(v).map(|row| row.to_vec()).collect()
        };
        // record the workspace at maximum extent, before the shrink —
        // the byte gauge should reflect what this call actually held
        crate::obs::memory::set_current(
            crate::obs::memory::MemCategory::ActivationScratch,
            ws.bytes(),
        );
        // steady-state decode/verify runs a handful of rows per tick; a
        // one-shot long prefill must not pin prefill-sized scratch for
        // the backend's lifetime, so capacity above the retained
        // envelope is released (rare, off the decode hot path)
        if rows > WS_RETAIN_ROWS {
            ws.shrink_to_rows(WS_RETAIN_ROWS, d, kd, f, v);
        }
        Ok(out)
    }

    /// The hand-derived backward pass: gradients for every registry
    /// parameter, plus their squared Frobenius norms.
    fn backward(&self, host: &[Vec<f32>], batch: &Batch, tr: &Trace<'_>)
                -> (Vec<Vec<f32>>, Vec<f32>) {
        let mc = &self.spec.config;
        let (b, s) = (batch.batch, batch.seq_len);
        let n = b * s;
        let (d, v, f) = (mc.dim, mc.vocab, mc.ffn_dim);
        let (nh, nkv) = (mc.n_heads, mc.n_kv_heads);
        let hd = mc.head_dim();
        let kd = mc.kv_dim();
        let ly = &self.layout;
        let mut grads: Vec<Vec<f32>> = self
            .spec
            .params
            .iter()
            .map(|p| vec![0.0f32; p.numel()])
            .collect();

        // ---- cross-entropy + LM head -----------------------------------
        // dlogits[t] = (softmax(logits[t]) - onehot(target_t)) * mask_t/denom,
        // processed row-by-row so the [n, v] softmax is never materialized.
        let head = &host[ly.head];
        let mut dhf = vec![0.0f32; n * d];
        {
            let ghead = &mut grads[ly.head];
            let mut dlrow = vec![0.0f32; v];
            for t in 0..n {
                let m = batch.mask[t];
                if m == 0.0 {
                    continue;
                }
                let w = (m as f64 / tr.denom) as f32;
                let row = &tr.logits[t * v..(t + 1) * v];
                let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
                for j in 0..v {
                    dlrow[j] = ((((row[j] - mx) as f64).exp() / sum) as f32) * w;
                }
                dlrow[batch.targets[t] as usize] -= w;
                let hfrow = &tr.hf[t * d..(t + 1) * d];
                let dhfrow = &mut dhf[t * d..(t + 1) * d];
                for jd in 0..d {
                    let hrow = &head[jd * v..(jd + 1) * v];
                    let mut acc = 0.0f32;
                    for jv in 0..v {
                        acc += dlrow[jv] * hrow[jv];
                    }
                    dhfrow[jd] = acc;
                    let hv = hfrow[jd];
                    if hv != 0.0 {
                        simd::axpy(hv, &dlrow, &mut ghead[jd * v..(jd + 1) * v]);
                    }
                }
            }
        }

        // ---- final RMSNorm ---------------------------------------------
        let mut dx = rms_backward(
            &tr.x_last,
            &host[ly.final_norm],
            &tr.rf,
            &dhf,
            n,
            d,
            &mut grads[ly.final_norm],
        );

        // ---- transformer layers, reversed ------------------------------
        for li in (0..mc.n_layers).rev() {
            let lt = &tr.layers[li];
            let lp = &ly.layers[li];

            // MLP: x_out = x_mid + (silu(h2@wgate) * (h2@wup)) @ wdown
            let dact = gemm_nt(&dx, &host[lp.wdown], n, d, f);
            gemm_tn_acc(&lt.act, &dx, n, f, d, &mut grads[lp.wdown]);
            let mut dgpre = vec![0.0f32; n * f];
            let mut dup = vec![0.0f32; n * f];
            for i in 0..n * f {
                let z = lt.gpre[i];
                let sg = sigmoid(z);
                dgpre[i] = dact[i] * lt.up[i] * sg * (1.0 + z * (1.0 - sg));
                dup[i] = dact[i] * z * sg;
            }
            gemm_tn_acc(&lt.h2, &dgpre, n, d, f, &mut grads[lp.wgate]);
            gemm_tn_acc(&lt.h2, &dup, n, d, f, &mut grads[lp.wup]);
            let mut dh2 = gemm_nt(&dgpre, &host[lp.wgate], n, f, d);
            let dh2b = gemm_nt(&dup, &host[lp.wup], n, f, d);
            for i in 0..n * d {
                dh2[i] += dh2b[i];
            }
            let dx_mid_norm = rms_backward(
                &lt.x_mid,
                &host[lp.mlp_norm],
                &lt.r2,
                &dh2,
                n,
                d,
                &mut grads[lp.mlp_norm],
            );
            let mut dx_mid = dx;
            for i in 0..n * d {
                dx_mid[i] += dx_mid_norm[i];
            }

            // attention: x_mid = x_in + (heads(h1) concat) @ wo
            let dconcat = gemm_nt(&dx_mid, &host[lp.wo], n, d, d);
            gemm_tn_acc(&lt.concat, &dx_mid, n, d, d, &mut grads[lp.wo]);
            let (mut dq, mut dk, dv) =
                attn_backward(&lt.q, &lt.k, &lt.v, &lt.att, &dconcat, b, s, nh, nkv, hd);
            rope_apply_inv(&mut dq, n, nh, hd, s, &tr.cos, &tr.sin);
            rope_apply_inv(&mut dk, n, nkv, hd, s, &tr.cos, &tr.sin);
            gemm_tn_acc(&lt.h1, &dq, n, d, d, &mut grads[lp.wq]);
            gemm_tn_acc(&lt.h1, &dk, n, d, kd, &mut grads[lp.wk]);
            gemm_tn_acc(&lt.h1, &dv, n, d, kd, &mut grads[lp.wv]);
            let mut dh1 = gemm_nt(&dq, &host[lp.wq], n, d, d);
            let dh1b = gemm_nt(&dk, &host[lp.wk], n, kd, d);
            let dh1c = gemm_nt(&dv, &host[lp.wv], n, kd, d);
            for i in 0..n * d {
                dh1[i] += dh1b[i] + dh1c[i];
            }
            let dx_norm = rms_backward(
                &lt.x_in,
                &host[lp.attn_norm],
                &lt.r1,
                &dh1,
                n,
                d,
                &mut grads[lp.attn_norm],
            );
            dx = dx_mid;
            for i in 0..n * d {
                dx[i] += dx_norm[i];
            }
        }

        // ---- embedding --------------------------------------------------
        {
            let gembed = &mut grads[ly.embed];
            for t in 0..n {
                let tok = batch.tokens[t] as usize;
                let row = &dx[t * d..(t + 1) * d];
                let grow = &mut gembed[tok * d..(tok + 1) * d];
                for (g, &x) in grow.iter_mut().zip(row) {
                    *g += x;
                }
            }
        }

        let sq_norms: Vec<f32> = grads
            .iter()
            .map(|g| g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() as f32)
            .collect();
        (grads, sq_norms)
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn sync_param(&mut self, _idx: usize, _data: &[f32]) -> Result<()> {
        Ok(()) // executes directly from the host mirror
    }

    fn fwd_bwd(&self, host: &[Vec<f32>], batch: &Batch) -> Result<StepOutput> {
        let _sp = crate::span!("fwd_bwd", "backend");
        let tr = self.forward(host, batch)?;
        // activation residency = the trace the backward pass keeps
        // alive (a size read-out; the computation never sees it)
        crate::obs::memory::set_current(
            crate::obs::memory::MemCategory::ActivationScratch,
            tr.bytes(),
        );
        let (grads, sq_norms) = self.backward(host, batch, &tr);
        Ok(StepOutput { loss: tr.loss as f32, grads, sq_norms })
    }

    fn predict(&self, host: &[Vec<f32>], batch: &Batch) -> Result<EvalOutput> {
        let _sp = crate::span!("predict", "backend");
        let tr = self.forward(host, batch)?;
        let v = self.spec.config.vocab;
        let n = batch.batch * batch.seq_len;
        let mut correct = vec![0.0f32; n];
        for t in 0..n {
            let row = &tr.logits[t * v..(t + 1) * v];
            let best = crate::util::argmax(row);
            correct[t] = if best == batch.targets[t] as usize { 1.0 } else { 0.0 };
        }
        Ok(EvalOutput { loss: tr.loss as f32, correct })
    }

    fn adam_update(
        &mut self,
        _idx: usize,
        p: &mut Vec<f32>,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let _sp = crate::span!("adam_update", "backend");
        ensure!(
            p.len() == grad.len() && grad.len() == m.len() && m.len() == v.len(),
            "adam_update length mismatch"
        );
        let mut st = AdamState { m: m.to_vec(), v: v.to_vec() };
        st.step(p, grad, lr, AdamHyper::default());
        let sq: f64 = grad.iter().map(|&g| (g as f64) * (g as f64)).sum();
        Ok((st.m, st.v, sq as f32))
    }

    fn tail_update(
        &mut self,
        _idx: usize,
        p: &mut Vec<f32>,
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<()> {
        ensure!(p.len() == m.len() && m.len() == v.len(), "tail_update length mismatch");
        let st = AdamState { m: m.to_vec(), v: v.to_vec() };
        st.momentum_tail(p, lr, AdamHyper::default());
        Ok(())
    }

    /// One prompt is the batch-of-one case of [`Backend::prefill_batch`]:
    /// a single ragged-batch code path serves both, so per-slot and
    /// batched prefill numerics are identical by construction.
    fn prefill(&self, host: &[Vec<f32>], tokens: &[i32], cache: &mut KvCache)
               -> Result<Vec<f32>> {
        let mut caches = [cache];
        let mut rows = self.prefill_many(host, &[tokens], &mut caches)?;
        Ok(rows.pop().expect("prefill_many returns one row per slot"))
    }

    /// Truly batched prefill: every admitted prompt's rows stack into
    /// one ragged `[total_tokens, hidden]` activation matrix, so each
    /// layer runs one GEMM per projection across the whole admission
    /// group instead of one per prompt — the prefill counterpart of
    /// [`Backend::decode_batch`].
    fn prefill_batch(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.prefill_many(host, chunks, caches)
    }

    /// Speculative verification is the all-positions case of the same
    /// ragged stacked path that serves prefill: one `[total_tokens,
    /// hidden]` forward over every slot's `[last_token, draft...]`
    /// chunk, with the LM head applied to **every** row instead of
    /// each slot's last. Per-row numerics are identical to sequential
    /// [`Backend::decode_step`] calls (same GEMM cores computing each
    /// output row independently in a fixed reduction order, same
    /// `attend_position` kernel), which is what makes greedy
    /// speculative decode bit-identical to greedy sequential decode —
    /// the invariant `rust/tests/serve.rs` pins.
    fn verify_step(
        &self,
        host: &[Vec<f32>],
        chunks: &[&[i32]],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            chunks.len() == positions.len() && chunks.len() == caches.len(),
            "verify_step: {} chunks, {} positions, {} caches",
            chunks.len(),
            positions.len(),
            caches.len()
        );
        for (i, (&pos, cache)) in positions.iter().zip(caches.iter()).enumerate() {
            ensure!(
                pos == cache.len(),
                "verify_step slot {i}: position {pos} but the cache holds {} positions — \
                 verification must be contiguous",
                cache.len()
            );
        }
        let _sp = crate::span!("verify_step", "backend");
        self.ragged_forward(host, chunks, caches, true)
    }

    /// One token is the batch-of-one case of [`Backend::decode_batch`]:
    /// a single code path (and a single workspace) serves both, so the
    /// per-slot and batched decode numerics are identical by
    /// construction.
    fn decode_step(&self, host: &[Vec<f32>], token: i32, pos: usize, cache: &mut KvCache)
                   -> Result<Vec<f32>> {
        let mut caches = [cache];
        let mut rows = self.decode_batch(host, &[token], &[pos], &mut caches)?;
        Ok(rows.pop().expect("decode_batch returns one row per slot"))
    }

    /// Truly batched decode: all slots stack into one `[batch, hidden]`
    /// activation matrix, so each layer runs one GEMM per projection
    /// (wq/wk/wv/wo/wgate/wup/wdown, plus the LM head) instead of one
    /// per slot. Attention stays per-slot over each ring-buffer cache —
    /// slots share weights, never context. Per-row numerics are
    /// identical to [`Backend::decode_step`] (same GEMM cores row by
    /// row, same `attend_position` kernel), so a scheduled batch
    /// decodes bit-identically to solo generation.
    fn decode_batch(
        &self,
        host: &[Vec<f32>],
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        let _sp = crate::span!("decode_batch", "backend");
        let mc = &self.spec.config;
        let (d, v, f) = (mc.dim, mc.vocab, mc.ffn_dim);
        let (nh, nkv) = (mc.n_heads, mc.n_kv_heads);
        let hd = mc.head_dim();
        let kd = mc.kv_dim();
        let rep = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();
        let bsz = tokens.len();
        ensure!(bsz > 0, "decode_batch: empty batch");
        ensure!(
            positions.len() == bsz && caches.len() == bsz,
            "decode_batch: {bsz} tokens, {} positions, {} caches",
            positions.len(),
            caches.len()
        );
        ensure!(host.len() == self.spec.params.len(), "param count mismatch");
        for (p, data) in self.spec.params.iter().zip(host) {
            ensure!(data.len() == p.numel(), "param {} size mismatch", p.name);
        }
        for (i, cache) in caches.iter().enumerate() {
            cache.check_spec(&self.spec)?;
            ensure!(
                positions[i] == cache.len(),
                "decode_batch slot {i}: position {} but the cache holds {} positions — \
                 decode must be contiguous",
                positions[i],
                cache.len()
            );
            let tk = tokens[i];
            ensure!(tk >= 0 && (tk as usize) < v, "token id {tk} outside vocab {v}");
        }

        let mut guard = self.ws.lock().unwrap_or_else(|e| e.into_inner());
        let ws = &mut *guard;
        ws.x.resize(bsz * d, 0.0);
        ws.h.resize(bsz * d, 0.0);
        ws.q.resize(bsz * d, 0.0);
        ws.k.resize(bsz * kd, 0.0);
        ws.v.resize(bsz * kd, 0.0);
        ws.concat.resize(bsz * d, 0.0);
        ws.proj.resize(bsz * d, 0.0);
        ws.gpre.resize(bsz * f, 0.0);
        ws.up.resize(bsz * f, 0.0);
        ws.act.resize(bsz * f, 0.0);

        // token embedding: one stacked [bsz, d] residual stream
        let embed = &host[self.layout.embed];
        for (i, &tk) in tokens.iter().enumerate() {
            let tok = tk as usize;
            ws.x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        for (li, lp) in self.layout.layers.iter().enumerate() {
            rms_forward_into(&ws.x, &host[lp.attn_norm], bsz, d, &mut ws.h);
            gemm_nn_into(&ws.h, &host[lp.wq], bsz, d, d, &mut ws.q);
            gemm_nn_into(&ws.h, &host[lp.wk], bsz, d, kd, &mut ws.k);
            gemm_nn_into(&ws.h, &host[lp.wv], bsz, d, kd, &mut ws.v);
            for i in 0..bsz {
                self.rope_row(&mut ws.q[i * d..(i + 1) * d], nh, positions[i]);
                self.rope_row(&mut ws.k[i * kd..(i + 1) * kd], nkv, positions[i]);
            }
            // per-slot attention, one pool task per slot: disjoint
            // caches, disjoint `concat` rows, same per-slot kernel as
            // the serial loop — bit-identical at any fan-out width
            ws.concat.fill(0.0);
            {
                let attn_macs: usize = (0..bsz)
                    .map(|i| {
                        let win = (positions[i] + 1).min(caches[i].capacity());
                        2 * win * nh * hd
                    })
                    .sum();
                let workers = par::plan_workers(bsz, attn_macs);
                let concat = par::SendPtr(ws.concat.as_mut_ptr());
                let cache_ptrs =
                    par::SendPtrs(caches.iter_mut().map(|c| &mut **c as *mut KvCache).collect());
                let (q, kk, vv) = (&ws.q, &ws.k, &ws.v);
                par::run_tasks(workers, bsz, |i| {
                    // SAFETY: task `i` exclusively owns cache `i` and
                    // `concat` row `i`; both outlive the dispatch.
                    let cache = unsafe { &mut *cache_ptrs.0[i] };
                    cache.write_kv(
                        li,
                        positions[i],
                        &kk[i * kd..(i + 1) * kd],
                        &vv[i * kd..(i + 1) * kd],
                    );
                    let orow = unsafe { std::slice::from_raw_parts_mut(concat.0.add(i * d), d) };
                    attend_position(
                        &q[i * d..(i + 1) * d],
                        positions[i],
                        cache,
                        li,
                        orow,
                        (nh, rep, hd, kd),
                        scale,
                    );
                });
            }
            gemm_nn_into(&ws.concat, &host[lp.wo], bsz, d, d, &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
            rms_forward_into(&ws.x, &host[lp.mlp_norm], bsz, d, &mut ws.h);
            gemm_nn_into(&ws.h, &host[lp.wgate], bsz, d, f, &mut ws.gpre);
            gemm_nn_into(&ws.h, &host[lp.wup], bsz, d, f, &mut ws.up);
            for ((a, &g), &u) in ws.act.iter_mut().zip(&ws.gpre).zip(&ws.up) {
                *a = silu(g) * u;
            }
            gemm_nn_into(&ws.act, &host[lp.wdown], bsz, f, d, &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
        }
        for cache in caches.iter_mut() {
            cache.advance(1);
        }

        // every slot needs its own next-token logits row
        rms_forward_into(&ws.x, &host[self.layout.final_norm], bsz, d, &mut ws.h);
        ws.logits.resize(bsz * v, 0.0);
        gemm_nn_into(&ws.h, &host[self.layout.head], bsz, d, v, &mut ws.logits);
        crate::obs::memory::set_current(
            crate::obs::memory::MemCategory::ActivationScratch,
            ws.bytes(),
        );
        Ok(ws.logits.chunks(v).map(|row| row.to_vec()).collect())
    }
}

// ---------------------------------------------------------------------------
// Elementwise + normalization kernels. The GEMMs are the shared slice
// cores in `tensor::{gemm_nn, gemm_tn_acc, gemm_nt}` — one matmul
// implementation for the whole repo.
// ---------------------------------------------------------------------------

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn silu(z: f32) -> f32 {
    z * sigmoid(z)
}

fn log_sum_exp(row: &[f32]) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
    mx as f64 + sum.ln()
}

/// `y[i] = x[i] * rsqrt(mean(x[i]^2) + eps) * w` per row; returns
/// `(y, rsqrt factors)`.
fn rms_forward(x: &[f32], w: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut h = vec![0.0f32; n * d];
    let mut r = vec![0.0f32; n];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let ms: f64 = row.iter().map(|&a| (a as f64) * (a as f64)).sum::<f64>() / d as f64;
        let ri = 1.0 / ((ms as f32) + NORM_EPS).sqrt();
        r[i] = ri;
        let hrow = &mut h[i * d..(i + 1) * d];
        for j in 0..d {
            hrow[j] = row[j] * ri * w[j];
        }
    }
    (h, r)
}

/// [`rms_forward`] into a caller-owned buffer, rsqrt factors discarded
/// (the serving paths keep no backward trace). Same accumulation order
/// as the training kernel, row by row; rows are independent, so
/// prefill-sized calls fan out over the pool (decode-sized ones stay
/// under the work floor and run serial) without changing a bit.
fn rms_forward_into(x: &[f32], w: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), n * d);
    debug_assert_eq!(out.len(), n * d);
    let workers = par::plan_workers(n, 2 * n * d);
    par::par_out_rows(out, n, d, workers, |row0, ochunk| {
        for (i, orow) in ochunk.chunks_mut(d).enumerate() {
            let row = &x[(row0 + i) * d..(row0 + i + 1) * d];
            let ms: f64 = row.iter().map(|&a| (a as f64) * (a as f64)).sum::<f64>() / d as f64;
            let ri = 1.0 / ((ms as f32) + NORM_EPS).sqrt();
            for j in 0..d {
                orow[j] = row[j] * ri * w[j];
            }
        }
    });
}

/// Attend position `p`'s query over the cache's resident window into
/// `orow` (`[d]`, zeroed by the caller). The position's own K/V rows
/// must already be written (`KvCache::write_kv`) — write-then-attend,
/// one position at a time, is the ordering that makes a wrapping chunk
/// safe. The shared per-position kernel of ragged batched prefill
/// ([`HostBackend::prefill_many`]) and batched decode
/// ([`Backend::decode_batch`]): one accumulation order for both is
/// what keeps every serving path within 1e-5 of the training forward —
/// and a forked cache bit-identical to a cold one, since reads go
/// through the same ring rows whether a chunk is owned or shared.
/// `dims` is `(n_heads, rep, head_dim, kv_dim)`.
fn attend_position(
    qrow_all: &[f32],
    p: usize,
    cache: &KvCache,
    layer: usize,
    orow_all: &mut [f32],
    dims: (usize, usize, usize, usize),
    scale: f32,
) {
    thread_local! {
        /// Per-thread attention-score scratch (window-sized). It was
        /// workspace-owned before the per-slot fan-out; now every pool
        /// participant needs its own, and persistent workers keep
        /// theirs warm across jobs for free.
        static SCORES: std::cell::RefCell<Vec<f32>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
    let (nh, rep, hd, _kd) = dims;
    let capacity = cache.capacity();
    let lo = (p + 1).saturating_sub(capacity);
    let w = p + 1 - lo;
    SCORES.with(|cell| {
        let mut scores = cell.borrow_mut();
        scores.resize(w, 0.0);
        for h in 0..nh {
            let kvh = h / rep;
            let qrow = &qrow_all[h * hd..][..hd];
            let mut mx = f32::NEG_INFINITY;
            for (jj, sc_out) in scores.iter_mut().enumerate() {
                let slot = (lo + jj) % capacity;
                let kr = &cache.k_row(layer, slot)[kvh * hd..][..hd];
                // the q·k reduction stays scalar: vectorizing it would
                // need lane partial sums, which reorders the additions
                let mut sc = 0.0f32;
                for tt in 0..hd {
                    sc += qrow[tt] * kr[tt];
                }
                let sc = sc * scale;
                *sc_out = sc;
                mx = mx.max(sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                let e = (*sc - mx).exp();
                *sc = e;
                denom += e;
            }
            let inv = 1.0 / denom;
            let orow = &mut orow_all[h * hd..][..hd];
            for (jj, &pr) in scores.iter().enumerate() {
                let pr = pr * inv;
                if pr == 0.0 {
                    continue;
                }
                let slot = (lo + jj) % capacity;
                let vr = &cache.v_row(layer, slot)[kvh * hd..][..hd];
                simd::axpy(pr, vr, orow);
            }
        }
    });
}

/// Backward of `rms_forward`: accumulates `dw` and returns `dx`.
///
/// With `u = x*r`, `y = u ⊙ w`, `r = (mean(x²)+eps)^{-1/2}`:
/// `dx_j = r·dy_j·w_j − r³·x_j·(Σ_k dy_k·w_k·x_k)/d`.
fn rms_backward(x: &[f32], w: &[f32], r: &[f32], dh: &[f32], n: usize, d: usize,
                dw: &mut [f32]) -> Vec<f32> {
    debug_assert_eq!(dw.len(), d);
    let mut dx = vec![0.0f32; n * d];
    for i in 0..n {
        let xrow = &x[i * d..(i + 1) * d];
        let dhrow = &dh[i * d..(i + 1) * d];
        let ri = r[i];
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += (dhrow[j] * w[j]) as f64 * xrow[j] as f64;
            dw[j] += dhrow[j] * xrow[j] * ri;
        }
        let c = ((ri as f64).powi(3) * dot / d as f64) as f32;
        let dxrow = &mut dx[i * d..(i + 1) * d];
        for j in 0..d {
            dxrow[j] = ri * dhrow[j] * w[j] - c * xrow[j];
        }
    }
    dx
}

/// cos/sin tables `[s, hd/2]` — python/compile/model.py `_rope_tables`.
fn rope_tables(s: usize, hd: usize, theta: f32) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    for p in 0..s {
        for i in 0..half {
            let freq = theta.powf(-((2 * i) as f32) / hd as f32);
            let ang = p as f32 * freq;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// Rotate (even, odd) pairs of every head in place — the jnp convention:
/// `even' = e·c − o·s`, `odd' = e·s + o·c`. Row `t`'s position is `t % s`.
fn rope_apply(m: &mut [f32], n_rows: usize, n_heads: usize, hd: usize, s: usize,
              cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    let cols = n_heads * hd;
    for row in 0..n_rows {
        let pos = row % s;
        for h in 0..n_heads {
            let off = row * cols + h * hd;
            for i in 0..half {
                let c = cos[pos * half + i];
                let sn = sin[pos * half + i];
                let e = m[off + 2 * i];
                let o = m[off + 2 * i + 1];
                m[off + 2 * i] = e * c - o * sn;
                m[off + 2 * i + 1] = e * sn + o * c;
            }
        }
    }
}

/// Transpose rotation (= inverse; RoPE is orthogonal): the gradient map.
fn rope_apply_inv(m: &mut [f32], n_rows: usize, n_heads: usize, hd: usize, s: usize,
                  cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    let cols = n_heads * hd;
    for row in 0..n_rows {
        let pos = row % s;
        for h in 0..n_heads {
            let off = row * cols + h * hd;
            for i in 0..half {
                let c = cos[pos * half + i];
                let sn = sin[pos * half + i];
                let e = m[off + 2 * i];
                let o = m[off + 2 * i + 1];
                m[off + 2 * i] = e * c + o * sn;
                m[off + 2 * i + 1] = -e * sn + o * c;
            }
        }
    }
}

/// Causal GQA attention forward: returns `(att [b,nh,s,s], concat [n,d])`.
/// Query head `h` reads kv head `h / (nh/nkv)` (jnp.repeat layout).
fn attn_forward(q: &[f32], k: &[f32], v: &[f32], b: usize, s: usize, nh: usize,
                nkv: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let d = nh * hd;
    let kd = nkv * hd;
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0.0f32; b * nh * s * s];
    let mut concat = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for h in 0..nh {
            let kvh = h / rep;
            let abase = (bi * nh + h) * s * s;
            for i in 0..s {
                let row = bi * s + i;
                let qrow = &q[row * d + h * hd..][..hd];
                let arow = &mut att[abase + i * s..][..s];
                let mut mx = f32::NEG_INFINITY;
                for j in 0..=i {
                    let krow = &k[(bi * s + j) * kd + kvh * hd..][..hd];
                    let mut sc = 0.0f32;
                    for t in 0..hd {
                        sc += qrow[t] * krow[t];
                    }
                    let sc = sc * scale;
                    arow[j] = sc;
                    mx = mx.max(sc);
                }
                let mut denom = 0.0f32;
                for j in 0..=i {
                    let e = (arow[j] - mx).exp();
                    arow[j] = e;
                    denom += e;
                }
                let inv = 1.0 / denom;
                for j in 0..=i {
                    arow[j] *= inv;
                }
                let orow = &mut concat[row * d + h * hd..][..hd];
                for j in 0..=i {
                    let p = arow[j];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &v[(bi * s + j) * kd + kvh * hd..][..hd];
                    for t in 0..hd {
                        orow[t] += p * vrow[t];
                    }
                }
            }
        }
    }
    (att, concat)
}

/// Backward of `attn_forward` given `dconcat`: returns `(dq, dk, dv)` on
/// the post-RoPE values.
#[allow(clippy::too_many_arguments)]
fn attn_backward(q: &[f32], k: &[f32], v: &[f32], att: &[f32], dconcat: &[f32],
                 b: usize, s: usize, nh: usize, nkv: usize, hd: usize)
                 -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d = nh * hd;
    let kd = nkv * hd;
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = vec![0.0f32; b * s * d];
    let mut dk = vec![0.0f32; b * s * kd];
    let mut dv = vec![0.0f32; b * s * kd];
    let mut datt = vec![0.0f32; s];
    for bi in 0..b {
        for h in 0..nh {
            let kvh = h / rep;
            let abase = (bi * nh + h) * s * s;
            for i in 0..s {
                let row = bi * s + i;
                let dorow = &dconcat[row * d + h * hd..][..hd];
                let arow = &att[abase + i * s..][..s];
                // dv and softmax-input sensitivity
                let mut dot = 0.0f32;
                for j in 0..=i {
                    let vrow = &v[(bi * s + j) * kd + kvh * hd..][..hd];
                    let mut da = 0.0f32;
                    for t in 0..hd {
                        da += dorow[t] * vrow[t];
                    }
                    datt[j] = da;
                    dot += da * arow[j];
                    let p = arow[j];
                    let dvrow = &mut dv[(bi * s + j) * kd + kvh * hd..][..hd];
                    for t in 0..hd {
                        dvrow[t] += p * dorow[t];
                    }
                }
                // dscores -> dq, dk
                let qbase = row * d + h * hd;
                for j in 0..=i {
                    let ds = arow[j] * (datt[j] - dot) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow = &k[(bi * s + j) * kd + kvh * hd..][..hd];
                    for t in 0..hd {
                        dq[qbase + t] += ds * krow[t];
                    }
                    let qrow = &q[qbase..][..hd];
                    let dkrow = &mut dk[(bi * s + j) * kd + kvh * hd..][..hd];
                    for t in 0..hd {
                        dkrow[t] += ds * qrow[t];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    // the GEMM slice cores are pinned against naive matmul in
    // tensor::tests::slice_cores_match_naive_and_accumulate

    #[test]
    fn rope_inv_is_inverse() {
        let mut rng = Rng::new(2);
        let (n_rows, heads, hd, s) = (6, 2, 8, 3);
        let (cos, sin) = rope_tables(s, hd, ROPE_THETA);
        let orig = randv(n_rows * heads * hd, &mut rng);
        let mut m = orig.clone();
        rope_apply(&mut m, n_rows, heads, hd, s, &cos, &sin);
        rope_apply_inv(&mut m, n_rows, heads, hd, s, &cos, &sin);
        for (x, y) in m.iter().zip(&orig) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(3);
        let (n_rows, heads, hd, s) = (4, 3, 6, 4);
        let (cos, sin) = rope_tables(s, hd, ROPE_THETA);
        let orig = randv(n_rows * heads * hd, &mut rng);
        let mut m = orig.clone();
        rope_apply(&mut m, n_rows, heads, hd, s, &cos, &sin);
        let n0: f64 = orig.iter().map(|&x| (x as f64).powi(2)).sum();
        let n1: f64 = m.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((n0 - n1).abs() < 1e-4 * n0.max(1.0));
    }

    #[test]
    fn attention_rows_are_causal_distributions() {
        let mut rng = Rng::new(4);
        let (b, s, nh, nkv, hd) = (2, 5, 4, 2, 6);
        let q = randv(b * s * nh * hd, &mut rng);
        let k = randv(b * s * nkv * hd, &mut rng);
        let v = randv(b * s * nkv * hd, &mut rng);
        let (att, concat) = attn_forward(&q, &k, &v, b, s, nh, nkv, hd);
        assert_eq!(concat.len(), b * s * nh * hd);
        for bi in 0..b {
            for h in 0..nh {
                for i in 0..s {
                    let arow = &att[((bi * nh + h) * s + i) * s..][..s];
                    let sum: f32 = arow[..=i].iter().sum();
                    assert!((sum - 1.0).abs() < 1e-5, "row sums to {sum}");
                    for &p in &arow[i + 1..] {
                        assert_eq!(p, 0.0, "future position attended");
                    }
                }
            }
        }
    }

    #[test]
    fn rms_backward_matches_finite_difference() {
        let mut rng = Rng::new(5);
        let (n, d) = (3, 8);
        let x = randv(n * d, &mut rng);
        let w: Vec<f32> = (0..d).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let dh = randv(n * d, &mut rng);
        // loss = <dh, rms(x, w)>
        let loss = |x: &[f32], w: &[f32]| -> f64 {
            let (h, _) = rms_forward(x, w, n, d);
            h.iter().zip(&dh).map(|(&a, &b)| (a as f64) * (b as f64)).sum()
        };
        let (_, r) = rms_forward(&x, &w, n, d);
        let mut dw = vec![0.0f32; d];
        let dx = rms_backward(&x, &w, &r, &dh, n, d, &mut dw);
        let eps = 1e-2f32;
        for probe in 0..6 {
            let i = rng.below(n * d);
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps as f64);
            assert!(
                (fd - dx[i] as f64).abs() < 2e-3 + 0.02 * fd.abs(),
                "probe {probe}: dx[{i}] analytic {} vs fd {fd}",
                dx[i]
            );
            let j = rng.below(d);
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fdw = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!(
                (fdw - dw[j] as f64).abs() < 2e-3 + 0.02 * fdw.abs(),
                "probe {probe}: dw[{j}] analytic {} vs fd {fdw}",
                dw[j]
            );
        }
    }
}
