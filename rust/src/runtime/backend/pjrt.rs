//! `PjrtBackend` — the AOT-artifact execution path (feature `pjrt`).
//!
//! This is the original runtime: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`,
//! with parameters resident as device buffers that are passed by
//! reference on every step. Only changed modules are re-uploaded
//! (`sync_param`), and only the output tuple (loss, grads, norms)
//! crosses back to the host.
//!
//! Compiled executables are cached per artifact file in [`PjrtCompiler`]
//! (owned by `Engine`) and shared across sessions via `Rc`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::data::Batch;
use crate::modelspec::ModelSpec;
use crate::runtime::backend::{Backend, KvCache};
use crate::runtime::{EvalOutput, StepOutput};

/// PJRT client + compiled-executable cache (one per `Engine`).
pub struct PjrtCompiler {
    pub client: PjRtClient,
    dir: PathBuf,
    exe_cache: HashMap<String, Rc<PjRtLoadedExecutable>>,
}

impl PjrtCompiler {
    pub fn new(dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtCompiler { client, dir: dir.to_path_buf(), exe_cache: HashMap::new() })
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&mut self, file: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if !self.exe_cache.contains_key(file) {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
            self.exe_cache.insert(file.to_string(), Rc::new(exe));
        }
        Ok(Rc::clone(self.exe_cache.get(file).unwrap()))
    }
}

/// One session's device residency: parameter buffers + compiled graphs.
pub struct PjrtBackend {
    spec: ModelSpec,
    /// device-resident parameter buffers, registry order
    device: Vec<PjRtBuffer>,
    fwd_bwd: Rc<PjRtLoadedExecutable>,
    predict: Rc<PjRtLoadedExecutable>,
    /// fused-Adam executable per shape key
    adam: HashMap<String, Rc<PjRtLoadedExecutable>>,
    /// momentum-tail executable per shape key
    tail: HashMap<String, Rc<PjRtLoadedExecutable>>,
    client: PjRtClient,
}

impl PjrtBackend {
    pub fn create(comp: &mut PjrtCompiler, spec: &ModelSpec, host: &[Vec<f32>]) -> Result<Self> {
        let fwd_bwd = {
            let f = spec.graphs.get("fwd_bwd").ok_or_else(|| anyhow!("no fwd_bwd graph"))?;
            comp.load(&f.clone())?
        };
        let predict = {
            let f = spec.graphs.get("predict").ok_or_else(|| anyhow!("no predict graph"))?;
            comp.load(&f.clone())?
        };
        let mut adam = HashMap::new();
        let mut tail = HashMap::new();
        for p in &spec.params {
            let key = p.shape_key();
            if !adam.contains_key(&key) {
                if let Some(f) = spec.graphs.get(&format!("adam.{key}")) {
                    adam.insert(key.clone(), comp.load(&f.clone())?);
                }
                if let Some(f) = spec.graphs.get(&format!("tail.{key}")) {
                    tail.insert(key.clone(), comp.load(&f.clone())?);
                }
            }
        }
        let mut device = Vec::with_capacity(host.len());
        for (p, data) in spec.params.iter().zip(host) {
            device.push(
                comp.client
                    .buffer_from_host_buffer(data, &p.shape, None)
                    .map_err(|e| anyhow!("upload {}: {e:?}", p.name))?,
            );
        }
        Ok(PjrtBackend {
            spec: spec.clone(),
            device,
            fwd_bwd,
            predict,
            adam,
            tail,
            client: comp.client.clone(),
        })
    }

    fn batch_buffers(&self, batch: &Batch) -> Result<[PjRtBuffer; 3]> {
        let dims = [batch.batch, batch.seq_len];
        let t = self
            .client
            .buffer_from_host_buffer(&batch.tokens, &dims, None)
            .map_err(|e| anyhow!("tokens upload: {e:?}"))?;
        let g = self
            .client
            .buffer_from_host_buffer(&batch.targets, &dims, None)
            .map_err(|e| anyhow!("targets upload: {e:?}"))?;
        let m = self
            .client
            .buffer_from_host_buffer(&batch.mask, &dims, None)
            .map_err(|e| anyhow!("mask upload: {e:?}"))?;
        Ok([t, g, m])
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn sync_param(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        let p = &self.spec.params[idx];
        self.device[idx] = self
            .client
            .buffer_from_host_buffer(data, &p.shape, None)
            .map_err(|e| anyhow!("sync {}: {e:?}", p.name))?;
        Ok(())
    }

    fn fwd_bwd(&self, _host: &[Vec<f32>], batch: &Batch) -> Result<StepOutput> {
        let [t, g, m] = self.batch_buffers(batch)?;
        let mut args: Vec<&PjRtBuffer> = self.device.iter().collect();
        args.push(&t);
        args.push(&g);
        args.push(&m);
        let out = self
            .fwd_bwd
            .execute_b(&args)
            .map_err(|e| anyhow!("fwd_bwd execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fwd_bwd output: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let n = self.spec.params.len();
        anyhow::ensure!(parts.len() == n + 2, "unexpected output arity {}", parts.len());
        let loss = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let mut grads = Vec::with_capacity(n);
        for part in &parts[1..=n] {
            grads.push(part.to_vec::<f32>().map_err(|e| anyhow!("grad: {e:?}"))?);
        }
        let sq_norms = parts[n + 1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("sq_norms: {e:?}"))?;
        Ok(StepOutput { loss, grads, sq_norms })
    }

    fn predict(&self, _host: &[Vec<f32>], batch: &Batch) -> Result<EvalOutput> {
        let [t, g, m] = self.batch_buffers(batch)?;
        let mut args: Vec<&PjRtBuffer> = self.device.iter().collect();
        args.push(&t);
        args.push(&g);
        args.push(&m);
        let out = self
            .predict
            .execute_b(&args)
            .map_err(|e| anyhow!("predict execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("predict output: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let loss = parts[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let correct = parts[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("correct: {e:?}"))?;
        Ok(EvalOutput { loss, correct })
    }

    /// Fused Adam update (Pallas kernel): consumes grad + moments,
    /// updates the host mirror + device buffer, returns (m', v', sum(g^2)).
    fn adam_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let shape = self.spec.params[idx].shape.clone();
        let key = self.spec.params[idx].shape_key();
        let exe = self
            .adam
            .get(&key)
            .ok_or_else(|| anyhow!("no adam graph for shape {key}"))?;
        let gbuf = self.client.buffer_from_host_buffer(grad, &shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let mbuf = self.client.buffer_from_host_buffer(m, &shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let vbuf = self.client.buffer_from_host_buffer(v, &shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let lrbuf = self.client.buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let args: Vec<&PjRtBuffer> = vec![&self.device[idx], &gbuf, &mbuf, &vbuf, &lrbuf];
        let out = exe.execute_b(&args).map_err(|e| anyhow!("adam execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        let p_new = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let m_new = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let v_new = parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let sq = parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        *p = p_new;
        self.sync_param(idx, p)?;
        Ok((m_new, v_new, sq))
    }

    /// The additional momentum step (Alg. 1 line 16) via the Pallas tail
    /// kernel: updates the host mirror + device buffer.
    fn tail_update(
        &mut self,
        idx: usize,
        p: &mut Vec<f32>,
        m: &[f32],
        v: &[f32],
        lr: f32,
    ) -> Result<()> {
        let shape = self.spec.params[idx].shape.clone();
        let key = self.spec.params[idx].shape_key();
        let exe = self
            .tail
            .get(&key)
            .ok_or_else(|| anyhow!("no tail graph for shape {key}"))?;
        let mbuf = self.client.buffer_from_host_buffer(m, &shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let vbuf = self.client.buffer_from_host_buffer(v, &shape, None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let lrbuf = self.client.buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let args: Vec<&PjRtBuffer> = vec![&self.device[idx], &mbuf, &vbuf, &lrbuf];
        let out = exe.execute_b(&args).map_err(|e| anyhow!("tail execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let p_new = lit
            .to_tuple1()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        *p = p_new;
        self.sync_param(idx, p)
    }

    // The AOT artifacts are lowered for fixed [b, s] training shapes;
    // no incremental-decode graphs exist, so serving is host-only.
    fn prefill(&self, _host: &[Vec<f32>], _tokens: &[i32], _cache: &mut KvCache)
               -> Result<Vec<f32>> {
        Err(anyhow!(
            "pjrt backend does not support incremental decode: the AOT artifacts \
             contain no prefill/decode graphs — serve with --backend host"
        ))
    }

    // Explicit (not the looping default) so the error surfaces once,
    // clearly, instead of from the first slot's prefill.
    fn prefill_batch(&self, _host: &[Vec<f32>], _chunks: &[&[i32]],
                     _caches: &mut [&mut KvCache]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "pjrt backend does not support incremental decode: the AOT artifacts \
             contain no prefill/decode graphs — serve with --backend host"
        ))
    }

    fn decode_step(&self, _host: &[Vec<f32>], _token: i32, _pos: usize,
                   _cache: &mut KvCache) -> Result<Vec<f32>> {
        Err(anyhow!(
            "pjrt backend does not support incremental decode: the AOT artifacts \
             contain no prefill/decode graphs — serve with --backend host"
        ))
    }

    // Explicit (not the looping default) so the error surfaces once,
    // clearly, instead of from the first slot's decode_step.
    fn decode_batch(&self, _host: &[Vec<f32>], _tokens: &[i32], _positions: &[usize],
                    _caches: &mut [&mut KvCache]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "pjrt backend does not support incremental decode: the AOT artifacts \
             contain no prefill/decode graphs — serve with --backend host"
        ))
    }

    // Explicit (not the looping default) so speculative decoding fails
    // once, clearly, instead of from the first draft position's
    // decode_step.
    fn verify_step(&self, _host: &[Vec<f32>], _chunks: &[&[i32]], _positions: &[usize],
                   _caches: &mut [&mut KvCache]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "pjrt backend does not support incremental decode: the AOT artifacts \
             contain no prefill/decode graphs — serve with --backend host"
        ))
    }
}
