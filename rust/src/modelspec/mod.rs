//! The parameter/module registry — L3's view of the L2 ABI.
//!
//! `python/compile/aot.py` serializes `configs.param_specs` into
//! `artifacts/manifest.txt`; this module parses it back. Parameter order
//! is a hard contract: the fwd/bwd graph consumes params and emits grads
//! in registry order.
//!
//! Terminology (paper Remark 2): a **layer** is a transformer block, a
//! **module** is a matrix parameter inside a layer (`W_q … W_down`), a
//! **block** is whatever unit the optimizer samples. MISA's sampling
//! blocks are the matrix modules; norms/embed/head are parameters but
//! not fine-tuning sampling blocks (Table 2 footnote).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Module kind, mirroring python/compile/configs.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModuleKind {
    Norm,
    Wq,
    Wk,
    Wv,
    Wo,
    Wgate,
    Wup,
    Wdown,
    Embed,
    Head,
}

impl ModuleKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "norm" => ModuleKind::Norm,
            "wq" => ModuleKind::Wq,
            "wk" => ModuleKind::Wk,
            "wv" => ModuleKind::Wv,
            "wo" => ModuleKind::Wo,
            "wgate" => ModuleKind::Wgate,
            "wup" => ModuleKind::Wup,
            "wdown" => ModuleKind::Wdown,
            "embed" => ModuleKind::Embed,
            "head" => ModuleKind::Head,
            other => bail!("unknown module kind {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ModuleKind::Norm => "norm",
            ModuleKind::Wq => "wq",
            ModuleKind::Wk => "wk",
            ModuleKind::Wv => "wv",
            ModuleKind::Wo => "wo",
            ModuleKind::Wgate => "wgate",
            ModuleKind::Wup => "wup",
            ModuleKind::Wdown => "wdown",
            ModuleKind::Embed => "embed",
            ModuleKind::Head => "head",
        }
    }

    /// Is this one of the paper's seven MISA sampling-module kinds?
    pub fn is_matrix_module(&self) -> bool {
        matches!(
            self,
            ModuleKind::Wq
                | ModuleKind::Wk
                | ModuleKind::Wv
                | ModuleKind::Wo
                | ModuleKind::Wgate
                | ModuleKind::Wup
                | ModuleKind::Wdown
        )
    }

    /// All seven matrix-module kinds, in paper order (Fig. 10 x-axis).
    pub fn matrix_kinds() -> [ModuleKind; 7] {
        [
            ModuleKind::Wq,
            ModuleKind::Wk,
            ModuleKind::Wv,
            ModuleKind::Wo,
            ModuleKind::Wgate,
            ModuleKind::Wup,
            ModuleKind::Wdown,
        ]
    }
}

/// One named parameter (the registry row).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub kind: ModuleKind,
    /// transformer layer index, or -1 for embed/head/final_norm
    pub layer: i32,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn shape_key(&self) -> String {
        self.shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }
}

/// Architecture constants for one lowered model configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn_dim: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ModelConfig {
    /// Per-head dimension (dim / n_heads).
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Total key/value width (n_kv_heads * head_dim) — GQA.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }
}

/// A model configuration plus its parameter registry and graph artifacts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub config: ModelConfig,
    pub params: Vec<ParamSpec>,
    /// graph key ("fwd_bwd", "predict", "adam.RxC", "tail.RxC") -> file
    pub graphs: HashMap<String, String>,
}

impl ModelSpec {
    /// Total parameter count (all registry entries).
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Indices of the MISA sampling modules (fine-tuning block set).
    pub fn matrix_module_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.kind.is_matrix_module())
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices trainable in the given mode.
    pub fn trainable_indices(&self, pretrain: bool) -> Vec<usize> {
        if pretrain {
            (0..self.params.len()).collect()
        } else {
            self.matrix_module_indices()
        }
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

// ---------------------------------------------------------------------------
// Builtin registry — mirror of python/compile/configs.py.
//
// The HostBackend needs no AOT artifacts, so the model registry must be
// available without a manifest.txt. These constants are the single Rust
// copy of the CONFIGS list (the ABI order of `param_specs` is identical).
// ---------------------------------------------------------------------------

/// The builtin model configurations (python/compile/configs.py CONFIGS).
pub fn builtin_configs() -> Vec<ModelConfig> {
    let mk = |name: &str, vocab, dim, n_layers, n_heads, n_kv_heads, ffn_dim, seq_len, batch| {
        ModelConfig {
            name: name.to_string(),
            vocab,
            dim,
            n_layers,
            n_heads,
            n_kv_heads,
            ffn_dim,
            seq_len,
            batch,
        }
    };
    vec![
        mk("tiny", 256, 64, 2, 4, 2, 176, 32, 4),
        mk("small", 512, 128, 4, 4, 2, 344, 64, 8),
        mk("pt130", 1024, 192, 4, 6, 3, 512, 64, 8),
        mk("pt350", 1024, 320, 6, 8, 4, 864, 64, 8),
        mk("e2e", 8192, 768, 12, 12, 6, 2048, 64, 4),
    ]
}

/// Build the parameter registry for a configuration, in ABI order —
/// the Rust mirror of python/compile/configs.py::param_specs.
pub fn spec_for(config: ModelConfig) -> ModelSpec {
    let (d, f, v, kd) = (config.dim, config.ffn_dim, config.vocab, config.kv_dim());
    let mut params = Vec::new();
    for i in 0..config.n_layers {
        let layer = i as i32;
        let p = |suffix: &str| format!("layers.{i}.{suffix}");
        params.push(ParamSpec { name: p("attn_norm"), kind: ModuleKind::Norm, layer, shape: vec![d] });
        params.push(ParamSpec { name: p("wq"), kind: ModuleKind::Wq, layer, shape: vec![d, d] });
        params.push(ParamSpec { name: p("wk"), kind: ModuleKind::Wk, layer, shape: vec![d, kd] });
        params.push(ParamSpec { name: p("wv"), kind: ModuleKind::Wv, layer, shape: vec![d, kd] });
        params.push(ParamSpec { name: p("wo"), kind: ModuleKind::Wo, layer, shape: vec![d, d] });
        params.push(ParamSpec { name: p("mlp_norm"), kind: ModuleKind::Norm, layer, shape: vec![d] });
        params.push(ParamSpec { name: p("wgate"), kind: ModuleKind::Wgate, layer, shape: vec![d, f] });
        params.push(ParamSpec { name: p("wup"), kind: ModuleKind::Wup, layer, shape: vec![d, f] });
        params.push(ParamSpec { name: p("wdown"), kind: ModuleKind::Wdown, layer, shape: vec![f, d] });
    }
    params.push(ParamSpec {
        name: "final_norm".into(),
        kind: ModuleKind::Norm,
        layer: -1,
        shape: vec![d],
    });
    params.push(ParamSpec { name: "embed".into(), kind: ModuleKind::Embed, layer: -1, shape: vec![v, d] });
    params.push(ParamSpec { name: "head".into(), kind: ModuleKind::Head, layer: -1, shape: vec![d, v] });
    ModelSpec { config, params, graphs: HashMap::new() }
}

/// The parsed artifact manifest: the L3 entry point.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelSpec>,
    /// sampler-softmax artifacts: module count -> file
    pub probs: HashMap<usize, String>,
}

impl Manifest {
    /// The artifact-free manifest: builtin model registry, no graphs.
    /// This is what the host backend runs on in a fresh checkout.
    pub fn builtin() -> Self {
        Manifest {
            dir: PathBuf::from("<builtin>"),
            models: builtin_configs().into_iter().map(spec_for).collect(),
            probs: HashMap::new(),
        }
    }

    /// Parse `dir/manifest.txt` when present, else the builtin registry.
    pub fn load_or_builtin(dir: &Path) -> Result<Self> {
        if dir.join("manifest.txt").exists() {
            Self::load(dir)
        } else {
            Ok(Self::builtin())
        }
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut models: Vec<ModelSpec> = Vec::new();
        let mut probs = HashMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {raw:?}", lineno + 1);
            match toks[0] {
                "version" => {
                    if toks.get(1) != Some(&"1") {
                        bail!("unsupported manifest version: {raw}");
                    }
                }
                "config" => {
                    models.push(ModelSpec {
                        config: ModelConfig {
                            name: toks.get(1).ok_or_else(|| anyhow!(ctx()))?.to_string(),
                            vocab: 0,
                            dim: 0,
                            n_layers: 0,
                            n_heads: 0,
                            n_kv_heads: 0,
                            ffn_dim: 0,
                            seq_len: 0,
                            batch: 0,
                        },
                        params: Vec::new(),
                        graphs: HashMap::new(),
                    });
                }
                "field" => {
                    let m = models.last_mut().ok_or_else(|| anyhow!(ctx()))?;
                    let key = toks[1];
                    let val: usize = toks[2].parse().with_context(ctx)?;
                    match key {
                        "vocab" => m.config.vocab = val,
                        "dim" => m.config.dim = val,
                        "n_layers" => m.config.n_layers = val,
                        "n_heads" => m.config.n_heads = val,
                        "n_kv_heads" => m.config.n_kv_heads = val,
                        "ffn_dim" => m.config.ffn_dim = val,
                        "seq_len" => m.config.seq_len = val,
                        "batch" => m.config.batch = val,
                        other => bail!("unknown field {other:?} in {}", ctx()),
                    }
                }
                "param" => {
                    let m = models.last_mut().ok_or_else(|| anyhow!(ctx()))?;
                    let name = toks[1].to_string();
                    let kind = ModuleKind::parse(toks[2]).with_context(ctx)?;
                    let layer: i32 = toks[3].parse().with_context(ctx)?;
                    let ndim: usize = toks[4].parse().with_context(ctx)?;
                    let shape: Vec<usize> = toks[5..5 + ndim]
                        .iter()
                        .map(|t| t.parse().unwrap())
                        .collect();
                    m.params.push(ParamSpec { name, kind, layer, shape });
                }
                "graph" => {
                    let m = models.last_mut().ok_or_else(|| anyhow!(ctx()))?;
                    m.graphs.insert(toks[1].to_string(), toks[2].to_string());
                }
                "probs" => {
                    let b: usize = toks[1].parse().with_context(ctx)?;
                    probs.insert(b, toks[2].to_string());
                }
                other => bail!("unknown manifest directive {other:?} at {}", ctx()),
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, probs })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.config.name == name)
            .ok_or_else(|| anyhow!("config {name:?} not in manifest"))
    }

    pub fn graph_path(&self, spec: &ModelSpec, key: &str) -> Result<PathBuf> {
        let file = spec
            .graphs
            .get(key)
            .ok_or_else(|| anyhow!("graph {key:?} missing for {}", spec.config.name))?;
        Ok(self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1
config tiny
  field vocab 256
  field dim 64
  field n_layers 2
  field n_heads 4
  field n_kv_heads 2
  field ffn_dim 176
  field seq_len 32
  field batch 4
  param layers.0.attn_norm norm 0 1 64
  param layers.0.wq wq 0 2 64 64
  param layers.0.wk wk 0 2 64 32
  param embed embed -1 2 256 64
  graph fwd_bwd tiny.fwd_bwd.hlo.txt
  graph adam.64x64 tiny.adam.64x64.hlo.txt
probs 14 probs.14.hlo.txt
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.models.len(), 1);
        let spec = m.model("tiny").unwrap();
        assert_eq!(spec.config.dim, 64);
        assert_eq!(spec.params.len(), 4);
        assert_eq!(spec.params[1].kind, ModuleKind::Wq);
        assert_eq!(spec.params[1].numel(), 64 * 64);
        assert_eq!(spec.params[1].shape_key(), "64x64");
        assert_eq!(m.probs.get(&14).unwrap(), "probs.14.hlo.txt");
        assert_eq!(
            m.graph_path(spec, "fwd_bwd").unwrap(),
            Path::new("/tmp/tiny.fwd_bwd.hlo.txt")
        );
    }

    #[test]
    fn matrix_module_filtering() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let spec = m.model("tiny").unwrap();
        assert_eq!(spec.matrix_module_indices(), vec![1, 2]);
        assert_eq!(spec.trainable_indices(false), vec![1, 2]);
        assert_eq!(spec.trainable_indices(true), vec![0, 1, 2, 3]);
    }

    #[test]
    fn rejects_bad_kind() {
        assert!(ModuleKind::parse("conv").is_err());
    }

    #[test]
    fn kind_roundtrip() {
        for k in ModuleKind::matrix_kinds() {
            assert_eq!(ModuleKind::parse(k.as_str()).unwrap(), k);
            assert!(k.is_matrix_module());
        }
        assert!(!ModuleKind::Norm.is_matrix_module());
        assert!(!ModuleKind::Embed.is_matrix_module());
    }

    #[test]
    fn missing_config_errors() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
        let spec = m.model("tiny").unwrap();
        assert!(m.graph_path(spec, "predict").is_err());
    }

    #[test]
    fn builtin_registry_mirrors_configs_py() {
        let m = Manifest::builtin();
        assert_eq!(m.models.len(), 5);
        let tiny = m.model("tiny").unwrap();
        // 9 params per layer + final_norm + embed + head
        assert_eq!(tiny.params.len(), 2 * 9 + 3);
        assert_eq!(tiny.config.kv_dim(), 32);
        assert_eq!(tiny.config.head_dim(), 16);
        // ABI order: attn_norm, wq, wk, wv, wo, mlp_norm, wgate, wup, wdown
        let kinds: Vec<ModuleKind> = tiny.params[..9].iter().map(|p| p.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ModuleKind::Norm,
                ModuleKind::Wq,
                ModuleKind::Wk,
                ModuleKind::Wv,
                ModuleKind::Wo,
                ModuleKind::Norm,
                ModuleKind::Wgate,
                ModuleKind::Wup,
                ModuleKind::Wdown,
            ]
        );
        assert_eq!(tiny.params[1].shape, vec![64, 64]);
        assert_eq!(tiny.params[2].shape, vec![64, 32]); // GQA: kv_dim
        assert_eq!(tiny.params[8].shape, vec![176, 64]); // wdown [f, d]
        let last = &tiny.params[tiny.params.len() - 1];
        assert_eq!(last.name, "head");
        assert_eq!(last.shape, vec![64, 256]);
        // every config has matrix modules for the sampler
        for spec in &m.models {
            assert_eq!(
                spec.matrix_module_indices().len(),
                7 * spec.config.n_layers
            );
        }
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let m = Manifest::load_or_builtin(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(m.models.len(), 5);
    }
}
