//! Token samplers over final-position logits.
//!
//! Greedy, temperature, top-k and top-p (nucleus) sampling, composed in
//! the conventional order: temperature scaling → top-k truncation →
//! top-p truncation → renormalize → draw. All randomness comes from the
//! caller's deterministic `util::Rng`, so a (seed, prompt, checkpoint)
//! triple always regenerates the same tokens — the property the CLI
//! and the serving tests pin.

use anyhow::{ensure, Result};

use crate::util::Rng;

/// Sampling configuration for one generation stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplerCfg {
    /// Softmax temperature; `<= 0` selects greedy decoding.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens (`0` disables).
    pub top_k: usize,
    /// Nucleus: keep the smallest prefix of the sorted distribution
    /// whose cumulative mass reaches `top_p` (`>= 1.0` disables).
    pub top_p: f32,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg { temperature: 1.0, top_k: 0, top_p: 1.0 }
    }
}

impl SamplerCfg {
    /// Deterministic argmax decoding.
    pub fn greedy() -> Self {
        SamplerCfg { temperature: 0.0, top_k: 0, top_p: 1.0 }
    }

    /// Reject configurations the sampler cannot execute (non-finite
    /// temperature, non-positive top-p).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.temperature.is_finite(), "temperature must be finite");
        ensure!(
            self.top_p > 0.0 && self.top_p.is_finite(),
            "top-p must be positive (got {}); values >= 1 disable nucleus sampling",
            self.top_p
        );
        Ok(())
    }
}

/// Index of the largest logit (first on ties — the shared
/// [`crate::util::argmax`], the same convention the predict graph uses).
pub fn argmax(logits: &[f32]) -> usize {
    crate::util::argmax(logits)
}

/// Draw one token id from `logits` under `cfg`.
pub fn sample(logits: &[f32], cfg: &SamplerCfg, rng: &mut Rng) -> usize {
    assert!(!logits.is_empty(), "sample over empty logits");
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // candidate order: descending logit, ties by index, so the order —
    // and therefore the draw — is fully deterministic. This runs per
    // decoded token, so only order what the filters actually need:
    // top-k selects to the k boundary and sorts just the kept k;
    // top-p needs the kept set sorted; plain temperature needs nothing.
    let n = logits.len();
    let mut order: Vec<usize> = (0..n).collect();
    let desc = |a: &usize, b: &usize| logits[*b].total_cmp(&logits[*a]).then(a.cmp(b));
    if cfg.top_k > 0 && cfg.top_k < n {
        order.select_nth_unstable_by(cfg.top_k - 1, desc);
        order.truncate(cfg.top_k);
        order.sort_unstable_by(desc);
    } else if cfg.top_p < 1.0 {
        order.sort_unstable_by(desc);
    }

    // softmax over the surviving candidates at the given temperature.
    // Subtract the max BEFORE scaling, in f64: the top exponent stays 0,
    // so even near-zero temperatures (1/T overflowing f32) degrade to
    // near-greedy instead of NaN probabilities.
    let inv_t = 1.0f64 / cfg.temperature as f64;
    let mx = order.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = order
        .iter()
        .map(|&i| (((logits[i] - mx) as f64) * inv_t).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= total;
    }

    // nucleus truncation: keep the smallest prefix reaching top_p mass
    // (always at least one token)
    if cfg.top_p < 1.0 {
        let mut cum = 0.0f64;
        let mut cut = probs.len();
        for (j, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p as f64 {
                cut = j + 1;
                break;
            }
        }
        probs.truncate(cut);
        order.truncate(cut);
        let mass: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= mass;
        }
    }

    let mut u = rng.f64();
    for (j, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return order[j];
        }
    }
    *order.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_from(xs: &[f32]) -> Vec<f32> {
        xs.to_vec()
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let logits = logits_from(&[0.1, 2.0, -1.0, 1.9]);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let cfg = SamplerCfg { temperature: 0.0, top_k: 2, top_p: 0.5 };
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
            assert_eq!(argmax(&logits), 1);
        }
    }

    #[test]
    fn top_k_truncates_support() {
        // logits rank: idx 3 > 1 > 0 > 2; k=2 must only ever emit {3, 1}
        let logits = logits_from(&[0.5, 1.5, -0.5, 2.5]);
        let cfg = SamplerCfg { temperature: 1.0, top_k: 2, top_p: 1.0 };
        let mut rng = Rng::new(7);
        let mut seen = [0usize; 4];
        for _ in 0..500 {
            seen[sample(&logits, &cfg, &mut rng)] += 1;
        }
        assert_eq!(seen[0], 0);
        assert_eq!(seen[2], 0);
        assert!(seen[1] > 0 && seen[3] > 0, "both top-2 tokens should appear: {seen:?}");
        assert!(seen[3] > seen[1], "higher logit must dominate: {seen:?}");
    }

    #[test]
    fn top_p_truncates_tail_mass() {
        // one dominant token (~0.95 mass): top_p = 0.9 keeps only it
        let logits = logits_from(&[6.0, 0.0, 0.0, 0.0]);
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.9 };
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 0);
        }
        // two equal heads holding ~all mass: top_p = 0.9 keeps both,
        // never the tail
        let logits = logits_from(&[5.0, 5.0, -5.0, -5.0]);
        let mut seen = [0usize; 4];
        for _ in 0..500 {
            seen[sample(&logits, &cfg, &mut rng)] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "{seen:?}");
        assert_eq!(seen[2] + seen[3], 0, "{seen:?}");
    }

    #[test]
    fn temperature_sharpens_distribution() {
        let logits = logits_from(&[1.0, 0.0]);
        let mut hot = 0usize;
        let mut cold = 0usize;
        let n = 2000;
        let mut rng = Rng::new(13);
        for _ in 0..n {
            let c = SamplerCfg { temperature: 4.0, ..SamplerCfg::default() };
            if sample(&logits, &c, &mut rng) == 0 {
                hot += 1;
            }
            let c = SamplerCfg { temperature: 0.25, ..SamplerCfg::default() };
            if sample(&logits, &c, &mut rng) == 0 {
                cold += 1;
            }
        }
        // T=4 → p(0) ≈ 0.56; T=0.25 → p(0) ≈ 0.98
        assert!(cold > hot, "cold {cold} vs hot {hot}");
        assert!(cold as f64 / n as f64 > 0.9, "cold frac {}", cold as f64 / n as f64);
    }

    #[test]
    fn same_seed_same_draws() {
        let logits = logits_from(&[0.3, 0.1, 0.9, 0.2, 0.45]);
        let cfg = SamplerCfg { temperature: 0.8, top_k: 4, top_p: 0.95 };
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &cfg, &mut a), sample(&logits, &cfg, &mut b));
        }
    }

    #[test]
    fn tiny_temperature_degrades_to_near_greedy_not_nan() {
        // 1/T overflows f32 at T ~ 1e-39; the draw must still pick the
        // argmax token, never fall through on NaN probabilities
        let logits = logits_from(&[0.5, 3.0, -1.0, 2.9]);
        let cfg = SamplerCfg { temperature: 1e-39, top_k: 0, top_p: 1.0 };
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(SamplerCfg { top_p: 0.0, ..SamplerCfg::default() }.validate().is_err());
        assert!(SamplerCfg { temperature: f32::NAN, ..SamplerCfg::default() }
            .validate()
            .is_err());
        assert!(SamplerCfg::default().validate().is_ok());
        assert!(SamplerCfg::greedy().validate().is_ok());
    }
}
