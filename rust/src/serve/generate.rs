//! Single-stream generation: prefill the prompt, then decode
//! token-by-token against one KV cache. This is the `misa generate`
//! engine; multi-request serving goes through [`crate::serve::scheduler`].

use anyhow::{ensure, Result};

use crate::runtime::Session;
use crate::serve::sampler::{sample, SamplerCfg};
use crate::util::Rng;

/// Configuration for one generation.
#[derive(Clone, Debug)]
pub struct GenerateCfg {
    /// Number of new tokens to produce (generation may stop earlier on
    /// `eos`).
    pub max_new: usize,
    /// Token-selection configuration.
    pub sampler: SamplerCfg,
    /// Seed of the sampling stream — fixes the generation entirely.
    pub seed: u64,
    /// Optional stop token: generation ends once it is emitted.
    pub eos: Option<i32>,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg { max_new: 32, sampler: SamplerCfg::greedy(), seed: 0, eos: None }
    }
}

/// One finished generation plus its latency/throughput measurements.
#[derive(Clone, Debug)]
pub struct Generation {
    /// Newly generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Prefill-to-first-token latency, seconds.
    pub ttft_s: f64,
    /// Decode throughput over the post-prefill tokens, tokens/second.
    pub decode_tps: f64,
}

/// Generate up to `cfg.max_new` tokens after `prompt`.
pub fn generate(sess: &Session, prompt: &[i32], cfg: &GenerateCfg) -> Result<Generation> {
    ensure!(!prompt.is_empty(), "generate: empty prompt");
    ensure!(cfg.max_new > 0, "generate: max_new must be > 0");
    cfg.sampler.validate()?;
    let mut cache = sess.kv_cache(prompt.len() + cfg.max_new)?;
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut logits = sess.prefill(prompt, &mut cache)?;
    let first = sample(&logits, &cfg.sampler, &mut rng) as i32;
    let ttft_s = t0.elapsed().as_secs_f64();
    let mut tokens = vec![first];
    let t1 = std::time::Instant::now();
    while tokens.len() < cfg.max_new && cfg.eos != Some(*tokens.last().unwrap()) {
        let last = *tokens.last().unwrap();
        logits = sess.decode_step(last, cache.len(), &mut cache)?;
        tokens.push(sample(&logits, &cfg.sampler, &mut rng) as i32);
    }
    let decode_s = t1.elapsed().as_secs_f64();
    let decoded = tokens.len().saturating_sub(1);
    Ok(Generation {
        tokens,
        ttft_s,
        decode_tps: if decode_s > 0.0 { decoded as f64 / decode_s } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Session};

    fn tiny_session() -> Session {
        let mut eng = Engine::host();
        Session::create(&mut eng, "tiny", 0).unwrap()
    }

    #[test]
    fn greedy_generation_is_reproducible() {
        let sess = tiny_session();
        let cfg = GenerateCfg { max_new: 8, ..GenerateCfg::default() };
        let a = generate(&sess, &[1, 20, 7], &cfg).unwrap();
        let b = generate(&sess, &[1, 20, 7], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
        let v = sess.spec.config.vocab as i32;
        assert!(a.tokens.iter().all(|&t| t >= 0 && t < v));
        assert!(a.ttft_s >= 0.0 && a.decode_tps >= 0.0);
    }

    #[test]
    fn sampled_generation_depends_only_on_seed() {
        let sess = tiny_session();
        let sampler = SamplerCfg { temperature: 0.9, top_k: 32, top_p: 0.95 };
        let mk = |seed| GenerateCfg { max_new: 12, sampler, seed, eos: None };
        let a = generate(&sess, &[1, 5], &mk(3)).unwrap();
        let b = generate(&sess, &[1, 5], &mk(3)).unwrap();
        let c = generate(&sess, &[1, 5], &mk(4)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_ne!(a.tokens, c.tokens, "different seeds should diverge");
    }

    #[test]
    fn eos_stops_generation_early() {
        let sess = tiny_session();
        // greedy decode once to learn the first emitted token, then use
        // it as the stop token: generation must end right there
        let probe =
            generate(&sess, &[1, 9], &GenerateCfg { max_new: 4, ..Default::default() })
                .unwrap();
        let stop = probe.tokens[0];
        let cfg = GenerateCfg { max_new: 16, eos: Some(stop), ..Default::default() };
        let g = generate(&sess, &[1, 9], &cfg).unwrap();
        assert_eq!(g.tokens, vec![stop]);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let sess = tiny_session();
        assert!(generate(&sess, &[], &GenerateCfg::default()).is_err());
        let cfg = GenerateCfg { max_new: 0, ..Default::default() };
        assert!(generate(&sess, &[1], &cfg).is_err());
    }
}
