//! Single-stream generation: prefill the prompt, then decode
//! token-by-token against one KV cache — or, with
//! [`GenerateCfg::spec`] set, several tokens per verification forward
//! through the speculative path (same tokens, fewer forwards). This is
//! the `misa generate` engine; multi-request serving goes through
//! [`crate::serve::scheduler`].

use anyhow::{ensure, Result};

use crate::obs::Timeline;
use crate::runtime::Session;
use crate::serve::sampler::{sample, SamplerCfg};
use crate::serve::spec::{self, DraftCtl, SpecCfg, SpecStats};
use crate::util::Rng;

/// Configuration for one generation.
#[derive(Clone, Debug)]
pub struct GenerateCfg {
    /// Number of new tokens to produce (generation may stop earlier on
    /// `eos`).
    pub max_new: usize,
    /// Token-selection configuration.
    pub sampler: SamplerCfg,
    /// Seed of the sampling stream — fixes the generation entirely.
    pub seed: u64,
    /// Optional stop token: generation ends once it is emitted.
    pub eos: Option<i32>,
    /// Speculative decoding: draft from the stream's own history and
    /// verify several tokens per forward. Output is identical with or
    /// without it (exact parity, test-pinned); only wall-clock
    /// changes. `None` decodes one token per forward. The default
    /// honors the `MISA_SPEC` environment override
    /// ([`SpecCfg::from_env`]).
    pub spec: Option<SpecCfg>,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg {
            max_new: 32,
            sampler: SamplerCfg::greedy(),
            seed: 0,
            eos: None,
            spec: SpecCfg::from_env(),
        }
    }
}

/// One finished generation plus its latency/throughput measurements.
#[derive(Clone, Debug)]
pub struct Generation {
    /// Newly generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Prefill-to-first-token latency, seconds.
    pub ttft_s: f64,
    /// Decode throughput over the post-prefill tokens, tokens/second.
    pub decode_tps: f64,
    /// Inter-token latency samples in milliseconds, one per token
    /// after the first (a speculative tick emitting `n` tokens
    /// contributes `n` samples of `gap / n`).
    pub itl_ms: Vec<f64>,
    /// Drafting counters when speculative decoding ran (`None`
    /// otherwise).
    pub spec: Option<SpecStats>,
}

/// Generate up to `cfg.max_new` tokens after `prompt`.
pub fn generate(sess: &Session, prompt: &[i32], cfg: &GenerateCfg) -> Result<Generation> {
    ensure!(!prompt.is_empty(), "generate: empty prompt");
    ensure!(cfg.max_new > 0, "generate: max_new must be > 0");
    cfg.sampler.validate()?;
    if let Some(s) = &cfg.spec {
        s.validate()?;
    }
    let _sp = crate::span!("generate", "serve");
    let mut cache = sess.kv_cache(prompt.len() + cfg.max_new)?;
    let mut rng = Rng::new(cfg.seed);
    let mut tl = Timeline::start();
    let t0 = std::time::Instant::now();
    let logits = sess.prefill(prompt, &mut cache)?;
    let first = sample(&logits, &cfg.sampler, &mut rng) as i32;
    let ttft_s = t0.elapsed().as_secs_f64();
    tl.mark_first_token();
    let mut tokens = vec![first];
    let t1 = std::time::Instant::now();
    let stats = match cfg.spec {
        Some(scfg) => Some(spec_decode_loop(
            sess, prompt, &mut tokens, &mut cache, &mut rng, &mut tl, cfg, &scfg,
        )?),
        None => {
            while tokens.len() < cfg.max_new && cfg.eos != Some(*tokens.last().unwrap()) {
                let last = *tokens.last().unwrap();
                let logits = sess.decode_step(last, cache.len(), &mut cache)?;
                tokens.push(sample(&logits, &cfg.sampler, &mut rng) as i32);
                tl.emit(1);
            }
            None
        }
    };
    let decode_s = t1.elapsed().as_secs_f64();
    let decoded = tokens.len().saturating_sub(1);
    Ok(Generation {
        tokens,
        ttft_s,
        decode_tps: if decode_s > 0.0 { decoded as f64 / decode_s } else { 0.0 },
        itl_ms: tl.itl_ms,
        spec: stats,
    })
}

/// The speculative decode loop: draft from history, verify the stacked
/// chunk in one forward, keep the verified prefix plus the model's own
/// next token, roll the rejected suffix out of the cache. Emits
/// exactly the tokens the sequential loop in [`generate`] would.
#[allow(clippy::too_many_arguments)]
fn spec_decode_loop(
    sess: &Session,
    prompt: &[i32],
    tokens: &mut Vec<i32>,
    cache: &mut crate::runtime::KvCache,
    rng: &mut Rng,
    tl: &mut Timeline,
    cfg: &GenerateCfg,
    scfg: &SpecCfg,
) -> Result<SpecStats> {
    let vocab = sess.spec.config.vocab;
    let mut ctl = DraftCtl::new(scfg);
    let mut stats = SpecStats::default();
    // the proposer's view of the stream: prompt plus everything emitted
    let mut history = prompt.to_vec();
    history.extend_from_slice(tokens);
    while tokens.len() < cfg.max_new && cfg.eos != Some(*tokens.last().unwrap()) {
        let remaining = cfg.max_new - tokens.len();
        let budget = spec::draft_budget(ctl.draft_len(), cache.len(), cache.capacity(), remaining);
        let (chunk, drafts) = spec::draft_chunk(&history, scfg.ngram, budget);
        let start = cache.len();
        let rows = {
            let mut caches = [&mut *cache];
            sess.verify_step(&[chunk.as_slice()], &[start], &mut caches)?
        };
        let (emitted, accepted) = spec::accept(&rows[0], vocab, &drafts, &cfg.sampler, rng);
        stats.record(drafts.len(), accepted);
        ctl.record(scfg, drafts.len(), accepted);
        let mut pushed = 0usize;
        for &x in &emitted {
            tokens.push(x);
            history.push(x);
            pushed += 1;
            if tokens.len() >= cfg.max_new || cfg.eos == Some(x) {
                break;
            }
        }
        tl.emit(pushed);
        // the verified-correct prefix stays resident: `last` plus the
        // accepted drafts; the corrective/bonus token is fed next tick
        cache.truncate(start + 1 + accepted)?;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Session};

    fn tiny_session() -> Session {
        let mut eng = Engine::host();
        Session::create(&mut eng, "tiny", 0).unwrap()
    }

    #[test]
    fn greedy_generation_is_reproducible() {
        let sess = tiny_session();
        let cfg = GenerateCfg { max_new: 8, ..GenerateCfg::default() };
        let a = generate(&sess, &[1, 20, 7], &cfg).unwrap();
        let b = generate(&sess, &[1, 20, 7], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
        let v = sess.spec.config.vocab as i32;
        assert!(a.tokens.iter().all(|&t| t >= 0 && t < v));
        assert!(a.ttft_s >= 0.0 && a.decode_tps >= 0.0);
        assert_eq!(
            a.itl_ms.len(),
            a.tokens.len() - 1,
            "one ITL sample per token after the first"
        );
        assert!(a.itl_ms.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn sampled_generation_depends_only_on_seed() {
        let sess = tiny_session();
        let sampler = SamplerCfg { temperature: 0.9, top_k: 32, top_p: 0.95 };
        let mk = |seed| GenerateCfg { max_new: 12, sampler, seed, ..GenerateCfg::default() };
        let a = generate(&sess, &[1, 5], &mk(3)).unwrap();
        let b = generate(&sess, &[1, 5], &mk(3)).unwrap();
        let c = generate(&sess, &[1, 5], &mk(4)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_ne!(a.tokens, c.tokens, "different seeds should diverge");
    }

    #[test]
    fn eos_stops_generation_early() {
        let sess = tiny_session();
        // greedy decode once to learn the first emitted token, then use
        // it as the stop token: generation must end right there
        let probe =
            generate(&sess, &[1, 9], &GenerateCfg { max_new: 4, ..Default::default() })
                .unwrap();
        let stop = probe.tokens[0];
        let cfg = GenerateCfg { max_new: 16, eos: Some(stop), ..Default::default() };
        let g = generate(&sess, &[1, 9], &cfg).unwrap();
        assert_eq!(g.tokens, vec![stop]);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let sess = tiny_session();
        assert!(generate(&sess, &[], &GenerateCfg::default()).is_err());
        let cfg = GenerateCfg { max_new: 0, ..Default::default() };
        assert!(generate(&sess, &[1], &cfg).is_err());
        let cfg = GenerateCfg {
            spec: Some(SpecCfg { draft_len: 0, ngram: 3 }),
            ..Default::default()
        };
        assert!(generate(&sess, &[1], &cfg).is_err());
    }

    /// Tentpole invariant, solo flavor: speculative generation emits
    /// exactly the tokens sequential generation emits — greedy and
    /// seeded-sampled — and reports its drafting counters.
    #[test]
    fn spec_generation_matches_plain_generation() {
        let sess = tiny_session();
        // a prompt with recurring n-grams so the proposer always has
        // something to say
        let prompt = [1, 30, 31, 32, 30, 31, 32, 30, 31];
        for sampler in [
            SamplerCfg::greedy(),
            SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 },
        ] {
            let plain = GenerateCfg {
                max_new: 20,
                sampler,
                seed: 11,
                eos: None,
                spec: None,
            };
            let spec = GenerateCfg {
                spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
                ..plain.clone()
            };
            let a = generate(&sess, &prompt, &plain).unwrap();
            let b = generate(&sess, &prompt, &spec).unwrap();
            assert_eq!(a.tokens, b.tokens, "speculation changed the output");
            assert!(a.spec.is_none());
            // counter consistency; guaranteed drafting/acceptance is
            // pinned deterministically by the fixed-point test below
            let st = b.spec.unwrap();
            assert!(st.accepted <= st.drafted);
        }
    }

    /// Deterministic full acceptance: an all-zero parameter set makes
    /// every logits row identical (argmax 0), so greedy decode is a
    /// fixed point the n-gram proposer predicts perfectly — acceptance
    /// is structural, not statistical.
    #[test]
    fn spec_acceptance_is_full_on_a_fixed_point_stream() {
        let mut eng = Engine::host();
        let spec_m = eng.manifest.model("tiny").unwrap().clone();
        let zeros: Vec<Vec<f32>> =
            spec_m.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let sess = Session::with_params(&mut eng, spec_m, zeros).unwrap();
        let cfg = GenerateCfg {
            max_new: 16,
            spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
            ..GenerateCfg::default()
        };
        let g = generate(&sess, &[1, 0, 0], &cfg).unwrap();
        assert_eq!(g.tokens, vec![0; 16], "zero params greedy-decode to token 0");
        let st = g.spec.unwrap();
        assert!(st.drafted > 0);
        assert_eq!(st.accepted, st.drafted, "every draft of a fixed point verifies");
        assert!(st.acceptance_rate() > 0.999);
    }
}
