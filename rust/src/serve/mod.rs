//! Inference serving subsystem.
//!
//! Training produces checkpoints; this module is how they get *used*.
//! It layers on the execution ABI's serving entry points
//! (`Backend::prefill` / `Backend::prefill_batch` /
//! `Backend::decode_step` / `Backend::decode_batch` over per-slot
//! `runtime::KvCache`s) and is backend-agnostic like everything else
//! above the runtime — though only the host backend implements
//! incremental decode today (PJRT's AOT artifacts carry no decode
//! graphs and return a clear unsupported error).
//!
//! - [`sampler`] — token selection over final-position logits: greedy,
//!   temperature, top-k, top-p. Driven by the deterministic `util::Rng`
//!   so generations are seed-reproducible.
//! - [`mod@generate`] — the single-stream loop: prefill the prompt,
//!   then decode token-by-token against one KV cache. Powers
//!   `misa generate`.
//! - [`cache_store`] — the prefix-sharing prompt cache: a token-prefix
//!   trie whose entries are prefilled prompts; a new request forks the
//!   longest matching prefix (`KvCache::fork_from`, copy-on-write at
//!   ring-chunk granularity) and prefills only its novel suffix.
//! - [`spec`] — speculative decoding: a self-drafting n-gram proposer
//!   over each stream's own token history, the multi-token acceptance
//!   walk against `Backend::verify_step`'s stacked logits, and the
//!   adaptive draft-length controller. Greedy *and* seeded-sampled
//!   output is bit-identical with speculation on or off (the
//!   acceptance walk consumes the same RNG stream sequential decode
//!   would); only the number of forwards changes.
//! - [`scheduler`] — continuous batching: a request queue with
//!   token-budget admission, per-slot KV caches, iteration-level
//!   scheduling (new requests are admitted the moment finished ones
//!   free slots), shared-prefix admission grouping with one stacked
//!   `prefill_batch` forward per wave, chunked prefill
//!   (`SchedulerCfg::prefill_chunk`) so giant prompts never stall
//!   in-flight decode, speculative multi-token ticks through one
//!   ragged `verify_step`, and per-request TTFT / tokens-per-second /
//!   prefix-reuse / draft-acceptance metrics through `util::metrics`.
//!   Powers `misa bench-serve`. Every request carries a
//!   [`crate::obs::Timeline`] (enqueue → admit → prefill → first token
//!   → finish) pooled into exact TTFT/ITL percentile distributions,
//!   and the hot paths are spanned for `--trace-out` Chrome traces —
//!   see DESIGN.md §7.
//! - [`capacity`] — capacity planning: sweep the scheduler over a
//!   (slots × token-budget × threads) grid, least-squares-fit closed
//!   forms for peak KV residency and throughput, and answer
//!   `misa capacity --predict` sizing queries from the saved fit.
//!
//! Memory accounting: one slot's KV cache holds
//! `2 * n_layers * capacity * kv_dim` f32s (`KvCache::bytes`), where
//! `kv_dim = n_kv_heads * head_dim` — GQA-sized, `n_heads / n_kv_heads`
//! times smaller than full attention residency. The scheduler's token
//! budget bounds the sum of per-request costs (`prompt_len + max_new`
//! positions each), which bounds per-request resident KV bytes (cache
//! misses allocate exactly their cost; hits share the store ring's
//! prefix chunks copy-on-write); the prompt store's own residency is
//! bounded separately by its `max_entries × capacity` configuration.
//! See DESIGN.md §5 for the full serving-cache architecture.

#![warn(missing_docs)]

pub mod cache_store;
pub mod capacity;
pub mod generate;
pub mod sampler;
pub mod scheduler;
pub mod spec;

pub use cache_store::{CacheStats, CacheStore, CacheStoreCfg};
pub use capacity::{CapacityModel, CapacityPoint, SweepCfg};
pub use generate::{generate, GenerateCfg, Generation};
pub use sampler::{argmax, sample, SamplerCfg};
pub use scheduler::{Completion, FinishReason, Request, Scheduler, SchedulerCfg};
pub use spec::{DraftCtl, SpecCfg, SpecStats};
