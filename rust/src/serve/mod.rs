//! Inference serving subsystem.
//!
//! Training produces checkpoints; this module is how they get *used*.
//! It layers on the execution ABI's serving entry points
//! (`Backend::prefill` / `Backend::decode_step` / `Backend::decode_batch`
//! over per-slot `runtime::KvCache`s) and is backend-agnostic like
//! everything else
//! above the runtime — though only the host backend implements
//! incremental decode today (PJRT's AOT artifacts carry no decode
//! graphs and return a clear unsupported error).
//!
//! - [`sampler`] — token selection over final-position logits: greedy,
//!   temperature, top-k, top-p. Driven by the deterministic `util::Rng`
//!   so generations are seed-reproducible.
//! - [`generate`] — the single-stream loop: prefill the prompt, then
//!   decode token-by-token against one KV cache. Powers
//!   `misa generate`.
//! - [`scheduler`] — continuous batching: a request queue with
//!   token-budget admission, per-slot KV caches, iteration-level
//!   scheduling (new requests are admitted the moment finished ones
//!   free slots), and per-request TTFT / tokens-per-second metrics
//!   through `util::metrics`. Powers `misa bench-serve`.
//!
//! Memory accounting: one slot's KV cache holds
//! `2 * n_layers * capacity * kv_dim` f32s (`KvCache::bytes`), where
//! `capacity = prompt_len + max_new` and `kv_dim = n_kv_heads *
//! head_dim` — GQA-sized, `n_heads / n_kv_heads` times smaller than
//! full attention residency. The scheduler's token budget bounds the
//! sum of slot capacities, which bounds resident KV bytes.

pub mod generate;
pub mod sampler;
pub mod scheduler;

pub use generate::{generate, GenerateCfg, Generation};
pub use sampler::{argmax, sample, SamplerCfg};
pub use scheduler::{Completion, FinishReason, Request, Scheduler, SchedulerCfg};
