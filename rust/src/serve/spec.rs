//! Speculative decoding: a self-drafting n-gram proposer plus the
//! multi-token acceptance walk.
//!
//! Decode emits one token per forward pass, so latency is bound by
//! model depth rather than arithmetic throughput. Speculative decoding
//! converts several sequential decode steps into one stacked
//! verification forward: a cheap proposer guesses up to `k` draft
//! tokens, `Backend::verify_step` runs `[last_token, draft...]` as one
//! multi-token cached forward returning logits at *every* position,
//! and the longest draft prefix the model itself would have produced
//! is accepted — together with the model's one corrective (or bonus)
//! token from the row after the last accepted draft. Rejected draft
//! positions are rolled out of the KV cache with
//! `KvCache::truncate`. The same accept-only-what-verifies idea MISA
//! applies to sampled modules in training, applied to decode work.
//!
//! No second model is needed: the proposer is prompt-lookup / n-gram
//! matching over the slot's own token history ([`propose`]) — serving
//! workloads are full of repeated structure (retrieval spans, code,
//! template continuations), and whenever the recent suffix occurred
//! earlier, whatever followed it then is a strong guess for what
//! follows now. [`DraftCtl`] adapts the draft length per slot: full
//! acceptance grows it back toward the configured cap, zero acceptance
//! halves it, so slots whose history stops predicting pay for at most
//! a halving cascade rather than `k` wasted rows per tick.
//!
//! **Exact parity.** The acceptance walk ([`accept`]) samples each
//! verified row with the *same* sampler and the *same* per-request RNG
//! stream the sequential loop would have used, and the host backend's
//! verify rows are bit-identical to sequential `decode_step` rows (one
//! GEMM core, fixed per-row reduction order). By induction, every
//! emitted token — greedy *or* seeded-sampled — equals the token the
//! non-speculative loop would have emitted; drafting changes
//! wall-clock, never output. `rust/tests/serve.rs` pins this, and the
//! entire test suite can be re-run with speculation forced on via
//! `MISA_SPEC` (see [`SpecCfg::from_env`]).

use anyhow::{ensure, Result};

use crate::serve::sampler::{sample, SamplerCfg};
use crate::util::Rng;

/// Speculative-decoding configuration (per scheduler or generation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecCfg {
    /// Maximum draft tokens proposed per slot per tick (`k`). The
    /// verify chunk is `k + 1` rows (the last sampled token plus the
    /// draft), so a fully accepted tick advances `k + 1` tokens.
    pub draft_len: usize,
    /// Longest history suffix the proposer tries to match (it backs
    /// off `ngram..=1` until a match is found).
    pub ngram: usize,
}

impl Default for SpecCfg {
    fn default() -> Self {
        SpecCfg { draft_len: 4, ngram: 3 }
    }
}

impl SpecCfg {
    /// Reject configurations the drafting loop cannot execute.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.draft_len >= 1, "spec: draft-len must be >= 1");
        ensure!(self.ngram >= 1, "spec: ngram must be >= 1");
        Ok(())
    }

    /// The `MISA_SPEC` environment default: unset, `0`, or unparseable
    /// disables speculation (`None`); `MISA_SPEC=k` enables it with
    /// `draft_len = k` and the default n-gram order. `GenerateCfg` and
    /// `SchedulerCfg` defaults read this, so `MISA_SPEC=4 cargo test`
    /// re-runs the whole suite speculatively — and, because parity is
    /// exact, it must pass identically (a CI job pins that).
    pub fn from_env() -> Option<SpecCfg> {
        match std::env::var("MISA_SPEC").ok()?.parse::<usize>() {
            Ok(k) if k >= 1 => Some(SpecCfg { draft_len: k, ..SpecCfg::default() }),
            _ => None,
        }
    }
}

/// Aggregate drafting counters — `misa bench-serve --json` exports
/// them as `drafted_tokens` / `accepted_tokens` / `acceptance_rate`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens proposed to the verifier.
    pub drafted: u64,
    /// Draft tokens the model verified and accepted.
    pub accepted: u64,
}

impl SpecStats {
    /// `accepted / drafted` (0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Fold one slot-tick's outcome into the totals.
    pub fn record(&mut self, drafted: usize, accepted: usize) {
        self.drafted += drafted as u64;
        self.accepted += accepted as u64;
    }
}

impl crate::obs::MetricSource for SpecStats {
    fn metric_kvs(&self) -> Vec<(String, f64)> {
        vec![
            ("serve.spec.drafted".to_string(), self.drafted as f64),
            ("serve.spec.accepted".to_string(), self.accepted as f64),
            ("serve.spec.acceptance_rate".to_string(), self.acceptance_rate()),
        ]
    }
}

/// Per-slot adaptive draft length: starts at the configured cap, is
/// halved (floor 1) by a tick with zero accepted drafts, grown back by
/// one by a fully accepted tick, and held by partial acceptance —
/// slots whose history predicts well speculate deep, slots that stop
/// predicting back off geometrically instead of burning `k` verify
/// rows per tick.
#[derive(Clone, Copy, Debug)]
pub struct DraftCtl {
    cur: usize,
}

impl DraftCtl {
    /// Start at the configured draft cap.
    pub fn new(cfg: &SpecCfg) -> Self {
        DraftCtl { cur: cfg.draft_len.max(1) }
    }

    /// Draft tokens this slot should attempt next tick.
    pub fn draft_len(&self) -> usize {
        self.cur
    }

    /// Fold one tick's outcome into the back-off state.
    pub fn record(&mut self, cfg: &SpecCfg, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return; // nothing proposed: no evidence either way
        }
        if accepted == drafted {
            self.cur = (self.cur + 1).min(cfg.draft_len.max(1));
        } else if accepted == 0 {
            self.cur = (self.cur / 2).max(1);
        }
    }
}

/// Longest draft a slot may attempt this tick.
///
/// Two caps compose with the adaptive length `ctl_len`:
/// - the verify chunk (`1 + draft`) must not wrap the ring past
///   `capacity` written positions, or the rejected suffix could not be
///   rolled back exactly (`KvCache::truncate` is refused once rolled-
///   back writes clobber retained positions) — a slot at or past its
///   ring capacity simply decodes one token per tick through the same
///   verify path;
/// - a fully accepted tick emits `draft + 1` tokens, which must not
///   exceed the request's remaining token allowance, so speculation
///   never drafts rows the request could not use.
pub fn draft_budget(ctl_len: usize, cache_len: usize, capacity: usize, remaining: usize) -> usize {
    ctl_len
        .min(capacity.saturating_sub(cache_len + 1))
        .min(remaining.saturating_sub(1))
}

/// Prompt-lookup drafting: propose up to `k` tokens by matching the
/// longest suffix n-gram (order `ngram` backing off to 1) of `history`
/// against its own earlier occurrences and replaying what followed the
/// **earliest** one. Returns an empty draft when no suffix recurs —
/// the tick then degrades to a plain one-token decode through the
/// verify path. Proposed tokens come verbatim from `history`, so they
/// are always in-vocabulary.
///
/// The earliest occurrence (not the most recent) is deliberate: on a
/// periodic stream — exactly where self-drafting shines — the most
/// recent match ends right before the suffix itself and leaves almost
/// no recorded continuation to replay, while the earliest match has
/// the whole rest of the history behind it, so the draft fills the
/// full `k` budget. The scan is O(`history.len() * ngram`) per order
/// in the worst case; slot histories here are serving-scale (hundreds
/// of positions), so the proposer costs microseconds against a
/// multi-millisecond forward.
pub fn propose(history: &[i32], ngram: usize, k: usize) -> Vec<i32> {
    let len = history.len();
    if k == 0 || len < 2 {
        return Vec::new();
    }
    for n in (1..=ngram.min(len - 1)).rev() {
        let pat = &history[len - n..];
        // earliest occurrence whose match ends strictly before the
        // suffix itself, so the continuation is recorded history
        for s in 0..len - n {
            if &history[s..s + n] == pat {
                let from = s + n;
                let take = k.min(len - from);
                return history[from..from + take].to_vec();
            }
        }
    }
    Vec::new()
}

/// Assemble one slot's verify chunk: its last sampled token (the final
/// element of `history`, which has not been fed to the model yet)
/// followed by the history-drafted continuation. Returns `(chunk,
/// drafts)`. Shared by the solo generate loop, the scheduler's batched
/// tick, and the parity tests, so the `[last, draft...]` layout — which
/// the acceptance walk and the `start + 1 + accepted` rollback length
/// both assume — lives in exactly one place.
pub fn draft_chunk(history: &[i32], ngram: usize, budget: usize) -> (Vec<i32>, Vec<i32>) {
    let last = *history.last().expect("a stream always holds at least one token");
    let drafts = propose(history, ngram, budget);
    let mut chunk = Vec::with_capacity(1 + drafts.len());
    chunk.push(last);
    chunk.extend_from_slice(&drafts);
    (chunk, drafts)
}

/// The acceptance walk over one slot's verify output.
///
/// `rows` is `(drafts.len() + 1) * vocab` stacked logits — row `j` is
/// the model's next-token distribution after consuming the last
/// sampled token and the first `j` draft tokens. Each row is sampled
/// with the slot's own sampler and RNG stream, exactly as the
/// sequential loop would have: row 0's sample is the token sequential
/// decode would emit next; if it equals `drafts[0]`, row 1's context
/// matches the sequential loop's next step, so its sample is the
/// *following* sequential token, and so on by induction. The walk
/// stops at the first sampled token that diverges from its draft (the
/// corrective token) or after sampling the row past the full draft
/// (the bonus token).
///
/// Returns `(emitted, accepted)`: `emitted` are the `accepted + 1`
/// tokens the sequential loop would have produced this tick, and
/// `accepted` (`= emitted.len() - 1`) is how many draft positions —
/// and therefore how many cache positions — survive the rollback.
pub fn accept(
    rows: &[f32],
    vocab: usize,
    drafts: &[i32],
    sampler: &SamplerCfg,
    rng: &mut Rng,
) -> (Vec<i32>, usize) {
    let n_rows = drafts.len() + 1;
    debug_assert_eq!(rows.len(), n_rows * vocab, "verify rows do not match the draft");
    let mut emitted = Vec::with_capacity(n_rows);
    for j in 0..n_rows {
        let x = sample(&rows[j * vocab..(j + 1) * vocab], sampler, rng) as i32;
        emitted.push(x);
        if j >= drafts.len() || x != drafts[j] {
            break;
        }
    }
    let accepted = emitted.len() - 1;
    (emitted, accepted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propose_replays_the_earliest_matching_continuation() {
        // suffix [7, 8] occurred earlier twice; the earliest occurrence
        // (indices 1..3) wins and its full continuation is replayed
        let h = [1, 7, 8, 9, 2, 7, 8, 5, 6, 7, 8];
        assert_eq!(propose(&h, 3, 4), vec![9, 2, 7, 8]);
        assert_eq!(propose(&h, 3, 2), vec![9, 2]);
        assert_eq!(propose(&h, 8, 1), vec![9]);
        // no recurrence → no draft
        assert_eq!(propose(&[1, 2, 3, 4], 3, 4), Vec::<i32>::new());
        // degenerate histories
        assert_eq!(propose(&[5], 3, 4), Vec::<i32>::new());
        assert_eq!(propose(&[], 3, 4), Vec::<i32>::new());
        assert_eq!(propose(&h, 3, 0), Vec::<i32>::new());
    }

    #[test]
    fn propose_prefers_longer_ngrams_and_fills_on_periodic_streams() {
        // suffix ...[2, 9]: the order-2 match (at index 1, continuing
        // with 4) must win over the order-1 matches on [9] alone
        let h = [1, 2, 9, 4, 9, 7, 2, 9];
        assert_eq!(propose(&h, 2, 1), vec![4]);
        assert_eq!(propose(&h, 1, 2), vec![4, 9], "order-1 earliest [9] is index 2");
        // periodic stream: the earliest match leaves a full-budget
        // continuation (a most-recent matcher would see one token)
        let p = [5, 6, 7, 5, 6, 7, 5, 6, 7];
        assert_eq!(propose(&p, 3, 4), vec![5, 6, 7, 5]);
    }

    #[test]
    fn draft_chunk_prepends_the_unfed_last_token() {
        let h = [5, 6, 7, 5, 6, 7, 5, 6, 7];
        let (chunk, drafts) = draft_chunk(&h, 3, 4);
        assert_eq!(drafts, vec![5, 6, 7, 5]);
        assert_eq!(chunk, vec![7, 5, 6, 7, 5]);
        // no recurrence → the chunk degrades to the bare last token
        let (chunk, drafts) = draft_chunk(&[1, 2, 3], 3, 4);
        assert!(drafts.is_empty());
        assert_eq!(chunk, vec![3]);
    }

    #[test]
    fn accept_walks_greedy_rows_against_the_draft() {
        // vocab 4; rows' argmaxes: 2, 1, 3
        let rows = [
            0.0, 0.1, 0.9, 0.2, // argmax 2
            0.0, 0.8, 0.1, 0.2, // argmax 1
            0.1, 0.0, 0.2, 0.9, // argmax 3
        ];
        let greedy = SamplerCfg::greedy();
        let mut rng = Rng::new(1);
        // full acceptance: drafts equal the argmax chain → bonus token
        let (em, acc) = accept(&rows, 4, &[2, 1], &greedy, &mut rng);
        assert_eq!((em, acc), (vec![2, 1, 3], 2));
        // first-draft mismatch: the corrective token is row 0's sample
        let (em, acc) = accept(&rows[..8], 4, &[0], &greedy, &mut rng);
        assert_eq!((em, acc), (vec![2], 0));
        // partial: first draft verifies, second diverges
        let (em, acc) = accept(&rows, 4, &[2, 0], &greedy, &mut rng);
        assert_eq!((em, acc), (vec![2, 1], 1));
        // empty draft: plain decode through the verify path
        let (em, acc) = accept(&rows[..4], 4, &[], &greedy, &mut rng);
        assert_eq!((em, acc), (vec![2], 0));
    }

    #[test]
    fn accept_consumes_the_same_rng_stream_as_sequential_sampling() {
        // sampled (non-greedy) acceptance draws once per emitted token,
        // in row order — exactly the sequential loop's stream
        let rows: Vec<f32> = (0..3)
            .flat_map(|j| (0..5).map(move |i| ((i * 7 + j * 3) % 5) as f32 * 0.3))
            .collect();
        let cfg = SamplerCfg { temperature: 0.9, top_k: 4, top_p: 0.95 };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        // sequential reference: sample row by row while drafts match
        let mut want = Vec::new();
        let drafts = {
            // pre-compute what the stream emits so the draft fully matches
            let mut probe = Rng::new(9);
            (0..2)
                .map(|j| sample(&rows[j * 5..(j + 1) * 5], &cfg, &mut probe) as i32)
                .collect::<Vec<i32>>()
        };
        for j in 0..3 {
            want.push(sample(&rows[j * 5..(j + 1) * 5], &cfg, &mut a) as i32);
            if j < 2 && want[j] != drafts[j] {
                break;
            }
        }
        let (em, acc) = accept(&rows, 5, &drafts, &cfg, &mut b);
        assert_eq!(em, want);
        assert_eq!(acc, em.len() - 1);
        // both RNGs sit at the same stream position afterwards
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    }

    #[test]
    fn draft_budget_respects_ring_and_allowance() {
        // plenty of room: the adaptive length rules
        assert_eq!(draft_budget(4, 10, 64, 20), 4);
        // verify chunk may not wrap: 1 + m <= capacity - cache_len
        assert_eq!(draft_budget(4, 62, 64, 20), 1);
        assert_eq!(draft_budget(4, 63, 64, 20), 0);
        assert_eq!(draft_budget(4, 70, 64, 20), 0, "wrapped slots decode one by one");
        // a fully accepted tick emits m + 1 tokens <= remaining
        assert_eq!(draft_budget(4, 10, 64, 3), 2);
        assert_eq!(draft_budget(4, 10, 64, 1), 0);
    }

    #[test]
    fn draft_ctl_backs_off_and_recovers() {
        let cfg = SpecCfg { draft_len: 8, ngram: 3 };
        let mut ctl = DraftCtl::new(&cfg);
        assert_eq!(ctl.draft_len(), 8);
        ctl.record(&cfg, 8, 0); // zero acceptance: halve
        assert_eq!(ctl.draft_len(), 4);
        ctl.record(&cfg, 4, 0);
        ctl.record(&cfg, 2, 0);
        ctl.record(&cfg, 1, 0);
        assert_eq!(ctl.draft_len(), 1, "floor is 1, never 0");
        ctl.record(&cfg, 1, 1); // full acceptance: grow by one
        assert_eq!(ctl.draft_len(), 2);
        ctl.record(&cfg, 2, 1); // partial: hold
        assert_eq!(ctl.draft_len(), 2);
        ctl.record(&cfg, 0, 0); // no draft: no evidence
        assert_eq!(ctl.draft_len(), 2);
        for _ in 0..10 {
            ctl.record(&cfg, 2, 2);
        }
        assert_eq!(ctl.draft_len(), 8, "growth is capped at the configured draft_len");
    }

    #[test]
    fn spec_stats_and_cfg_validate() {
        let mut st = SpecStats::default();
        assert_eq!(st.acceptance_rate(), 0.0);
        st.record(4, 3);
        st.record(2, 0);
        assert_eq!(st.drafted, 6);
        assert_eq!(st.accepted, 3);
        assert!((st.acceptance_rate() - 0.5).abs() < 1e-12);
        assert!(SpecCfg::default().validate().is_ok());
        assert!(SpecCfg { draft_len: 0, ngram: 3 }.validate().is_err());
        assert!(SpecCfg { draft_len: 4, ngram: 0 }.validate().is_err());
    }
}
