//! Continuous-batching scheduler.
//!
//! Iteration-level scheduling in the vLLM/Orca style: each iteration
//! (1) admits queued requests into free slots while the KV token
//! budget allows, (2) advances prompt prefill — grouped by shared
//! prefix, forking the prompt cache where it matches and running every
//! novel chunk through a single stacked `Session::prefill_batch`
//! forward, capped at [`SchedulerCfg::prefill_chunk`] rows per tick so
//! giant prompts never stall in-flight decode — sampling first tokens
//! (TTFT) as prompts complete, and (3) advances every unfinished slot
//! through a single batched forward: one token per slot via
//! `Session::decode_batch`, or — with [`SchedulerCfg::spec`] —
//! several per slot via speculative drafting and one ragged
//! `Session::verify_step`. Every phase runs one stacked forward per
//! iteration, not one per slot, so batching buys FLOP efficiency
//! rather than just scheduling overhead. Finished requests free their
//! slot and budget immediately, so waiting requests are admitted on
//! the very next iteration — no batch-boundary stalls.
//!
//! Prefix reuse (`SchedulerCfg::prefix_cache`) hangs a
//! [`crate::serve::CacheStore`] off the scheduler: a prompt's first
//! prefill round looks it up, forks the longest stored prefix
//! (copy-on-write, `KvCache::fork_from`) and prefills only the suffix;
//! completed prompts are stored back (COW snapshots) for later
//! admissions. Prompts prefilling in the *same* tick that share a
//! prefix split into waves: the first carrier prefills it, the rest
//! fork it once the carrier completes instead of each re-prefilling
//! it. Reuse never changes what a request computes — forked decode is
//! bit-compatible with cold decode (test-pinned) — only how much of
//! it is recomputed.
//!
//! Speculative decoding (`SchedulerCfg::spec`) needs no second model:
//! each slot drafts from its own token history
//! ([`crate::serve::spec::propose`]), all slots' `[last, draft...]`
//! chunks stack into one ragged `verify_step` forward returning logits
//! at every draft position, and each slot keeps the longest draft
//! prefix its own sampler verifies plus the model's corrective token,
//! rolling rejected K/V back with `KvCache::truncate`. The acceptance
//! walk consumes the same per-request RNG stream sequential decode
//! would, so speculation — greedy *or* sampled — emits bit-identical
//! tokens and only changes how many forwards they cost (test-pinned).
//!
//! Memory accounting is in KV *positions*: a request admitted with
//! prompt length `p` and `max_new` new tokens costs `p + max_new`
//! positions for its lifetime (charged at admission, while its prompt
//! is still prefilling), and the sum of live costs never exceeds
//! [`SchedulerCfg::token_budget`]. Cache misses allocate exactly their
//! cost (a right-sized private ring); cache hits ride the store's
//! fixed ring capacity but share their prefix chunks copy-on-write —
//! either way *physical* per-request residency tracks the logical
//! cost, with the store's own entries bounded separately by its
//! `max_entries × capacity` configuration.
//!
//! Each request samples from its own `Rng::new(request.seed)` stream,
//! so its output is independent of batch composition — a scheduled
//! generation is bitwise-identical to running
//! [`crate::serve::generate()`] alone with the same seed, with or
//! without the prefix cache, chunked prefill, or speculation. The
//! tests pin exactly that.
//!
//! Observability: every request carries a [`crate::obs::Timeline`]
//! (enqueue → admit → prefill done → first token → finish, plus
//! per-token gaps), each scheduler phase runs under a span
//! (`sched_tick` / `admission` / `prefill_rounds` / `decode_tick` /
//! `spec_tick`), and completions feed the `serve.ttft_ms` /
//! `serve.itl_ms` histograms. All of it only reads clocks — the
//! parity invariants above hold verbatim with tracing enabled
//! (test-pinned in `rust/tests/obs.rs`).

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::obs::{self, Latencies, Timeline};
use crate::runtime::{KvCache, Session};
use crate::serve::cache_store::{CacheStats, CacheStore, CacheStoreCfg};
use crate::serve::sampler::{sample, SamplerCfg};
use crate::serve::spec::{self, DraftCtl, SpecCfg, SpecStats};
use crate::util::{MetricsSink, Rng};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`Completion`].
    pub id: u64,
    /// Prompt token ids (must be non-empty and inside the vocab).
    pub prompt: Vec<i32>,
    /// Number of new tokens to produce (generation may stop earlier on
    /// `eos`).
    pub max_new: usize,
    /// Token-selection configuration.
    pub sampler: SamplerCfg,
    /// Seed of this request's sampling stream.
    pub seed: u64,
    /// Optional stop token.
    pub eos: Option<i32>,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its full `max_new` tokens.
    MaxNew,
    /// Emitted its stop token early.
    Eos,
    /// Rejected at admission (e.g. a prompt token outside the model's
    /// vocab — only checkable once the session is known). The request
    /// completes with no tokens instead of erroring the whole run.
    Rejected,
    /// Cancelled by the caller ([`Scheduler::cancel`]) while queued,
    /// prefilling, or decoding. The completion carries the tokens
    /// generated before cancellation — a prefix of what the request
    /// would have produced.
    Cancelled,
}

/// A finished request with its per-request serving metrics.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Prompt length, in tokens.
    pub prompt_len: usize,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Prompt positions served from a forked prompt-cache prefix
    /// instead of being re-prefilled (0 with the cache disabled).
    pub reused_tokens: usize,
    /// Submit-to-first-token latency (includes queue wait), seconds.
    pub ttft_s: f64,
    /// Decode throughput after the first token, tokens/second.
    pub decode_tps: f64,
    /// Inter-token latency samples in milliseconds: one per generated
    /// token after the first. A speculative tick emitting `n` tokens
    /// contributes `n` samples of `gap / n`, so spec on/off produce
    /// comparable distributions (`len == tokens.len() - 1` either
    /// way; empty for rejected requests, and covering only the tokens
    /// actually emitted for cancelled ones).
    pub itl_ms: Vec<f64>,
    /// Why the request finished.
    pub finish: FinishReason,
}

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerCfg {
    /// Maximum concurrently active requests (decode batch width).
    pub max_slots: usize,
    /// Maximum total KV positions resident across all active slots.
    pub token_budget: usize,
    /// Prefix-sharing prompt cache; `None` disables reuse entirely
    /// (every request prefills its full prompt into a private cache).
    pub prefix_cache: Option<CacheStoreCfg>,
    /// Cap on prompt positions prefilled per tick, across all prompts
    /// (`0` = unlimited). With a cap, a giant prompt prefills a chunk
    /// per tick — its partial state carries across ticks — while
    /// already-active slots keep decoding every tick instead of
    /// stalling behind it.
    pub prefill_chunk: usize,
    /// Speculative decoding: slots self-draft from their token history
    /// and verify several tokens per tick in one stacked forward.
    /// Output is identical with or without it (exact parity,
    /// test-pinned); only wall-clock changes. The default honors the
    /// `MISA_SPEC` environment override
    /// ([`crate::serve::spec::SpecCfg::from_env`]).
    pub spec: Option<SpecCfg>,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            max_slots: 8,
            token_budget: 8192,
            prefix_cache: None,
            prefill_chunk: 0,
            spec: SpecCfg::from_env(),
        }
    }
}

/// One active generation stream.
struct Slot {
    req: Request,
    cache: KvCache,
    rng: Rng,
    generated: Vec<i32>,
    /// lifecycle stamps + inter-token gaps (enqueue → finish)
    tl: Timeline,
    /// KV positions charged against the token budget
    /// (`prompt + max_new`, independent of the cache's ring capacity)
    cost: usize,
    /// prompt positions forked from the store instead of prefilled
    reused: usize,
    /// adaptive draft-length controller (speculative decoding only)
    ctl: Option<DraftCtl>,
    /// the proposer's view of the stream (prompt + generated), kept
    /// incrementally so speculative ticks never rebuild it from
    /// scratch; empty when speculation is off
    history: Vec<i32>,
}

impl Slot {
    fn finished(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) = (self.req.eos, self.generated.last()) {
            if last == eos {
                return Some(FinishReason::Eos);
            }
        }
        if self.generated.len() >= self.req.max_new {
            return Some(FinishReason::MaxNew);
        }
        None
    }
}

/// An admitted request whose prompt is still prefilling. Its KV cost
/// is already charged against the token budget; `cache` is created on
/// its first prefill round (the store lookup happens then, so a
/// same-tick carrier can seed the store first).
struct PrefillJob {
    req: Request,
    /// lifecycle stamps, carried from the queue entry
    tl: Timeline,
    cost: usize,
    cache: Option<KvCache>,
    rng: Rng,
    /// prompt positions forked from the store instead of prefilled
    reused: usize,
    /// prompt positions resident so far (starts at `reused`)
    done: usize,
}

/// Longest common prefix of two token sequences.
fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Can this request ride the prompt cache? Only when its whole
/// lifetime (`prompt + max_new` positions) fits the store's ring
/// capacity — a forked cache must never wrap, so reuse changes nothing
/// about the attention windows the request computes.
fn store_eligible(store: &CacheStore, req: &Request) -> bool {
    req.prompt.len() + req.max_new <= store.cfg().capacity
}

fn cache_eligible(store: &Option<CacheStore>, req: &Request) -> bool {
    store.as_ref().is_some_and(|s| store_eligible(s, req))
}

/// Should the not-yet-started job `i` wait for an earlier,
/// still-prefilling prompt to seed the store before it forks? Mirrors
/// the wave rule: defer while an earlier eligible job shares a longer
/// usable prefix than the store currently holds. The front job never
/// defers, so every prefill round makes progress.
fn job_defers(store: &Option<CacheStore>, jobs: &VecDeque<PrefillJob>, i: usize) -> bool {
    let Some(store) = store else { return false };
    let job = &jobs[i];
    if !store_eligible(store, &job.req) {
        return false;
    }
    let pi = &job.req.prompt;
    // a fork never covers the final position (its logits must be
    // computed), so cap usable lengths
    let usable = |l: usize| l.min(pi.len() - 1);
    let store_m = usable(store.peek_match(pi));
    let min_prefix = store.cfg().min_prefix;
    (0..i).any(|j| {
        store_eligible(store, &jobs[j].req)
            && usable(lcp(pi, &jobs[j].req.prompt)) > store_m.max(min_prefix - 1)
    })
}

/// The continuous-batching scheduler. Submit requests, then [`Self::run`]
/// to completion (or step iterations manually with [`Self::tick`]).
pub struct Scheduler {
    cfg: SchedulerCfg,
    queue: VecDeque<(Request, Timeline)>,
    /// admitted, budget-charged, prompt not yet fully resident
    prefilling: VecDeque<PrefillJob>,
    active: Vec<Slot>,
    store: Option<CacheStore>,
    in_flight_tokens: usize,
    /// high-water mark of concurrently active slots (observability)
    peak_active: usize,
    /// aggregate speculative-decoding counters
    spec_totals: SpecStats,
    /// pooled TTFT/ITL samples across completed requests
    latencies: Latencies,
    /// Per-request serving metrics (TTFT, decode tok/s, KV residency,
    /// reused prompt positions), one record per completion.
    pub metrics: MetricsSink,
}

impl Scheduler {
    /// Build a scheduler; `max_slots` is clamped to at least 1 (zero
    /// slots could never admit anything and would make [`Self::run`]
    /// spin forever on a non-empty queue), and degenerate speculative
    /// limits are clamped to 1 for the same reason.
    pub fn new(mut cfg: SchedulerCfg) -> Self {
        cfg.max_slots = cfg.max_slots.max(1);
        if let Some(s) = &mut cfg.spec {
            s.draft_len = s.draft_len.max(1);
            s.ngram = s.ngram.max(1);
        }
        Scheduler {
            store: cfg.prefix_cache.map(CacheStore::new),
            cfg,
            queue: VecDeque::new(),
            prefilling: VecDeque::new(),
            active: Vec::new(),
            in_flight_tokens: 0,
            peak_active: 0,
            spec_totals: SpecStats::default(),
            latencies: Latencies::default(),
            metrics: MetricsSink::memory(),
        }
    }

    /// Enqueue a request. Rejects requests that could never be admitted
    /// (cost above the whole token budget) instead of deadlocking.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        ensure!(!req.prompt.is_empty(), "request {}: empty prompt", req.id);
        ensure!(req.max_new > 0, "request {}: max_new must be > 0", req.id);
        req.sampler.validate()?;
        let cost = req.prompt.len() + req.max_new;
        ensure!(
            cost <= self.cfg.token_budget,
            "request {}: needs {cost} KV positions but the token budget is {}",
            req.id,
            self.cfg.token_budget
        );
        self.queue.push_back((req, Timeline::start()));
        Ok(())
    }

    /// Cancel an outstanding request wherever it currently lives —
    /// queued, mid-prefill, or actively decoding. Returns a
    /// [`FinishReason::Cancelled`] completion carrying whatever tokens
    /// were generated so far (always a prefix of what the request
    /// would have produced), or `None` when the id is unknown or
    /// already completed. Admission charges are released immediately —
    /// the freed budget and slot admit the next queued request on the
    /// very next [`Self::tick`] — and the cancelled request's KV ring
    /// is dropped here, so measured residency falls at the next tick's
    /// gauge update. Cancellation never perturbs the survivors: each
    /// slot samples from its own seed stream, so the remaining
    /// requests' output is bit-identical to a run that never admitted
    /// the cancelled one (test-pinned).
    pub fn cancel(&mut self, id: u64) -> Option<Completion> {
        if let Some(i) = self.queue.iter().position(|(r, _)| r.id == id) {
            let (req, tl) = self.queue.remove(i).expect("index from position");
            return Some(self.cancelled(req, tl, 0, Vec::new()));
        }
        if let Some(i) = self.prefilling.iter().position(|j| j.req.id == id) {
            let job = self.prefilling.remove(i).expect("index from position");
            self.in_flight_tokens -= job.cost;
            return Some(self.cancelled(job.req, job.tl, job.reused, Vec::new()));
        }
        if let Some(i) = self.active.iter().position(|s| s.req.id == id) {
            let slot = self.active.remove(i);
            self.in_flight_tokens -= slot.cost;
            return Some(self.cancelled(slot.req, slot.tl, slot.reused, slot.generated));
        }
        None
    }

    /// Requests still queued, prefilling, or actively decoding.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.prefilling.len() + self.active.len()
    }

    /// High-water mark of concurrently active slots.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// KV positions currently charged against the token budget.
    pub fn in_flight_tokens(&self) -> usize {
        self.in_flight_tokens
    }

    /// Prompt-cache reuse counters (`None` when the prefix cache is
    /// disabled).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.store.as_ref().map(|s| s.stats())
    }

    /// Aggregate speculative-decoding counters (`None` when
    /// speculation is disabled).
    pub fn spec_stats(&self) -> Option<SpecStats> {
        self.cfg.spec.map(|_| self.spec_totals)
    }

    /// Pooled TTFT/ITL samples across completed requests (exact
    /// percentiles via [`Latencies::ttft`] / [`Latencies::itl`]).
    pub fn latencies(&self) -> &Latencies {
        &self.latencies
    }

    /// Publish the scheduler's counters into the global metrics
    /// registry (gauges under `serve.*`), including the cache and
    /// speculation stats when those features are on — one call makes
    /// the whole serving state visible to the Prometheus-style dump.
    pub fn publish_metrics(&self) {
        obs::metrics::gauge_set("serve.peak_active", self.peak_active as f64);
        obs::metrics::gauge_set("serve.in_flight_tokens", self.in_flight_tokens as f64);
        obs::metrics::gauge_set("serve.pending", self.pending() as f64);
        if let Some(stats) = self.cache_stats() {
            obs::metrics::publish(&stats);
        }
        if let Some(stats) = self.spec_stats() {
            obs::metrics::publish(&stats);
        }
    }

    /// Physical bytes currently resident across every live KV ring —
    /// active slots, partially prefilled jobs, and prompt-store entries
    /// — with copy-on-write chunk sharing deduplicated
    /// ([`crate::runtime::kv_resident_bytes`]). This is *measured*
    /// residency, not the analytic `bytes_for × peak_active` upper
    /// bound: with prefix sharing it is typically far smaller.
    pub fn kv_resident_bytes(&self) -> u64 {
        let slots = self.active.iter().map(|s| &s.cache);
        let jobs = self.prefilling.iter().filter_map(|j| j.cache.as_ref());
        let store = self.store.iter().flat_map(|s| s.resident_caches());
        crate::runtime::kv_resident_bytes(slots.chain(jobs).chain(store))
    }

    /// Record the current measured residency into the `serve.*` gauge
    /// and the byte-accounting peak tracker, once per tick.
    fn record_kv_residency(&self) {
        let bytes = self.kv_resident_bytes();
        obs::memory::set_current(obs::memory::MemCategory::KvCache, bytes);
        obs::metrics::gauge_set("serve.kv_resident_bytes", bytes as f64);
    }

    /// One scheduling iteration: admit queued requests, advance prompt
    /// prefill (up to `prefill_chunk` rows), advance every active slot
    /// by at least one decode step, retire finished requests. Returns
    /// the requests that completed during this iteration.
    pub fn tick(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let _sp = crate::span!("sched_tick", "serve");
        crate::obs::flight::record(
            "sched",
            "tick",
            self.pending() as u64,
            self.in_flight_tokens as u64,
        );
        let mut done = Vec::new();
        let vocab = sess.spec.config.vocab;

        // admission: pop every request the free slots and the budget
        // can take this iteration. FIFO — a too-large head-of-queue
        // request waits rather than being bypassed, keeping completion
        // order predictable. Admitted requests charge their full KV
        // cost immediately and enter the prefill pipeline.
        let _adm = crate::span!("admission", "serve");
        while self.active.len() + self.prefilling.len() < self.cfg.max_slots {
            let Some((req, _)) = self.queue.front() else { break };
            let cost = req.prompt.len() + req.max_new;
            if self.in_flight_tokens + cost > self.cfg.token_budget {
                break;
            }
            let (req, mut tl) = self.queue.pop_front().unwrap();
            // token range is only checkable against a concrete model;
            // a bad prompt rejects this request, not the whole run
            if req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
                let ttft_s = tl.enqueued.elapsed().as_secs_f64();
                obs::metrics::counter_add("serve.rejected", 1);
                crate::obs::flight::record("sched", "reject", req.id, req.prompt.len() as u64);
                self.metrics.log(
                    req.id,
                    &[("ttft_ms", ttft_s * 1e3), ("new_tokens", 0.0), ("rejected", 1.0)],
                );
                done.push(Completion {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    reused_tokens: 0,
                    ttft_s,
                    decode_tps: 0.0,
                    itl_ms: Vec::new(),
                    finish: FinishReason::Rejected,
                });
                continue;
            }
            tl.admit();
            crate::obs::flight::record("sched", "admit", req.id, cost as u64);
            self.in_flight_tokens += cost;
            self.prefilling.push_back(PrefillJob {
                rng: Rng::new(req.seed),
                tl,
                cost,
                cache: None,
                reused: 0,
                done: 0,
                req,
            });
        }
        drop(_adm);

        self.prefill_rounds(sess)?;
        self.decode_phase(sess, vocab)?;
        // measure physical KV residency at the tick's high-water point
        // (before retirement frees finishing slots)
        self.record_kv_residency();

        // retire finished slots, freeing budget for the next iteration
        let mut i = 0;
        while i < self.active.len() {
            if let Some(finish) = self.active[i].finished() {
                let slot = self.active.swap_remove(i);
                self.in_flight_tokens -= slot.cost;
                done.push(self.complete(slot, finish));
            } else {
                i += 1;
            }
        }
        Ok(done)
    }

    /// The prefill engine: rounds of shared-prefix waves under the
    /// per-tick row cap. Each round selects the runnable jobs (FIFO; a
    /// job that has not started defers while an earlier prompt would
    /// seed a longer store prefix than the store holds), starts new
    /// ones (store lookup → fork, or a right-sized private ring), and
    /// runs one stacked ragged `prefill_batch` over every member's
    /// next chunk. Prompts that complete sample their first token,
    /// enter the store, and activate; partial prompts keep their state
    /// in [`Scheduler::prefilling`] across ticks.
    fn prefill_rounds(&mut self, sess: &Session) -> Result<()> {
        let _sp = crate::span!("prefill_rounds", "serve");
        let mut rows_left =
            if self.cfg.prefill_chunk == 0 { usize::MAX } else { self.cfg.prefill_chunk };
        while rows_left > 0 && !self.prefilling.is_empty() {
            // this round's wave (indices into `prefilling`, ascending)
            let mut wave: Vec<usize> = Vec::new();
            for i in 0..self.prefilling.len() {
                let job = &self.prefilling[i];
                if job.cache.is_none() && job_defers(&self.store, &self.prefilling, i) {
                    continue;
                }
                wave.push(i);
            }
            // the front job never defers, so the wave is never empty

            // start new wave members: fork the longest stored prefix
            // when it pays off (the fork rides the store's ring layout,
            // sharing its prefix chunks), else a right-sized private
            // ring — a miss never over-allocates, so physical KV
            // residency stays bounded by the token budget; the store
            // converts layouts itself on insert-back
            for &i in &wave {
                if self.prefilling[i].cache.is_some() {
                    continue;
                }
                let hit = if cache_eligible(&self.store, &self.prefilling[i].req) {
                    let store = self.store.as_mut().expect("eligible implies store");
                    store.lookup(&self.prefilling[i].req.prompt)
                } else {
                    None
                };
                let job = &mut self.prefilling[i];
                let (cache, reused) = match hit {
                    Some((cache, m)) => (cache, m),
                    None => (sess.kv_cache(job.cost)?, 0),
                };
                job.cache = Some(cache);
                job.reused = reused;
                job.done = reused;
            }

            // row assignment under the per-tick cap
            let mut members: Vec<(usize, usize)> = Vec::new(); // (job, rows)
            for &i in &wave {
                if rows_left == 0 {
                    break;
                }
                let job = &self.prefilling[i];
                let take = (job.req.prompt.len() - job.done).min(rows_left);
                rows_left -= take;
                members.push((i, take));
            }
            if members.is_empty() {
                break; // cap exhausted before this round started
            }

            // one stacked ragged forward prefills every member's chunk
            let rows = {
                let mut chunks: Vec<&[i32]> = Vec::with_capacity(members.len());
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(members.len());
                let mut next = members.iter().peekable();
                for (i, job) in self.prefilling.iter_mut().enumerate() {
                    if next.peek().is_some_and(|&&(mi, _)| mi == i) {
                        let &(_, take) = next.next().unwrap();
                        let PrefillJob { req, cache, done, .. } = job;
                        chunks.push(&req.prompt[*done..*done + take]);
                        caches.push(cache.as_mut().expect("wave member started"));
                    }
                }
                sess.prefill_batch(&chunks, &mut caches)?
            };

            // advance chunk state; a chunk's returned logits are only
            // meaningful when it finished the prompt (mid-prompt rows
            // never feed sampling)
            let mut finished: Vec<(usize, Vec<f32>)> = Vec::new();
            for (&(i, take), logits) in members.iter().zip(rows) {
                let job = &mut self.prefilling[i];
                job.done += take;
                if job.done == job.req.prompt.len() {
                    finished.push((i, logits));
                }
            }
            // activate completed prompts in FIFO order: sample the
            // first token (TTFT), store the freshly resident prompt
            // back (COW snapshot), join the decode batch
            let mut acts: Vec<(PrefillJob, Vec<f32>)> = Vec::new();
            for (i, logits) in finished.into_iter().rev() {
                let job = self.prefilling.remove(i).expect("completed index in range");
                acts.push((job, logits));
            }
            acts.reverse();
            for (job, logits) in acts {
                let PrefillJob { req, mut tl, cost, cache, rng, reused, .. } = job;
                tl.prefill_done();
                let spec_on = self.cfg.spec.is_some();
                let mut slot = Slot {
                    cache: cache.expect("completed job has a cache"),
                    rng,
                    generated: Vec::with_capacity(req.max_new),
                    tl,
                    cost,
                    reused,
                    ctl: self.cfg.spec.map(|s| DraftCtl::new(&s)),
                    history: if spec_on { req.prompt.clone() } else { Vec::new() },
                    req,
                };
                let first = sample(&logits, &slot.req.sampler, &mut slot.rng) as i32;
                slot.generated.push(first);
                if spec_on {
                    slot.history.push(first);
                }
                slot.tl.mark_first_token();
                // same gate as lookup: requests that can never hit
                // (lifetime beyond the store ring) also never insert,
                // so they cannot thrash the LRU or pay the copy
                if cache_eligible(&self.store, &slot.req) {
                    let store = self.store.as_mut().expect("eligible implies store");
                    store.insert(&slot.req.prompt, &slot.cache)?;
                }
                self.active.push(slot);
                self.peak_active = self.peak_active.max(self.active.len());
            }
        }
        Ok(())
    }

    /// The decode phase: one batched forward advances every unfinished
    /// slot — each layer runs one GEMM per projection across the whole
    /// batch instead of one per slot (attention stays per-slot over
    /// each ring cache). Without speculation every slot gains exactly
    /// one token (`decode_batch`); with it, each slot drafts from its
    /// own history, all chunks verify in one ragged `verify_step`, and
    /// each slot keeps its verified prefix plus the model's corrective
    /// token, rolling rejected K/V back. Sampling always draws from
    /// each slot's own seed stream, so batching — and speculation —
    /// changes wall-clock, never tokens. The unfinished-slot set is
    /// computed ONCE as an (ascending) index list so logits row i is
    /// structurally — not coincidentally — aligned with slot
    /// `batch[i]` in every pass.
    fn decode_phase(&mut self, sess: &Session, vocab: usize) -> Result<()> {
        let batch: Vec<usize> = (0..self.active.len())
            .filter(|&i| self.active[i].finished().is_none())
            .collect();
        if batch.is_empty() {
            return Ok(());
        }
        let Some(scfg) = self.cfg.spec else {
            let _sp = crate::span!("decode_tick", "serve");
            let tokens: Vec<i32> = batch
                .iter()
                .map(|&i| *self.active[i].generated.last().expect("prefill seeded a token"))
                .collect();
            let positions: Vec<usize> =
                batch.iter().map(|&i| self.active[i].cache.len()).collect();
            let logits = {
                // `batch` is ascending, so this filter yields caches in
                // exactly `batch` order
                let mut caches: Vec<&mut KvCache> = self
                    .active
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| batch.binary_search(i).is_ok())
                    .map(|(_, s)| &mut s.cache)
                    .collect();
                sess.decode_batch(&tokens, &positions, &mut caches)?
            };
            for (row, &i) in logits.iter().zip(&batch) {
                let slot = &mut self.active[i];
                let next = sample(row, &slot.req.sampler, &mut slot.rng) as i32;
                slot.generated.push(next);
                slot.tl.emit(1);
            }
            return Ok(());
        };

        // speculative tick: draft per slot, verify all slots' chunks in
        // one ragged stacked forward, accept + roll back per slot
        let _sp = crate::span!("spec_tick", "serve");
        let mut drafts: Vec<Vec<i32>> = Vec::with_capacity(batch.len());
        let mut chunk_buf: Vec<Vec<i32>> = Vec::with_capacity(batch.len());
        for &i in &batch {
            let slot = &self.active[i];
            let remaining = slot.req.max_new - slot.generated.len();
            let ctl = slot.ctl.as_ref().expect("spec slots carry a controller");
            let budget = spec::draft_budget(
                ctl.draft_len(),
                slot.cache.len(),
                slot.cache.capacity(),
                remaining,
            );
            let (chunk, d) = spec::draft_chunk(&slot.history, scfg.ngram, budget);
            chunk_buf.push(chunk);
            drafts.push(d);
        }
        let positions: Vec<usize> =
            batch.iter().map(|&i| self.active[i].cache.len()).collect();
        let rows = {
            let chunks: Vec<&[i32]> = chunk_buf.iter().map(|c| c.as_slice()).collect();
            let mut caches: Vec<&mut KvCache> = self
                .active
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| batch.binary_search(i).is_ok())
                .map(|(_, s)| &mut s.cache)
                .collect();
            sess.verify_step(&chunks, &positions, &mut caches)?
        };
        for (bi, (row, &i)) in rows.iter().zip(&batch).enumerate() {
            let slot = &mut self.active[i];
            let (emitted, accepted) =
                spec::accept(row, vocab, &drafts[bi], &slot.req.sampler, &mut slot.rng);
            self.spec_totals.record(drafts[bi].len(), accepted);
            slot.ctl
                .as_mut()
                .expect("spec slots carry a controller")
                .record(&scfg, drafts[bi].len(), accepted);
            // emit up to the slot's stop conditions: the budget already
            // guarantees max_new is never overshot, and an early eos
            // simply discards the rest of the verified tail
            let mut pushed = 0usize;
            for &x in &emitted {
                slot.generated.push(x);
                slot.history.push(x);
                pushed += 1;
                if slot.finished().is_some() {
                    break;
                }
            }
            slot.tl.emit(pushed);
            // the verified-correct prefix stays resident (`last` plus
            // the accepted drafts); the corrective/bonus token is fed
            // next tick
            slot.cache.truncate(positions[bi] + 1 + accepted)?;
        }
        Ok(())
    }

    fn complete(&mut self, mut slot: Slot, finish: FinishReason) -> Completion {
        slot.tl.finish();
        debug_assert!(
            slot.tl.validate().is_ok(),
            "timeline ordering violated: {:?}",
            slot.tl.validate()
        );
        let now = slot.tl.finished.expect("finish() just stamped");
        let first = slot.tl.first_token.unwrap_or(now);
        let ttft_s = first.saturating_duration_since(slot.tl.enqueued).as_secs_f64();
        let decoded = slot.generated.len().saturating_sub(1);
        let decode_s = now.saturating_duration_since(first).as_secs_f64();
        let decode_tps = if decode_s > 0.0 { decoded as f64 / decode_s } else { 0.0 };
        // bytes for the *charged* positions: a forked cache rides the
        // store's (larger) ring but shares its prefix chunks, so the
        // cost-based figure is the honest per-request residency
        let kv_bytes = 2
            * slot.cache.n_layers()
            * slot.cost
            * slot.cache.kv_dim()
            * std::mem::size_of::<f32>();
        self.metrics.log(
            slot.req.id,
            &[
                ("ttft_ms", ttft_s * 1e3),
                ("decode_tps", decode_tps),
                ("new_tokens", slot.generated.len() as f64),
                ("reused_tokens", slot.reused as f64),
                ("kv_positions", slot.cost as f64),
                ("kv_bytes", kv_bytes as f64),
            ],
        );
        // pool the raw samples (exact percentiles for bench-serve) and
        // feed the global histograms (Prometheus-style dump)
        self.latencies.absorb(slot.tl.ttft_ms(), &slot.tl.itl_ms);
        obs::metrics::observe("serve.ttft_ms", ttft_s * 1e3);
        for &g in &slot.tl.itl_ms {
            obs::metrics::observe("serve.itl_ms", g);
        }
        obs::metrics::counter_add("serve.completions", 1);
        obs::metrics::counter_add("serve.tokens_out", slot.generated.len() as u64);
        crate::obs::flight::record("sched", "complete", slot.req.id, slot.generated.len() as u64);
        Completion {
            id: slot.req.id,
            prompt_len: slot.req.prompt.len(),
            tokens: slot.generated,
            reused_tokens: slot.reused,
            ttft_s,
            decode_tps,
            itl_ms: slot.tl.itl_ms,
            finish,
        }
    }

    /// Build a cancellation completion: terminate the timeline with
    /// its dedicated [`Timeline::cancel`] stamp (validated against the
    /// same ordering invariants as a completed lifecycle) and log a
    /// metrics record. The caller has already released the budget
    /// charge; dropping the request's state frees its KV ring.
    /// Cancelled requests are deliberately *not* pooled into the
    /// TTFT/ITL latency samples — an operator-aborted request would
    /// skew the serving percentiles the bench reports — and their
    /// reported TTFT is the *real* first-token latency when one was
    /// reached, else 0.0 (never the cancel instant masquerading as a
    /// first token).
    fn cancelled(
        &mut self,
        req: Request,
        mut tl: Timeline,
        reused: usize,
        tokens: Vec<i32>,
    ) -> Completion {
        tl.cancel();
        debug_assert!(
            tl.validate().is_ok(),
            "cancelled timeline ordering violated: {:?}",
            tl.validate()
        );
        let ttft_s = tl.ttft_ms().map_or(0.0, |ms| ms / 1e3);
        obs::metrics::counter_add("serve.cancellations", 1);
        crate::obs::flight::record("sched", "cancel", req.id, tokens.len() as u64);
        self.metrics.log(
            req.id,
            &[
                ("ttft_ms", ttft_s * 1e3),
                ("new_tokens", tokens.len() as f64),
                ("cancelled", 1.0),
            ],
        );
        Completion {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens,
            reused_tokens: reused,
            ttft_s,
            decode_tps: 0.0,
            itl_ms: tl.itl_ms,
            finish: FinishReason::Cancelled,
        }
    }

    /// Drive the queue to empty; returns completions in finish order.
    pub fn run(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick(sess)?);
        }
        crate::obs::flight::record("sched", "drain", out.len() as u64, 0);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Session};
    use crate::serve::generate::{generate, GenerateCfg};

    fn tiny_session() -> Session {
        let mut eng = Engine::host();
        Session::create(&mut eng, "tiny", 0).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampler: SamplerCfg { temperature: 0.7, top_k: 16, top_p: 0.9 },
            seed: 1000 + id,
            eos: None,
        }
    }

    fn solo(sess: &Session, r: &Request) -> Vec<i32> {
        generate(
            sess,
            &r.prompt,
            &GenerateCfg {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
                eos: r.eos,
                ..GenerateCfg::default()
            },
        )
        .unwrap()
        .tokens
    }

    #[test]
    fn all_requests_complete_with_metrics() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 3,
            token_budget: 256,
            ..SchedulerCfg::default()
        });
        for i in 0..5 {
            sched.submit(req(i, vec![1, 10 + i as i32], 4 + i as usize)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 5);
        assert_eq!(sched.in_flight_tokens(), 0);
        assert!(sched.peak_active() >= 2, "should overlap: {}", sched.peak_active());
        for c in &done {
            assert_eq!(c.tokens.len(), 4 + c.id as usize);
            assert_eq!(c.finish, FinishReason::MaxNew);
            assert!(c.ttft_s >= 0.0);
            assert_eq!(c.reused_tokens, 0, "cache disabled: nothing to reuse");
            assert_eq!(
                c.itl_ms.len(),
                c.tokens.len() - 1,
                "one ITL sample per token after the first"
            );
            assert!(c.itl_ms.iter().all(|&g| g >= 0.0));
        }
        // pooled latency samples match the per-completion ones
        assert_eq!(sched.latencies().ttft_ms.len(), 5);
        let itl_total: usize = done.iter().map(|c| c.itl_ms.len()).sum();
        assert_eq!(sched.latencies().itl_ms.len(), itl_total);
        assert!(sched.latencies().ttft().p99 >= sched.latencies().ttft().p50);
        // one metrics record per request
        assert_eq!(sched.metrics.history.len(), 5);
        assert_eq!(sched.metrics.series("ttft_ms").len(), 5);
        assert!(sched.cache_stats().is_none());
    }

    #[test]
    fn token_budget_serializes_admission() {
        let sess = tiny_session();
        // each request costs 2 + 6 = 8 positions; budget 8 → one at a time
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 8,
            ..SchedulerCfg::default()
        });
        for i in 0..3 {
            sched.submit(req(i, vec![1, 5], 6)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(sched.peak_active(), 1, "budget must prevent overlap");
    }

    #[test]
    fn oversized_request_is_rejected_up_front() {
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 16,
            ..SchedulerCfg::default()
        });
        let err = sched.submit(req(0, vec![1; 10], 10)).unwrap_err();
        assert!(format!("{err:#}").contains("token budget"), "{err:#}");
        assert!(sched.submit(req(1, vec![1; 10], 6)).is_ok());
    }

    #[test]
    fn out_of_vocab_prompt_rejects_request_not_run() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 64,
            ..SchedulerCfg::default()
        });
        sched.submit(req(0, vec![1, 5], 4)).unwrap();
        sched.submit(req(1, vec![1, 999], 4)).unwrap(); // 999 >= vocab 256
        sched.submit(req(2, vec![1, 6], 4)).unwrap();
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3, "good requests must survive a bad one");
        done.sort_by_key(|c| c.id);
        assert_eq!(done[1].finish, FinishReason::Rejected);
        assert!(done[1].tokens.is_empty());
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[2].tokens.len(), 4);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    #[test]
    fn cancel_releases_budget_and_admits_waiters() {
        let sess = tiny_session();
        // each request costs 2 + 6 = 8 positions; budget 8 → one at a time
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 8,
            ..SchedulerCfg::default()
        });
        sched.submit(req(0, vec![1, 5], 6)).unwrap();
        sched.submit(req(1, vec![1, 6], 6)).unwrap();
        sched.tick(&sess).unwrap();
        assert_eq!(sched.in_flight_tokens(), 8, "only request 0 fits the budget");
        // cancel the active request: budget frees immediately, the
        // waiter is admitted on the very next tick
        let c = sched.cancel(0).expect("request 0 is active");
        assert_eq!(c.finish, FinishReason::Cancelled);
        assert!(!c.tokens.is_empty(), "one tick generated at least the first token");
        assert_eq!(sched.in_flight_tokens(), 0);
        sched.tick(&sess).unwrap();
        assert_eq!(sched.in_flight_tokens(), 8, "the waiter took the freed budget");
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].finish, FinishReason::MaxNew);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    #[test]
    fn cancel_covers_every_lifecycle_stage() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 1,
            token_budget: 64,
            prefill_chunk: 2, // 6-token prompts take several ticks
            spec: None,
            ..SchedulerCfg::default()
        });
        // queued (never admitted): no budget was charged
        sched.submit(req(0, vec![1, 2, 3, 4, 5, 6], 4)).unwrap();
        sched.submit(req(1, vec![1, 2, 3, 4, 5, 7], 4)).unwrap();
        let c = sched.cancel(1).expect("request 1 is still queued");
        assert_eq!((c.finish, c.tokens.len()), (FinishReason::Cancelled, 0));
        assert_eq!(sched.in_flight_tokens(), 0);
        // mid-prefill: one tick prefills 2 of 6 prompt rows
        sched.tick(&sess).unwrap();
        assert_eq!(sched.pending(), 1, "request 0 is mid-prefill");
        let c = sched.cancel(0).expect("request 0 is prefilling");
        assert_eq!((c.finish, c.tokens.len()), (FinishReason::Cancelled, 0));
        assert_eq!(sched.in_flight_tokens(), 0);
        assert_eq!(sched.pending(), 0);
        // unknown / already-cancelled ids are None, state is untouched
        assert!(sched.cancel(0).is_none());
        assert!(sched.cancel(99).is_none());
        assert_eq!(sched.metrics.series("cancelled").len(), 2);
    }

    #[test]
    fn zero_slots_is_clamped_not_a_hang() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 0,
            token_budget: 64,
            ..SchedulerCfg::default()
        });
        sched.submit(req(0, vec![1, 2], 3)).unwrap();
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(sched.peak_active(), 1);
    }

    #[test]
    fn scheduled_output_matches_solo_generation() {
        let sess = tiny_session();
        let reqs: Vec<Request> = (0..4)
            .map(|i| req(i, vec![1, 3 + i as i32, 20], 6))
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 64,
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {} diverged from solo generation", r.id
            );
        }
    }

    /// Prefix reuse must change wall-clock, never tokens — every
    /// scheduled output still equals solo generation, while the store
    /// records real hits on the shared system prompt.
    #[test]
    fn prefix_cache_preserves_solo_parity_and_reuses_tokens() {
        let sess = tiny_session();
        let shared: Vec<i32> = vec![1, 7, 8, 9, 10, 11, 12, 13]; // 8-token system prompt
        let reqs: Vec<Request> = (0..4)
            .map(|i| {
                let mut p = shared.clone();
                p.extend([30 + i as i32, 40 + i as i32]);
                req(i, p, 5)
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 256,
            prefix_cache: Some(CacheStoreCfg {
                capacity: 64,
                max_entries: 8,
                min_prefix: 4,
            }),
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 4);
        done.sort_by_key(|c| c.id);
        let mut total_reused = 0usize;
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {}: prefix reuse changed the generated tokens", r.id
            );
            total_reused += c.reused_tokens;
        }
        let stats = sched.cache_stats().unwrap();
        assert!(stats.hits >= 3, "later requests must fork the shared prefix: {stats:?}");
        assert!(
            stats.reused_tokens >= 3 * shared.len() as u64,
            "each hit reuses at least the shared prompt: {stats:?}"
        );
        assert_eq!(stats.reused_tokens, total_reused as u64);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    /// Two same-tick admissions sharing a prefix split into waves: the
    /// carrier prefills it, the second forks it from the store in the
    /// same tick — no same-batch double prefill.
    #[test]
    fn same_tick_admissions_share_a_prefix_through_waves() {
        let sess = tiny_session();
        let shared = vec![1, 21, 22, 23, 24, 25];
        let mut a = shared.clone();
        a.push(31);
        let mut b = shared.clone();
        b.push(32);
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 256,
            prefix_cache: Some(CacheStoreCfg {
                capacity: 32,
                max_entries: 8,
                min_prefix: 2,
            }),
            ..SchedulerCfg::default()
        });
        sched.submit(req(0, a, 3)).unwrap();
        sched.submit(req(1, b, 3)).unwrap();
        // both admitted in the very first tick
        sched.tick(&sess).unwrap();
        let stats = sched.cache_stats().unwrap();
        assert_eq!(stats.hits, 1, "the deferred request must fork, not re-prefill");
        assert_eq!(stats.reused_tokens, shared.len() as u64);
        assert_eq!(sched.peak_active(), 2);
    }

    /// Chunked prefill (`prefill_chunk`) caps prompt rows per tick but
    /// must not change a single generated token.
    #[test]
    fn chunked_prefill_matches_solo_generation() {
        let sess = tiny_session();
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                let p: Vec<i32> = std::iter::once(1)
                    .chain((0..9).map(|j| 30 + (i * 9 + j) as i32))
                    .collect();
                req(i, p, 5)
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 3,
            token_budget: 256,
            prefill_chunk: 4, // 10-token prompts span three ticks
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3);
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {}: chunked prefill changed the generated tokens", r.id
            );
        }
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    /// A giant prompt behind `prefill_chunk` spans several ticks while
    /// an already-active request keeps decoding every tick — chunking
    /// exists precisely so prefill cannot stall in-flight decode.
    #[test]
    fn chunked_prefill_spans_ticks_without_stalling_decode() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 256,
            prefill_chunk: 4,
            spec: None, // pin per-tick decode progress to exactly one token
            ..SchedulerCfg::default()
        });
        // short request first: fully prefilled + first token + one
        // decode in tick 1, finishes (3 tokens) during tick 2
        sched.submit(req(0, vec![1, 6], 3)).unwrap();
        let done = sched.tick(&sess).unwrap();
        assert!(done.is_empty());
        // giant prompt: 11 tokens → rounds of 4/4/3 across ticks 2-4
        let giant = req(1, std::iter::once(1).chain(50..60).collect(), 2);
        sched.submit(giant.clone()).unwrap();
        let done2 = sched.tick(&sess).unwrap();
        assert_eq!(done2.len(), 1, "the short request must finish while the giant prefills");
        assert_eq!(done2[0].id, 0);
        assert_eq!(done2[0].tokens.len(), 3);
        assert_eq!(sched.pending(), 1, "the giant prompt is still prefilling");
        let done3 = sched.tick(&sess).unwrap();
        assert!(done3.is_empty(), "tick 3 is still prefill-only for the giant");
        // tick 4 finishes prefill (3 rows) + first token + one decode;
        // with max_new = 2 the request completes in the same tick
        let done4 = sched.tick(&sess).unwrap();
        assert_eq!(done4.len(), 1);
        assert_eq!(done4[0].tokens, solo(&sess, &giant));
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    /// Tentpole: speculative decoding must change wall-clock, never
    /// tokens — scheduled output with `spec` on equals solo generation
    /// (which here also verifies scheduler-vs-solo with speculation on
    /// both sides), and the aggregate counters stay consistent.
    #[test]
    fn spec_scheduler_matches_solo_generation() {
        let sess = tiny_session();
        // repeated-structure prompts so the proposer has material
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                let t = 40 + i as i32;
                req(i, vec![1, t, t + 1, t, t + 1, t], 8)
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 3,
            token_budget: 256,
            spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3);
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(c.tokens.len(), r.max_new);
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {}: speculation changed the generated tokens", r.id
            );
        }
        // counters stay consistent (whether this model's sampled
        // suffixes recur enough to draft is its business — guaranteed
        // drafting/acceptance is pinned by the fixed-point test below)
        let st = sched.spec_stats().unwrap();
        assert!(st.accepted <= st.drafted);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    /// Deterministic acceptance: all-zero parameters make greedy decode
    /// a fixed point (argmax 0 forever), so the n-gram drafts verify
    /// fully and the acceptance rate is exactly 1.
    #[test]
    fn spec_scheduler_accepts_fully_on_a_fixed_point_stream() {
        let mut eng = Engine::host();
        let spec_m = eng.manifest.model("tiny").unwrap().clone();
        let zeros: Vec<Vec<f32>> =
            spec_m.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let sess = Session::with_params(&mut eng, spec_m, zeros).unwrap();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 256,
            spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
            ..SchedulerCfg::default()
        });
        for i in 0..2u64 {
            sched
                .submit(Request {
                    id: i,
                    prompt: vec![1, 0, 0],
                    max_new: 12,
                    sampler: SamplerCfg::greedy(),
                    seed: i,
                    eos: None,
                })
                .unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 2);
        for c in &done {
            assert_eq!(c.tokens, vec![0; 12]);
        }
        let st = sched.spec_stats().unwrap();
        assert!(st.drafted > 0);
        assert_eq!(st.accepted, st.drafted, "a fixed point verifies every draft");
        assert!((st.acceptance_rate() - 1.0).abs() < 1e-12);
    }
}
