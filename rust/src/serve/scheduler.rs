//! Continuous-batching scheduler.
//!
//! Iteration-level scheduling in the vLLM/Orca style: each iteration
//! (1) admits queued requests into free slots while the KV token
//! budget allows, (2) prefills newly admitted requests — grouped by
//! shared prompt prefix, forking the prompt cache where it matches and
//! running every novel suffix through a single stacked
//! `Session::prefill_batch` forward — and samples their first tokens
//! (TTFT), and (3) advances every unfinished slot by one token through
//! a single `Session::decode_batch` call. Both phases run one stacked
//! forward per iteration, not one per slot, so batching buys FLOP
//! efficiency rather than just scheduling overhead. Finished requests
//! free their slot and budget immediately, so waiting requests are
//! admitted on the very next iteration — no batch-boundary stalls.
//!
//! Prefix reuse (`SchedulerCfg::prefix_cache`) hangs a
//! [`crate::serve::CacheStore`] off the scheduler: admission looks up
//! each eligible prompt, forks the longest stored prefix
//! (copy-on-write, `KvCache::fork_from`) and prefills only the suffix;
//! freshly prefilled prompts are stored back (COW snapshots) for later
//! admissions. Requests in the *same* admission round that share a
//! prefix are split into waves: the first carrier prefills it, the
//! rest fork it one wave later instead of each re-prefilling it.
//! Reuse never changes what a request computes — forked decode is
//! bit-compatible with cold decode (test-pinned) — only how much of
//! it is recomputed.
//!
//! Memory accounting is in KV *positions*: a request admitted with
//! prompt length `p` and `max_new` new tokens costs `p + max_new`
//! positions for its lifetime, and the sum of live costs never exceeds
//! `SchedulerCfg::token_budget`. Cache misses allocate exactly their
//! cost (a right-sized private ring); cache hits ride the store's
//! fixed ring capacity but share their prefix chunks copy-on-write —
//! either way *physical* per-request residency tracks the logical
//! cost, with the store's own entries bounded separately by its
//! `max_entries × capacity` configuration.
//!
//! Each request samples from its own `Rng::new(request.seed)` stream,
//! so its output is independent of batch composition — a scheduled
//! generation is bitwise-identical to running
//! [`crate::serve::generate()`] alone with the same seed, with or
//! without the prefix cache. The tests pin exactly that.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::runtime::{KvCache, Session};
use crate::serve::cache_store::{CacheStats, CacheStore, CacheStoreCfg};
use crate::serve::sampler::{sample, SamplerCfg};
use crate::util::{MetricsSink, Rng};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`Completion`].
    pub id: u64,
    /// Prompt token ids (must be non-empty and inside the vocab).
    pub prompt: Vec<i32>,
    /// Number of new tokens to produce (generation may stop earlier on
    /// `eos`).
    pub max_new: usize,
    /// Token-selection configuration.
    pub sampler: SamplerCfg,
    /// Seed of this request's sampling stream.
    pub seed: u64,
    /// Optional stop token.
    pub eos: Option<i32>,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its full `max_new` tokens.
    MaxNew,
    /// Emitted its stop token early.
    Eos,
    /// Rejected at admission (e.g. a prompt token outside the model's
    /// vocab — only checkable once the session is known). The request
    /// completes with no tokens instead of erroring the whole run.
    Rejected,
}

/// A finished request with its per-request serving metrics.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Prompt length, in tokens.
    pub prompt_len: usize,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Prompt positions served from a forked prompt-cache prefix
    /// instead of being re-prefilled (0 with the cache disabled).
    pub reused_tokens: usize,
    /// Submit-to-first-token latency (includes queue wait), seconds.
    pub ttft_s: f64,
    /// Decode throughput after the first token, tokens/second.
    pub decode_tps: f64,
    /// Why the request finished.
    pub finish: FinishReason,
}

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerCfg {
    /// Maximum concurrently active requests (decode batch width).
    pub max_slots: usize,
    /// Maximum total KV positions resident across all active slots.
    pub token_budget: usize,
    /// Prefix-sharing prompt cache; `None` disables reuse entirely
    /// (every request prefills its full prompt into a private cache).
    pub prefix_cache: Option<CacheStoreCfg>,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg { max_slots: 8, token_budget: 8192, prefix_cache: None }
    }
}

/// One active generation stream.
struct Slot {
    req: Request,
    cache: KvCache,
    rng: Rng,
    generated: Vec<i32>,
    submitted: Instant,
    /// set once the first token exists (prefill done)
    first_token_at: Option<Instant>,
    /// KV positions charged against the token budget
    /// (`prompt + max_new`, independent of the cache's ring capacity)
    cost: usize,
    /// prompt positions forked from the store instead of prefilled
    reused: usize,
}

impl Slot {
    fn finished(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) = (self.req.eos, self.generated.last()) {
            if last == eos {
                return Some(FinishReason::Eos);
            }
        }
        if self.generated.len() >= self.req.max_new {
            return Some(FinishReason::MaxNew);
        }
        None
    }
}

/// Longest common prefix of two token sequences.
fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// The continuous-batching scheduler. Submit requests, then [`Self::run`]
/// to completion (or step iterations manually with [`Self::tick`]).
pub struct Scheduler {
    cfg: SchedulerCfg,
    queue: VecDeque<(Request, Instant)>,
    active: Vec<Slot>,
    store: Option<CacheStore>,
    in_flight_tokens: usize,
    /// high-water mark of concurrently active slots (observability)
    peak_active: usize,
    /// Per-request serving metrics (TTFT, decode tok/s, KV residency,
    /// reused prompt positions), one record per completion.
    pub metrics: MetricsSink,
}

impl Scheduler {
    /// Build a scheduler; `max_slots` is clamped to at least 1 (zero
    /// slots could never admit anything and would make [`Self::run`]
    /// spin forever on a non-empty queue).
    pub fn new(mut cfg: SchedulerCfg) -> Self {
        cfg.max_slots = cfg.max_slots.max(1);
        Scheduler {
            store: cfg.prefix_cache.map(CacheStore::new),
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            in_flight_tokens: 0,
            peak_active: 0,
            metrics: MetricsSink::memory(),
        }
    }

    /// Enqueue a request. Rejects requests that could never be admitted
    /// (cost above the whole token budget) instead of deadlocking.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        ensure!(!req.prompt.is_empty(), "request {}: empty prompt", req.id);
        ensure!(req.max_new > 0, "request {}: max_new must be > 0", req.id);
        req.sampler.validate()?;
        let cost = req.prompt.len() + req.max_new;
        ensure!(
            cost <= self.cfg.token_budget,
            "request {}: needs {cost} KV positions but the token budget is {}",
            req.id,
            self.cfg.token_budget
        );
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    /// Requests still queued or actively decoding.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// High-water mark of concurrently active slots.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// KV positions currently charged against the token budget.
    pub fn in_flight_tokens(&self) -> usize {
        self.in_flight_tokens
    }

    /// Prompt-cache reuse counters (`None` when the prefix cache is
    /// disabled).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.store.as_ref().map(|s| s.stats())
    }

    /// Can this request ride the prompt cache? Only when its whole
    /// lifetime (`prompt + max_new` positions) fits the store's ring
    /// capacity — a forked cache must never wrap, so reuse changes
    /// nothing about the attention windows the request computes.
    fn cache_eligible(&self, req: &Request) -> bool {
        match &self.store {
            Some(s) => req.prompt.len() + req.max_new <= s.cfg().capacity,
            None => false,
        }
    }

    /// One scheduling iteration: admit + prefill new requests, advance
    /// every active slot by one decode step, retire finished requests.
    /// Returns the requests that completed during this iteration.
    pub fn tick(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        let vocab = sess.spec.config.vocab;
        // admission: pop every request the free slots and the budget can
        // take this iteration. FIFO — a too-large head-of-queue request
        // waits rather than being bypassed, keeping completion order
        // predictable.
        let mut admitted: Vec<(Request, Instant)> = Vec::new();
        let mut reserved = 0usize;
        while self.active.len() + admitted.len() < self.cfg.max_slots {
            let Some((req, _)) = self.queue.front() else { break };
            let cost = req.prompt.len() + req.max_new;
            if self.in_flight_tokens + reserved + cost > self.cfg.token_budget {
                break;
            }
            let (req, submitted) = self.queue.pop_front().unwrap();
            // token range is only checkable against a concrete model;
            // a bad prompt rejects this request, not the whole run
            if req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
                let ttft_s = submitted.elapsed().as_secs_f64();
                self.metrics.log(
                    req.id,
                    &[("ttft_ms", ttft_s * 1e3), ("new_tokens", 0.0), ("rejected", 1.0)],
                );
                done.push(Completion {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    reused_tokens: 0,
                    ttft_s,
                    decode_tps: 0.0,
                    finish: FinishReason::Rejected,
                });
                continue;
            }
            reserved += cost;
            admitted.push((req, submitted));
        }

        // prefill the admission group in shared-prefix waves: a request
        // defers when an *earlier* pending prompt shares a longer prefix
        // than the store currently holds — that wave prefills (and
        // stores) the carrier's prompt, so the deferred request forks
        // the shared prefix next wave instead of re-prefilling it. The
        // earliest pending request never defers, so every wave makes
        // progress and the loop terminates.
        let mut pending: VecDeque<(Request, Instant)> = admitted.into();
        while !pending.is_empty() {
            let items: Vec<(Request, Instant)> = pending.drain(..).collect();
            let mut deferred = vec![false; items.len()];
            if let Some(store) = &self.store {
                let min_prefix = store.cfg().min_prefix;
                for i in 0..items.len() {
                    let pi = &items[i].0.prompt;
                    if !self.cache_eligible(&items[i].0) {
                        continue;
                    }
                    // a fork never covers the final position (its
                    // logits must be computed), so cap usable lengths
                    let usable = |l: usize| l.min(pi.len() - 1);
                    let store_m = usable(store.peek_match(pi));
                    deferred[i] = (0..i).any(|j| {
                        self.cache_eligible(&items[j].0)
                            && usable(lcp(pi, &items[j].0.prompt)) > store_m.max(min_prefix - 1)
                    });
                }
            }
            let mut wave: Vec<(Request, Instant)> = Vec::new();
            for (item, defer) in items.into_iter().zip(deferred) {
                if defer {
                    pending.push_back(item);
                } else {
                    wave.push(item);
                }
            }

            // per-member cache setup: fork the longest stored prefix
            // when it pays off (the fork rides the store's ring layout,
            // sharing its prefix chunks), else a right-sized private
            // ring — a miss never over-allocates, so physical KV
            // residency stays bounded by the token budget; the store
            // converts layouts itself on insert-back
            let mut slots: Vec<Slot> = Vec::with_capacity(wave.len());
            for (req, submitted) in wave {
                let cost = req.prompt.len() + req.max_new;
                let hit = if self.cache_eligible(&req) {
                    let store = self.store.as_mut().expect("eligible implies store");
                    store.lookup(&req.prompt)
                } else {
                    None
                };
                let (cache, reused) = match hit {
                    Some((cache, m)) => (cache, m),
                    None => (sess.kv_cache(cost)?, 0),
                };
                slots.push(Slot {
                    cache,
                    rng: Rng::new(req.seed),
                    generated: Vec::with_capacity(req.max_new),
                    submitted,
                    first_token_at: None,
                    cost,
                    reused,
                    req,
                });
            }

            // one stacked ragged forward prefills every novel suffix
            let rows = {
                let mut chunks: Vec<&[i32]> = Vec::with_capacity(slots.len());
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(slots.len());
                for slot in slots.iter_mut() {
                    let Slot { req, cache, reused, .. } = slot;
                    chunks.push(&req.prompt[*reused..]);
                    caches.push(cache);
                }
                sess.prefill_batch(&chunks, &mut caches)?
            };

            // sample first tokens, store the freshly resident prompts
            // back (COW snapshots), and activate the slots
            for (mut slot, logits) in slots.into_iter().zip(rows) {
                let first = sample(&logits, &slot.req.sampler, &mut slot.rng) as i32;
                slot.generated.push(first);
                slot.first_token_at = Some(Instant::now());
                // same gate as lookup: requests that can never hit
                // (lifetime beyond the store ring) also never insert,
                // so they cannot thrash the LRU or pay the copy
                if self.cache_eligible(&slot.req) {
                    let store = self.store.as_mut().expect("eligible implies store");
                    store.insert(&slot.req.prompt, &slot.cache)?;
                }
                self.in_flight_tokens += slot.cost;
                self.active.push(slot);
                self.peak_active = self.peak_active.max(self.active.len());
            }
        }

        // decode: one *batched* forward advances every unfinished slot
        // by one token — each layer runs one GEMM per projection across
        // the whole batch instead of one per slot (attention stays
        // per-slot over each ring cache). Sampling still draws from
        // each slot's own seed stream, so batching changes wall-clock,
        // never tokens. The unfinished-slot set is computed ONCE as an
        // (ascending) index list so logits row i is structurally — not
        // coincidentally — aligned with slot `batch[i]` in every pass.
        let batch: Vec<usize> = (0..self.active.len())
            .filter(|&i| self.active[i].finished().is_none())
            .collect();
        if !batch.is_empty() {
            let tokens: Vec<i32> = batch
                .iter()
                .map(|&i| *self.active[i].generated.last().expect("prefill seeded a token"))
                .collect();
            let positions: Vec<usize> =
                batch.iter().map(|&i| self.active[i].cache.len()).collect();
            let logits = {
                // `batch` is ascending, so this filter yields caches in
                // exactly `batch` order
                let mut caches: Vec<&mut KvCache> = self
                    .active
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| batch.binary_search(i).is_ok())
                    .map(|(_, s)| &mut s.cache)
                    .collect();
                sess.decode_batch(&tokens, &positions, &mut caches)?
            };
            for (row, &i) in logits.iter().zip(&batch) {
                let slot = &mut self.active[i];
                let next = sample(row, &slot.req.sampler, &mut slot.rng) as i32;
                slot.generated.push(next);
            }
        }

        // retire finished slots, freeing budget for the next iteration
        let mut i = 0;
        while i < self.active.len() {
            if let Some(finish) = self.active[i].finished() {
                let slot = self.active.swap_remove(i);
                self.in_flight_tokens -= slot.cost;
                done.push(self.complete(slot, finish));
            } else {
                i += 1;
            }
        }
        Ok(done)
    }

    fn complete(&mut self, slot: Slot, finish: FinishReason) -> Completion {
        let now = Instant::now();
        let first = slot.first_token_at.unwrap_or(now);
        let ttft_s = first.duration_since(slot.submitted).as_secs_f64();
        let decoded = slot.generated.len().saturating_sub(1);
        let decode_s = now.duration_since(first).as_secs_f64();
        let decode_tps = if decode_s > 0.0 { decoded as f64 / decode_s } else { 0.0 };
        // bytes for the *charged* positions: a forked cache rides the
        // store's (larger) ring but shares its prefix chunks, so the
        // cost-based figure is the honest per-request residency
        let kv_bytes = 2
            * slot.cache.n_layers()
            * slot.cost
            * slot.cache.kv_dim()
            * std::mem::size_of::<f32>();
        self.metrics.log(
            slot.req.id,
            &[
                ("ttft_ms", ttft_s * 1e3),
                ("decode_tps", decode_tps),
                ("new_tokens", slot.generated.len() as f64),
                ("reused_tokens", slot.reused as f64),
                ("kv_positions", slot.cost as f64),
                ("kv_bytes", kv_bytes as f64),
            ],
        );
        Completion {
            id: slot.req.id,
            prompt_len: slot.req.prompt.len(),
            tokens: slot.generated,
            reused_tokens: slot.reused,
            ttft_s,
            decode_tps,
            finish,
        }
    }

    /// Drive the queue to empty; returns completions in finish order.
    pub fn run(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick(sess)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Session};
    use crate::serve::generate::{generate, GenerateCfg};

    fn tiny_session() -> Session {
        let mut eng = Engine::host();
        Session::create(&mut eng, "tiny", 0).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampler: SamplerCfg { temperature: 0.7, top_k: 16, top_p: 0.9 },
            seed: 1000 + id,
            eos: None,
        }
    }

    fn solo(sess: &Session, r: &Request) -> Vec<i32> {
        generate(
            sess,
            &r.prompt,
            &GenerateCfg { max_new: r.max_new, sampler: r.sampler, seed: r.seed, eos: r.eos },
        )
        .unwrap()
        .tokens
    }

    #[test]
    fn all_requests_complete_with_metrics() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 3,
            token_budget: 256,
            prefix_cache: None,
        });
        for i in 0..5 {
            sched.submit(req(i, vec![1, 10 + i as i32], 4 + i as usize)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 5);
        assert_eq!(sched.in_flight_tokens(), 0);
        assert!(sched.peak_active() >= 2, "should overlap: {}", sched.peak_active());
        for c in &done {
            assert_eq!(c.tokens.len(), 4 + c.id as usize);
            assert_eq!(c.finish, FinishReason::MaxNew);
            assert!(c.ttft_s >= 0.0);
            assert_eq!(c.reused_tokens, 0, "cache disabled: nothing to reuse");
        }
        // one metrics record per request
        assert_eq!(sched.metrics.history.len(), 5);
        assert_eq!(sched.metrics.series("ttft_ms").len(), 5);
        assert!(sched.cache_stats().is_none());
    }

    #[test]
    fn token_budget_serializes_admission() {
        let sess = tiny_session();
        // each request costs 2 + 6 = 8 positions; budget 8 → one at a time
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 8,
            prefix_cache: None,
        });
        for i in 0..3 {
            sched.submit(req(i, vec![1, 5], 6)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(sched.peak_active(), 1, "budget must prevent overlap");
    }

    #[test]
    fn oversized_request_is_rejected_up_front() {
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 16,
            prefix_cache: None,
        });
        let err = sched.submit(req(0, vec![1; 10], 10)).unwrap_err();
        assert!(format!("{err:#}").contains("token budget"), "{err:#}");
        assert!(sched.submit(req(1, vec![1; 10], 6)).is_ok());
    }

    #[test]
    fn out_of_vocab_prompt_rejects_request_not_run() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 64,
            prefix_cache: None,
        });
        sched.submit(req(0, vec![1, 5], 4)).unwrap();
        sched.submit(req(1, vec![1, 999], 4)).unwrap(); // 999 >= vocab 256
        sched.submit(req(2, vec![1, 6], 4)).unwrap();
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3, "good requests must survive a bad one");
        done.sort_by_key(|c| c.id);
        assert_eq!(done[1].finish, FinishReason::Rejected);
        assert!(done[1].tokens.is_empty());
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[2].tokens.len(), 4);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    #[test]
    fn zero_slots_is_clamped_not_a_hang() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 0,
            token_budget: 64,
            prefix_cache: None,
        });
        sched.submit(req(0, vec![1, 2], 3)).unwrap();
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(sched.peak_active(), 1);
    }

    #[test]
    fn scheduled_output_matches_solo_generation() {
        let sess = tiny_session();
        let reqs: Vec<Request> = (0..4)
            .map(|i| req(i, vec![1, 3 + i as i32, 20], 6))
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 64,
            prefix_cache: None,
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {} diverged from solo generation", r.id
            );
        }
    }

    /// Tentpole: prefix reuse must change wall-clock, never tokens —
    /// every scheduled output still equals solo generation, while the
    /// store records real hits on the shared system prompt.
    #[test]
    fn prefix_cache_preserves_solo_parity_and_reuses_tokens() {
        let sess = tiny_session();
        let shared: Vec<i32> = vec![1, 7, 8, 9, 10, 11, 12, 13]; // 8-token system prompt
        let reqs: Vec<Request> = (0..4)
            .map(|i| {
                let mut p = shared.clone();
                p.extend([30 + i as i32, 40 + i as i32]);
                req(i, p, 5)
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 256,
            prefix_cache: Some(CacheStoreCfg {
                capacity: 64,
                max_entries: 8,
                min_prefix: 4,
            }),
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 4);
        done.sort_by_key(|c| c.id);
        let mut total_reused = 0usize;
        for (c, r) in done.iter().zip(&reqs) {
            assert_eq!(
                c.tokens, solo(&sess, r),
                "request {}: prefix reuse changed the generated tokens", r.id
            );
            total_reused += c.reused_tokens;
        }
        let stats = sched.cache_stats().unwrap();
        assert!(stats.hits >= 3, "later requests must fork the shared prefix: {stats:?}");
        assert!(
            stats.reused_tokens >= 3 * shared.len() as u64,
            "each hit reuses at least the shared prompt: {stats:?}"
        );
        assert_eq!(stats.reused_tokens, total_reused as u64);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    /// Two same-tick admissions sharing a prefix split into waves: the
    /// carrier prefills it, the second forks it from the store in the
    /// same tick — no same-batch double prefill.
    #[test]
    fn same_tick_admissions_share_a_prefix_through_waves() {
        let sess = tiny_session();
        let shared = vec![1, 21, 22, 23, 24, 25];
        let mut a = shared.clone();
        a.push(31);
        let mut b = shared.clone();
        b.push(32);
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 256,
            prefix_cache: Some(CacheStoreCfg {
                capacity: 32,
                max_entries: 8,
                min_prefix: 2,
            }),
        });
        sched.submit(req(0, a, 3)).unwrap();
        sched.submit(req(1, b, 3)).unwrap();
        // both admitted in the very first tick
        sched.tick(&sess).unwrap();
        let stats = sched.cache_stats().unwrap();
        assert_eq!(stats.hits, 1, "the deferred request must fork, not re-prefill");
        assert_eq!(stats.reused_tokens, shared.len() as u64);
        assert_eq!(sched.peak_active(), 2);
    }
}
