//! Continuous-batching scheduler.
//!
//! Iteration-level scheduling in the vLLM/Orca style: each iteration
//! (1) admits queued requests into free slots while the KV token
//! budget allows, (2) prefills newly admitted requests and samples
//! their first token (TTFT), and (3) advances every unfinished slot by
//! one token through a single `Session::decode_batch` call — one
//! stacked `[batch, hidden]` forward per iteration, not one forward
//! per slot, so batching buys FLOP efficiency rather than just
//! scheduling overhead. Finished requests free their slot and budget
//! immediately, so waiting requests are admitted on the very next
//! iteration — no batch-boundary stalls.
//!
//! Memory accounting is in KV *positions*: a request admitted with
//! prompt length `p` and `max_new` new tokens holds a cache of
//! `p + max_new` positions for its lifetime, and the sum of live slot
//! capacities never exceeds `SchedulerCfg::token_budget`
//! (`KvCache::bytes` converts positions to bytes).
//!
//! Each request samples from its own `Rng::new(request.seed)` stream,
//! so its output is independent of batch composition — a scheduled
//! generation is bitwise-identical to running [`crate::serve::generate`]
//! alone with the same seed. The tests pin exactly that.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::runtime::{KvCache, Session};
use crate::serve::sampler::{sample, SamplerCfg};
use crate::util::{MetricsSink, Rng};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampler: SamplerCfg,
    /// Seed of this request's sampling stream.
    pub seed: u64,
    /// Optional stop token.
    pub eos: Option<i32>,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxNew,
    Eos,
    /// Rejected at admission (e.g. a prompt token outside the model's
    /// vocab — only checkable once the session is known). The request
    /// completes with no tokens instead of erroring the whole run.
    Rejected,
}

/// A finished request with its per-request serving metrics.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Submit-to-first-token latency (includes queue wait), seconds.
    pub ttft_s: f64,
    /// Decode throughput after the first token, tokens/second.
    pub decode_tps: f64,
    pub finish: FinishReason,
}

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerCfg {
    /// Maximum concurrently active requests (decode batch width).
    pub max_slots: usize,
    /// Maximum total KV positions resident across all active slots.
    pub token_budget: usize,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg { max_slots: 8, token_budget: 8192 }
    }
}

/// One active generation stream.
struct Slot {
    req: Request,
    cache: KvCache,
    rng: Rng,
    generated: Vec<i32>,
    submitted: Instant,
    /// set once the first token exists (prefill done)
    first_token_at: Option<Instant>,
}

impl Slot {
    fn cost(&self) -> usize {
        self.cache.capacity()
    }

    fn finished(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) = (self.req.eos, self.generated.last()) {
            if last == eos {
                return Some(FinishReason::Eos);
            }
        }
        if self.generated.len() >= self.req.max_new {
            return Some(FinishReason::MaxNew);
        }
        None
    }
}

/// The continuous-batching scheduler. Submit requests, then [`Self::run`]
/// to completion (or step iterations manually with [`Self::tick`]).
pub struct Scheduler {
    cfg: SchedulerCfg,
    queue: VecDeque<(Request, Instant)>,
    active: Vec<Slot>,
    in_flight_tokens: usize,
    /// high-water mark of concurrently active slots (observability)
    peak_active: usize,
    pub metrics: MetricsSink,
}

impl Scheduler {
    pub fn new(mut cfg: SchedulerCfg) -> Self {
        // zero slots could never admit anything and would make `run`
        // spin forever on a non-empty queue; clamp to one
        cfg.max_slots = cfg.max_slots.max(1);
        Scheduler {
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            in_flight_tokens: 0,
            peak_active: 0,
            metrics: MetricsSink::memory(),
        }
    }

    /// Enqueue a request. Rejects requests that could never be admitted
    /// (cost above the whole token budget) instead of deadlocking.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        ensure!(!req.prompt.is_empty(), "request {}: empty prompt", req.id);
        ensure!(req.max_new > 0, "request {}: max_new must be > 0", req.id);
        req.sampler.validate()?;
        let cost = req.prompt.len() + req.max_new;
        ensure!(
            cost <= self.cfg.token_budget,
            "request {}: needs {cost} KV positions but the token budget is {}",
            req.id,
            self.cfg.token_budget
        );
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// High-water mark of concurrently active slots.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// KV positions currently resident across active slots.
    pub fn in_flight_tokens(&self) -> usize {
        self.in_flight_tokens
    }

    /// One scheduling iteration: admit + prefill new requests, advance
    /// every active slot by one decode step, retire finished requests.
    /// Returns the requests that completed during this iteration.
    pub fn tick(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        let vocab = sess.spec.config.vocab;
        // admission: fill free slots while the budget allows. FIFO —
        // a too-large head-of-queue request waits rather than being
        // bypassed, keeping completion order predictable.
        while self.active.len() < self.cfg.max_slots {
            let Some((req, _)) = self.queue.front() else { break };
            let cost = req.prompt.len() + req.max_new;
            if self.in_flight_tokens + cost > self.cfg.token_budget {
                break;
            }
            let (req, submitted) = self.queue.pop_front().unwrap();
            // token range is only checkable against a concrete model;
            // a bad prompt rejects this request, not the whole run
            if req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
                let ttft_s = submitted.elapsed().as_secs_f64();
                self.metrics.log(
                    req.id,
                    &[("ttft_ms", ttft_s * 1e3), ("new_tokens", 0.0), ("rejected", 1.0)],
                );
                done.push(Completion {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    ttft_s,
                    decode_tps: 0.0,
                    finish: FinishReason::Rejected,
                });
                continue;
            }
            let mut slot = Slot {
                cache: sess.kv_cache(cost)?,
                rng: Rng::new(req.seed),
                generated: Vec::with_capacity(req.max_new),
                submitted,
                first_token_at: None,
                req,
            };
            let logits = sess.prefill(&slot.req.prompt, &mut slot.cache)?;
            let first = sample(&logits, &slot.req.sampler, &mut slot.rng) as i32;
            slot.generated.push(first);
            slot.first_token_at = Some(Instant::now());
            self.in_flight_tokens += cost;
            self.active.push(slot);
            self.peak_active = self.peak_active.max(self.active.len());
        }

        // decode: one *batched* forward advances every unfinished slot
        // by one token — each layer runs one GEMM per projection across
        // the whole batch instead of one per slot (attention stays
        // per-slot over each ring cache). Sampling still draws from
        // each slot's own seed stream, so batching changes wall-clock,
        // never tokens. The unfinished-slot set is computed ONCE as an
        // (ascending) index list so logits row i is structurally — not
        // coincidentally — aligned with slot `batch[i]` in every pass.
        let batch: Vec<usize> = (0..self.active.len())
            .filter(|&i| self.active[i].finished().is_none())
            .collect();
        if !batch.is_empty() {
            let tokens: Vec<i32> = batch
                .iter()
                .map(|&i| *self.active[i].generated.last().expect("prefill seeded a token"))
                .collect();
            let positions: Vec<usize> =
                batch.iter().map(|&i| self.active[i].cache.len()).collect();
            let logits = {
                // `batch` is ascending, so this filter yields caches in
                // exactly `batch` order
                let mut caches: Vec<&mut KvCache> = self
                    .active
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| batch.binary_search(i).is_ok())
                    .map(|(_, s)| &mut s.cache)
                    .collect();
                sess.decode_batch(&tokens, &positions, &mut caches)?
            };
            for (row, &i) in logits.iter().zip(&batch) {
                let slot = &mut self.active[i];
                let next = sample(row, &slot.req.sampler, &mut slot.rng) as i32;
                slot.generated.push(next);
            }
        }

        // retire finished slots, freeing budget for the next iteration
        let mut i = 0;
        while i < self.active.len() {
            if let Some(finish) = self.active[i].finished() {
                let slot = self.active.swap_remove(i);
                self.in_flight_tokens -= slot.cost();
                done.push(self.complete(slot, finish));
            } else {
                i += 1;
            }
        }
        Ok(done)
    }

    fn complete(&mut self, slot: Slot, finish: FinishReason) -> Completion {
        let now = Instant::now();
        let first = slot.first_token_at.unwrap_or(now);
        let ttft_s = first.duration_since(slot.submitted).as_secs_f64();
        let decoded = slot.generated.len().saturating_sub(1);
        let decode_s = now.duration_since(first).as_secs_f64();
        let decode_tps = if decode_s > 0.0 { decoded as f64 / decode_s } else { 0.0 };
        self.metrics.log(
            slot.req.id,
            &[
                ("ttft_ms", ttft_s * 1e3),
                ("decode_tps", decode_tps),
                ("new_tokens", slot.generated.len() as f64),
                ("kv_positions", slot.cache.capacity() as f64),
                ("kv_bytes", slot.cache.bytes() as f64),
            ],
        );
        Completion {
            id: slot.req.id,
            prompt_len: slot.req.prompt.len(),
            tokens: slot.generated,
            ttft_s,
            decode_tps,
            finish,
        }
    }

    /// Drive the queue to empty; returns completions in finish order.
    pub fn run(&mut self, sess: &Session) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick(sess)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Session};

    fn tiny_session() -> Session {
        let mut eng = Engine::host();
        Session::create(&mut eng, "tiny", 0).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampler: SamplerCfg { temperature: 0.7, top_k: 16, top_p: 0.9 },
            seed: 1000 + id,
            eos: None,
        }
    }

    #[test]
    fn all_requests_complete_with_metrics() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 3, token_budget: 256 });
        for i in 0..5 {
            sched.submit(req(i, vec![1, 10 + i as i32], 4 + i as usize)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 5);
        assert_eq!(sched.in_flight_tokens(), 0);
        assert!(sched.peak_active() >= 2, "should overlap: {}", sched.peak_active());
        for c in &done {
            assert_eq!(c.tokens.len(), 4 + c.id as usize);
            assert_eq!(c.finish, FinishReason::MaxNew);
            assert!(c.ttft_s >= 0.0);
        }
        // one metrics record per request
        assert_eq!(sched.metrics.history.len(), 5);
        assert_eq!(sched.metrics.series("ttft_ms").len(), 5);
    }

    #[test]
    fn token_budget_serializes_admission() {
        let sess = tiny_session();
        // each request costs 2 + 6 = 8 positions; budget 8 → one at a time
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 4, token_budget: 8 });
        for i in 0..3 {
            sched.submit(req(i, vec![1, 5], 6)).unwrap();
        }
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(sched.peak_active(), 1, "budget must prevent overlap");
    }

    #[test]
    fn oversized_request_is_rejected_up_front() {
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 2, token_budget: 16 });
        let err = sched.submit(req(0, vec![1; 10], 10)).unwrap_err();
        assert!(format!("{err:#}").contains("token budget"), "{err:#}");
        assert!(sched.submit(req(1, vec![1; 10], 6)).is_ok());
    }

    #[test]
    fn out_of_vocab_prompt_rejects_request_not_run() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 2, token_budget: 64 });
        sched.submit(req(0, vec![1, 5], 4)).unwrap();
        sched.submit(req(1, vec![1, 999], 4)).unwrap(); // 999 >= vocab 256
        sched.submit(req(2, vec![1, 6], 4)).unwrap();
        let mut done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 3, "good requests must survive a bad one");
        done.sort_by_key(|c| c.id);
        assert_eq!(done[1].finish, FinishReason::Rejected);
        assert!(done[1].tokens.is_empty());
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(done[2].tokens.len(), 4);
        assert_eq!(sched.in_flight_tokens(), 0);
    }

    #[test]
    fn zero_slots_is_clamped_not_a_hang() {
        let sess = tiny_session();
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 0, token_budget: 64 });
        sched.submit(req(0, vec![1, 2], 3)).unwrap();
        let done = sched.run(&sess).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(sched.peak_active(), 1);
    }

    #[test]
    fn scheduled_output_matches_solo_generation() {
        use crate::serve::generate::{generate, GenerateCfg};
        let sess = tiny_session();
        let reqs: Vec<Request> = (0..4)
            .map(|i| req(i, vec![1, 3 + i as i32, 20], 6))
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg { max_slots: 2, token_budget: 64 });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            let solo = generate(
                &sess,
                &r.prompt,
                &GenerateCfg {
                    max_new: r.max_new,
                    sampler: r.sampler,
                    seed: r.seed,
                    eos: r.eos,
                },
            )
            .unwrap();
            assert_eq!(
                c.tokens, solo.tokens,
                "request {} diverged from solo generation", r.id
            );
        }
    }
}
