//! Prefix-sharing prompt cache: a token-prefix trie over copy-on-write
//! [`KvCache`] forks.
//!
//! Serving workloads repeat prompt prefixes constantly — system
//! prompts, few-shot templates, multi-turn histories. Without reuse,
//! every admitted request re-prefills its full prompt and owns private
//! KV rows for it; with the store, a new request forks a cached cache
//! at the longest matching token prefix ([`KvCache::fork_from`]) and
//! prefills only the novel suffix. The fork is O(chunks) `Arc` clones:
//! K/V chunks stay physically shared until one side writes
//! (copy-on-write), so the prefix is neither recomputed *nor* duplicated
//! in memory — the same keep-only-what-diverges idea MISA applies to
//! optimizer state, applied to KV memory across requests.
//!
//! Structure: a trie with one node per token. An entry (a fully
//! prefilled prompt and its cache) hangs off the node where its prompt
//! ends; lookups walk the query prompt down the trie, and the deepest
//! reachable node gives the longest stored prefix — any entry below it
//! shares that prefix, and all of them hold bit-identical K/V rows for
//! it (same tokens, same positions, same kernels), so any one can be
//! forked. Eviction is least-recently-used at whole-entry granularity,
//! pruning the trie path behind the evicted entry.
//!
//! Every entry — and every cache forked from one — uses the *same* ring
//! capacity ([`CacheStoreCfg::capacity`]): chunk sharing requires one
//! ring layout. Cache misses keep their right-sized private rings
//! (never an over-allocation against the scheduler's budget); their
//! prompts enter the store through a one-time layout-converting row
//! copy on insert ([`KvCache::copy_prefix`]). Requests whose
//! `prompt + max_new` exceed the store capacity bypass the store
//! entirely — no lookup (a fork that wrapped would change attention
//! windows) and no insert (they could never hit, so seeding entries
//! would only thrash the LRU) — so the store never changes what a
//! request computes, only how much of it is recomputed. The store's
//! own residency is bounded by `max_entries` rings of `capacity`
//! positions.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::runtime::KvCache;

/// Configuration of a [`CacheStore`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheStoreCfg {
    /// Ring capacity (KV positions) of every stored entry — and of
    /// every request cache forked from one (chunk sharing requires one
    /// ring layout). Requests needing more positions bypass the store.
    pub capacity: usize,
    /// Maximum resident entries; the least-recently-used entry is
    /// evicted beyond this.
    pub max_entries: usize,
    /// Shortest matched prefix worth forking; shorter matches count as
    /// misses and re-prefill from scratch.
    pub min_prefix: usize,
}

impl Default for CacheStoreCfg {
    fn default() -> Self {
        CacheStoreCfg { capacity: 1024, max_entries: 32, min_prefix: 8 }
    }
}

/// Aggregate reuse counters, exported into `misa bench-serve --json`
/// records and the scheduler's metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups performed by cache-eligible admissions.
    pub lookups: u64,
    /// Lookups that forked a stored prefix.
    pub hits: u64,
    /// Total prompt positions served from forked caches instead of
    /// being re-prefilled.
    pub reused_tokens: u64,
    /// Prompts inserted (identical prompts deduplicate).
    pub insertions: u64,
    /// Entries evicted (least-recently-used).
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
}

impl CacheStats {
    /// `hits / lookups` (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

impl crate::obs::MetricSource for CacheStats {
    fn metric_kvs(&self) -> Vec<(String, f64)> {
        vec![
            ("serve.cache.lookups".to_string(), self.lookups as f64),
            ("serve.cache.hits".to_string(), self.hits as f64),
            ("serve.cache.hit_rate".to_string(), self.hit_rate()),
            ("serve.cache.reused_tokens".to_string(), self.reused_tokens as f64),
            ("serve.cache.insertions".to_string(), self.insertions as f64),
            ("serve.cache.evictions".to_string(), self.evictions as f64),
            ("serve.cache.entries".to_string(), self.entries as f64),
        ]
    }
}

/// One trie node: children keyed by the next token; `entry` is set on
/// nodes where a stored prompt ends.
#[derive(Default)]
struct Node {
    children: HashMap<i32, Node>,
    entry: Option<u64>,
}

/// A stored prompt: its tokens (the trie path, needed for eviction
/// pruning), its prefilled cache, and its LRU stamp.
struct Entry {
    tokens: Vec<i32>,
    cache: KvCache,
    last_used: u64,
}

/// The prefix-sharing prompt cache. Owned by the scheduler when
/// `SchedulerCfg::prefix_cache` is set; see the module docs for the
/// reuse model.
pub struct CacheStore {
    cfg: CacheStoreCfg,
    root: Node,
    entries: HashMap<u64, Entry>,
    next_id: u64,
    clock: u64,
    stats: CacheStats,
}

impl CacheStore {
    /// Build a store. Degenerate limits are clamped to 1 (a store that
    /// could hold nothing would silently disable reuse).
    pub fn new(mut cfg: CacheStoreCfg) -> Self {
        cfg.capacity = cfg.capacity.max(1);
        cfg.max_entries = cfg.max_entries.max(1);
        cfg.min_prefix = cfg.min_prefix.max(1);
        CacheStore {
            cfg,
            root: Node::default(),
            entries: HashMap::new(),
            next_id: 0,
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    /// The store's (clamped) configuration.
    pub fn cfg(&self) -> &CacheStoreCfg {
        &self.cfg
    }

    /// Reuse counters so far, including the current entry count.
    pub fn stats(&self) -> CacheStats {
        CacheStats { entries: self.entries.len(), ..self.stats }
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Length of the longest stored prefix of `prompt`, with no counter
    /// or LRU side effects — the scheduler's admission-grouping probe.
    pub fn peek_match(&self, prompt: &[i32]) -> usize {
        let mut node = &self.root;
        let mut depth = 0;
        for &t in prompt {
            match node.children.get(&t) {
                Some(child) => {
                    node = child;
                    depth += 1;
                }
                None => break,
            }
        }
        depth
    }

    /// Fork the longest usable stored prefix of `prompt`. On a hit,
    /// returns the forked cache plus the number of prompt positions it
    /// already holds — always `< prompt.len()`, so the caller prefills
    /// at least the final position and gets its logits. Matches shorter
    /// than [`CacheStoreCfg::min_prefix`] are misses.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<(KvCache, usize)> {
        self.stats.lookups += 1;
        let m = self.peek_match(prompt).min(prompt.len().saturating_sub(1));
        if m < self.cfg.min_prefix {
            return None;
        }
        // walk to the matched node, then descend to an entry below it:
        // every retained path terminates in an entry, and every entry
        // below holds bit-identical K/V rows for the first `m` positions
        // (same tokens, same absolute positions, same kernels). The
        // descent is deterministic — a node's own entry first, else the
        // smallest child token — i.e. the lexicographically smallest
        // stored prompt extending the match. Any entry would serve the
        // fork equally; pinning *which* one pins the LRU refresh, so
        // eviction order (and with it the whole serving state machine)
        // stays a pure function of the request stream rather than of
        // `HashMap` iteration order.
        let mut node = &self.root;
        for &t in &prompt[..m] {
            node = node.children.get(&t)?;
        }
        let id = loop {
            if let Some(id) = node.entry {
                break id;
            }
            node = node.children.iter().min_by_key(|(&t, _)| t).map(|(_, c)| c)?;
        };
        let entry = self.entries.get_mut(&id)?;
        let cache = KvCache::fork_from(&entry.cache, m).ok()?;
        self.clock += 1;
        entry.last_used = self.clock;
        self.stats.hits += 1;
        self.stats.reused_tokens += m as u64;
        Some((cache, m))
    }

    /// Store `prompt`'s prefilled cache as a reusable entry. When the
    /// caller's ring already has the store layout (it was forked from
    /// an entry), the entry is a copy-on-write snapshot
    /// ([`KvCache::fork_from`] at `prompt.len()`) — the caller's cache
    /// keeps decoding and only the chunks it then writes are
    /// duplicated. Otherwise (a right-sized private ring, the
    /// cache-miss path) the prompt rows are copied into a store-layout
    /// ring ([`KvCache::copy_prefix`] — a one-time memcpy, never a
    /// recompute). Returns `false` without storing when the prompt is
    /// empty, longer than the store's ring capacity, or already stored
    /// (the duplicate's LRU stamp refreshes instead).
    pub fn insert(&mut self, prompt: &[i32], cache: &KvCache) -> Result<bool> {
        if prompt.is_empty() || prompt.len() > self.cfg.capacity {
            return Ok(false);
        }
        ensure!(
            cache.len() >= prompt.len(),
            "cache holds {} positions but the prompt has {}",
            cache.len(),
            prompt.len()
        );
        self.clock += 1;
        // dedup: an identical prompt refreshes its LRU stamp instead
        {
            let mut node = &self.root;
            let mut walked = true;
            for &t in prompt {
                match node.children.get(&t) {
                    Some(child) => node = child,
                    None => {
                        walked = false;
                        break;
                    }
                }
            }
            if walked {
                if let Some(id) = node.entry {
                    if let Some(e) = self.entries.get_mut(&id) {
                        e.last_used = self.clock;
                    }
                    return Ok(false);
                }
            }
        }
        let snapshot = if cache.capacity() == self.cfg.capacity {
            KvCache::fork_from(cache, prompt.len())?
        } else {
            KvCache::copy_prefix(cache, prompt.len(), self.cfg.capacity)?
        };
        let id = self.next_id;
        self.next_id += 1;
        let mut node = &mut self.root;
        for &t in prompt {
            node = node.children.entry(t).or_default();
        }
        node.entry = Some(id);
        self.entries.insert(
            id,
            Entry { tokens: prompt.to_vec(), cache: snapshot, last_used: self.clock },
        );
        self.stats.insertions += 1;
        while self.entries.len() > self.cfg.max_entries {
            self.evict_lru();
        }
        Ok(true)
    }

    /// Iterator over every resident entry's cache, for COW-aware
    /// byte accounting ([`crate::runtime::kv_resident_bytes`] dedupes
    /// chunks these share with live request caches).
    pub(crate) fn resident_caches(&self) -> impl Iterator<Item = &KvCache> {
        self.entries.values().map(|e| &e.cache)
    }

    fn evict_lru(&mut self) {
        let Some((&id, _)) = self.entries.iter().min_by_key(|(_, e)| e.last_used) else {
            return;
        };
        if let Some(entry) = self.entries.remove(&id) {
            remove_path(&mut self.root, &entry.tokens, id);
            self.stats.evictions += 1;
        }
    }
}

/// Unmark `id` at the end of `tokens`, then prune now-empty nodes on
/// the way back up. Returns whether `node` itself became prunable.
fn remove_path(node: &mut Node, tokens: &[i32], id: u64) -> bool {
    match tokens.split_first() {
        None => {
            if node.entry == Some(id) {
                node.entry = None;
            }
        }
        Some((&t, rest)) => {
            if let Some(child) = node.children.get_mut(&t) {
                if remove_path(child, rest, id) {
                    node.children.remove(&t);
                }
            }
        }
    }
    node.entry.is_none() && node.children.is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::Manifest;

    fn store(capacity: usize, max_entries: usize, min_prefix: usize) -> CacheStore {
        CacheStore::new(CacheStoreCfg { capacity, max_entries, min_prefix })
    }

    /// A cache that *claims* `n` resident positions (store bookkeeping
    /// tests never read K/V values).
    fn cache_with_len(capacity: usize, n: usize) -> KvCache {
        let spec = Manifest::builtin().model("tiny").unwrap().clone();
        let mut c = KvCache::new(&spec, capacity).unwrap();
        c.advance(n);
        c
    }

    #[test]
    fn longest_prefix_lookup_and_min_prefix() {
        let mut s = store(64, 8, 4);
        let prompt: Vec<i32> = (1..=10).collect();
        assert!(s.insert(&prompt, &cache_with_len(64, 10)).unwrap());
        // 8-token overlap, then divergence
        let query: Vec<i32> = (1..=8).chain([99, 98]).collect();
        let (cache, m) = s.lookup(&query).unwrap();
        assert_eq!(m, 8);
        assert_eq!(cache.len(), 8);
        // an exact-prompt query is capped one short so the final
        // position still prefills for its logits
        let (_, m) = s.lookup(&prompt).unwrap();
        assert_eq!(m, 9);
        // a 3-token overlap is below min_prefix: miss
        assert!(s.lookup(&[1, 2, 3, 50, 51]).is_none());
        let st = s.stats();
        assert_eq!((st.lookups, st.hits, st.reused_tokens), (3, 2, 17));
        assert_eq!(st.entries, 1);
        assert!((st.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_insert_refreshes_instead_of_duplicating() {
        let mut s = store(64, 8, 4);
        let prompt: Vec<i32> = (1..=6).collect();
        assert!(s.insert(&prompt, &cache_with_len(64, 6)).unwrap());
        assert!(!s.insert(&prompt, &cache_with_len(64, 6)).unwrap());
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().insertions, 1);
        // a prefix of a stored prompt is its own entry on the same path
        assert!(s.insert(&prompt[..5], &cache_with_len(64, 5)).unwrap());
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn lru_eviction_prunes_the_trie() {
        let mut s = store(64, 2, 2);
        s.insert(&[1, 2, 3], &cache_with_len(64, 3)).unwrap();
        s.insert(&[4, 5, 6], &cache_with_len(64, 3)).unwrap();
        // touch the first so the second is the LRU victim
        assert!(s.lookup(&[1, 2, 3, 9]).is_some());
        s.insert(&[7, 8, 9], &cache_with_len(64, 3)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.peek_match(&[4, 5, 6]), 0, "evicted path must be pruned");
        assert_eq!(s.peek_match(&[1, 2, 3]), 3);
        assert_eq!(s.peek_match(&[7, 8, 9]), 3);
    }

    #[test]
    fn insert_converts_layouts_and_refuses_bad_donors() {
        let mut s = store(4, 8, 2);
        // longer than the store's rings: silently skipped
        assert!(!s.insert(&[1, 2, 3, 4, 5], &cache_with_len(4, 4)).unwrap());
        // cache shorter than the prompt: hard error
        assert!(s.insert(&[1, 2, 3], &cache_with_len(4, 2)).is_err());
        // a wrapped donor would read evicted positions: hard error
        assert!(s.insert(&[1, 2, 3], &cache_with_len(3, 5)).is_err());
        assert!(s.is_empty());
        // a right-sized private ring (the cache-miss path) converts
        // into a store-layout entry via a row copy
        assert!(s.insert(&[1, 2], &cache_with_len(8, 2)).unwrap());
        assert_eq!(s.len(), 1);
        let (forked, m) = s.lookup(&[1, 2, 9]).unwrap();
        assert_eq!(m, 2);
        assert_eq!(forked.capacity(), 4, "forks ride the store layout");
    }
}
