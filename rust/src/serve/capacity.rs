//! Capacity planning: measure a serving sweep, fit a closed-form
//! model, answer sizing queries.
//!
//! `misa capacity` runs the continuous-batching scheduler over a
//! (`slots` × `token_budget` × `threads`) grid with a fixed workload,
//! measuring each point's **peak COW-deduped KV residency** and
//! **aggregate decode throughput**. A least-squares fit then turns the
//! sweep into two small closed forms:
//!
//! - `peak_kv_mib ≈ a + b · eff_pos`, where `eff_pos` is the
//!   analytically effective resident positions
//!   `min(slots, requests, token_budget / cost) · cost` with
//!   `cost = prompt_len + max_new` — the budget-clamped concurrency
//!   times each stream's ring size (chunk rounding and allocator slack
//!   land in `a`/`b`);
//! - `tok_s ≈ a + b · eff_conc + c · threads`, the same clamped
//!   concurrency plus the worker-pool width.
//!
//! The fit (coefficients, per-point residuals, held-out error when
//! requested) is emitted as JSON; `misa capacity --predict` reloads
//! such a file (via [`crate::util::Json`]) and answers "what would
//! this configuration cost" without rerunning anything. Fit quality is
//! test-pinned: held-out `peak_kv_mib` predictions must land within
//! 15% of measurement (CI asserts this on a real 4-point sweep).

use anyhow::{ensure, Context, Result};

use crate::runtime::Session;
use crate::serve::{Request, SamplerCfg, Scheduler, SchedulerCfg};
use crate::util::json::escape;
use crate::util::{Json, Rng};

/// One measured sweep point.
#[derive(Clone, Debug)]
pub struct CapacityPoint {
    /// Scheduler slots (decode batch width).
    pub slots: usize,
    /// Scheduler token budget (KV positions).
    pub token_budget: usize,
    /// GEMM worker-pool width the point ran with.
    pub threads: usize,
    /// Peak COW-deduped KV residency, MiB (measured, not analytic).
    pub peak_kv_mib: f64,
    /// Aggregate decode throughput, new tokens per wall-clock second.
    pub tok_s: f64,
}

/// Sweep shape: the grid plus the fixed per-point workload.
#[derive(Clone, Debug)]
pub struct SweepCfg {
    /// Slot counts to visit.
    pub slots_list: Vec<usize>,
    /// Token budgets to visit.
    pub budget_list: Vec<usize>,
    /// Worker-pool widths to visit.
    pub threads_list: Vec<usize>,
    /// Requests per point.
    pub requests: usize,
    /// Prompt length per request.
    pub prompt_len: usize,
    /// New tokens per request.
    pub max_new: usize,
    /// Seed for the synthetic prompts.
    pub seed: u64,
}

impl Default for SweepCfg {
    fn default() -> Self {
        SweepCfg {
            slots_list: vec![1, 2, 4],
            budget_list: vec![4096],
            threads_list: vec![1],
            requests: 8,
            prompt_len: 8,
            max_new: 8,
            seed: 0,
        }
    }
}

/// The fitted capacity model: coefficients plus the workload constants
/// the features are built from.
#[derive(Clone, Debug)]
pub struct CapacityModel {
    /// `[intercept, per-eff_pos]` for `peak_kv_mib`.
    pub kv_coef: Vec<f64>,
    /// `[intercept, per-eff_conc, per-thread]` for `tok_s`.
    pub tps_coef: Vec<f64>,
    /// Requests per point (clamps effective concurrency).
    pub requests: usize,
    /// Prompt length the sweep used.
    pub prompt_len: usize,
    /// New tokens per request the sweep used.
    pub max_new: usize,
    /// The points the fit was computed from.
    pub points: Vec<CapacityPoint>,
}

/// Effective concurrency of a configuration: slots, clamped by how
/// many requests exist and how many streams the budget can charge.
fn eff_conc(slots: usize, budget: usize, requests: usize, cost: usize) -> f64 {
    slots.min(requests).min(budget / cost.max(1)).max(1) as f64
}

/// Solve `min_x ‖A x − y‖²` by ridge-damped normal equations
/// (`AᵀA + λI`) and Gaussian elimination with partial pivoting. The
/// tiny `λ` only guards rank-deficient sweeps (e.g. a single-column
/// grid); it does not visibly bias a well-posed fit.
pub fn lstsq(rows: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>> {
    ensure!(!rows.is_empty(), "lstsq: no rows");
    let k = rows[0].len();
    ensure!(rows.iter().all(|r| r.len() == k), "lstsq: ragged rows");
    ensure!(rows.len() == y.len(), "lstsq: {} rows vs {} targets", rows.len(), y.len());
    // normal equations
    let mut ata = vec![vec![0.0f64; k]; k];
    let mut aty = vec![0.0f64; k];
    for (r, &t) in rows.iter().zip(y) {
        for i in 0..k {
            aty[i] += r[i] * t;
            for j in 0..k {
                ata[i][j] += r[i] * r[j];
            }
        }
    }
    for (i, row) in ata.iter_mut().enumerate() {
        row[i] += ridge;
    }
    // Gaussian elimination with partial pivoting on [ata | aty]
    for col in 0..k {
        let pivot = (col..k)
            .max_by(|&a, &b| {
                ata[a][col].abs().partial_cmp(&ata[b][col].abs()).expect("finite pivots")
            })
            .expect("non-empty range");
        ata.swap(col, pivot);
        aty.swap(col, pivot);
        let diag = ata[col][col];
        ensure!(diag.abs() > 1e-12, "lstsq: singular system at column {col}");
        for row in col + 1..k {
            let f = ata[row][col] / diag;
            for j in col..k {
                ata[row][j] -= f * ata[col][j];
            }
            aty[row] -= f * aty[col];
        }
    }
    let mut x = vec![0.0f64; k];
    for col in (0..k).rev() {
        let mut acc = aty[col];
        for j in col + 1..k {
            acc -= ata[col][j] * x[j];
        }
        x[col] = acc / ata[col][col];
    }
    Ok(x)
}

impl CapacityModel {
    /// Fit the two closed forms to a sweep.
    pub fn fit(
        points: Vec<CapacityPoint>,
        requests: usize,
        prompt_len: usize,
        max_new: usize,
    ) -> Result<CapacityModel> {
        ensure!(points.len() >= 2, "capacity fit needs at least 2 sweep points");
        let cost = prompt_len + max_new;
        let kv_rows: Vec<Vec<f64>> = points
            .iter()
            .map(|p| {
                let c = eff_conc(p.slots, p.token_budget, requests, cost);
                vec![1.0, c * cost as f64]
            })
            .collect();
        let kv_y: Vec<f64> = points.iter().map(|p| p.peak_kv_mib).collect();
        let tps_rows: Vec<Vec<f64>> = points
            .iter()
            .map(|p| {
                let c = eff_conc(p.slots, p.token_budget, requests, cost);
                vec![1.0, c, p.threads as f64]
            })
            .collect();
        let tps_y: Vec<f64> = points.iter().map(|p| p.tok_s).collect();
        Ok(CapacityModel {
            kv_coef: lstsq(&kv_rows, &kv_y, 1e-9)?,
            tps_coef: lstsq(&tps_rows, &tps_y, 1e-9)?,
            requests,
            prompt_len,
            max_new,
            points,
        })
    }

    /// Predicted peak KV residency (MiB) for a configuration.
    pub fn predict_kv_mib(&self, slots: usize, budget: usize, _threads: usize) -> f64 {
        let cost = self.prompt_len + self.max_new;
        let c = eff_conc(slots, budget, self.requests, cost);
        self.kv_coef[0] + self.kv_coef[1] * c * cost as f64
    }

    /// Predicted aggregate throughput (tok/s) for a configuration.
    pub fn predict_tok_s(&self, slots: usize, budget: usize, threads: usize) -> f64 {
        let cost = self.prompt_len + self.max_new;
        let c = eff_conc(slots, budget, self.requests, cost);
        self.tps_coef[0] + self.tps_coef[1] * c + self.tps_coef[2] * threads as f64
    }

    /// Largest relative error of the kv fit over its own points.
    pub fn kv_fit_rel_err(&self) -> f64 {
        self.points
            .iter()
            .map(|p| {
                let pred = self.predict_kv_mib(p.slots, p.token_budget, p.threads);
                (pred - p.peak_kv_mib).abs() / p.peak_kv_mib.abs().max(1e-9)
            })
            .fold(0.0, f64::max)
    }

    /// Serialize the whole fit (coefficients, workload constants,
    /// per-point residuals) as one JSON document.
    pub fn to_json(&self) -> String {
        self.to_json_with(None)
    }

    /// [`CapacityModel::to_json`], optionally embedding a held-out
    /// check's `(kv, tps)` relative errors — what the CI capacity
    /// smoke asserts against. Unknown keys are ignored on reload.
    pub fn to_json_with(&self, holdout: Option<(f64, f64)>) -> String {
        let coef = |cs: &[f64]| {
            cs.iter().map(|c| format!("{c}")).collect::<Vec<_>>().join(", ")
        };
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bin\": \"{}\",\n", escape("capacity")));
        if let Some((kv, tps)) = holdout {
            out.push_str(&format!("  \"holdout_kv_rel_err\": {kv},\n"));
            out.push_str(&format!("  \"holdout_tok_s_rel_err\": {tps},\n"));
        }
        out.push_str(&format!("  \"requests\": {},\n", self.requests));
        out.push_str(&format!("  \"prompt_len\": {},\n", self.prompt_len));
        out.push_str(&format!("  \"max_new\": {},\n", self.max_new));
        out.push_str(&format!("  \"kv_coef\": [{}],\n", coef(&self.kv_coef)));
        out.push_str(&format!("  \"tps_coef\": [{}],\n", coef(&self.tps_coef)));
        out.push_str(&format!("  \"kv_fit_rel_err\": {},\n", self.kv_fit_rel_err()));
        out.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            let kv_pred = self.predict_kv_mib(p.slots, p.token_budget, p.threads);
            let tps_pred = self.predict_tok_s(p.slots, p.token_budget, p.threads);
            out.push_str(&format!(
                "    {{\"slots\": {}, \"token_budget\": {}, \"threads\": {}, \
                 \"peak_kv_mib\": {}, \"tok_s\": {}, \"kv_pred_mib\": {kv_pred}, \
                 \"tok_s_pred\": {tps_pred}}}{}\n",
                p.slots,
                p.token_budget,
                p.threads,
                p.peak_kv_mib,
                p.tok_s,
                if i + 1 < self.points.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Reload a fit emitted by [`CapacityModel::to_json`].
    pub fn from_json(text: &str) -> Result<CapacityModel> {
        let j = Json::parse(text).context("parsing capacity fit")?;
        let coef = |key: &str| -> Result<Vec<f64>> {
            j.arr_field(key)?
                .iter()
                .map(|v| v.as_f64().context("non-numeric coefficient"))
                .collect()
        };
        let points = j
            .arr_field("points")?
            .iter()
            .map(|p| {
                Ok(CapacityPoint {
                    slots: p.f64_field("slots")? as usize,
                    token_budget: p.f64_field("token_budget")? as usize,
                    threads: p.f64_field("threads")? as usize,
                    peak_kv_mib: p.f64_field("peak_kv_mib")?,
                    tok_s: p.f64_field("tok_s")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = CapacityModel {
            kv_coef: coef("kv_coef")?,
            tps_coef: coef("tps_coef")?,
            requests: j.f64_field("requests")? as usize,
            prompt_len: j.f64_field("prompt_len")? as usize,
            max_new: j.f64_field("max_new")? as usize,
            points,
        };
        ensure!(m.kv_coef.len() == 2, "kv_coef must have 2 entries");
        ensure!(m.tps_coef.len() == 3, "tps_coef must have 3 entries");
        Ok(m)
    }
}

/// Measure one sweep point: run the workload through a fresh scheduler
/// at the given shape, tracking peak residency by sampling
/// [`Scheduler::kv_resident_bytes`] around every tick (self-contained —
/// no global gauges, so concurrent measurements cannot bleed into each
/// other).
pub fn measure_point(
    sess: &Session,
    cfg: &SweepCfg,
    slots: usize,
    budget: usize,
    threads: usize,
) -> Result<CapacityPoint> {
    crate::tensor::set_threads(threads.max(1));
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: slots,
        token_budget: budget,
        prefix_cache: None,
        prefill_chunk: 0,
        spec: None,
    });
    let vocab = sess.spec.config.vocab;
    let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
    for id in 0..cfg.requests as u64 {
        let prompt: Vec<i32> =
            (0..cfg.prompt_len.max(1)).map(|_| rng.range(4, vocab) as i32).collect();
        sched.submit(Request {
            id,
            prompt,
            max_new: cfg.max_new.max(1),
            sampler: SamplerCfg { temperature: 0.0, ..SamplerCfg::default() },
            seed: cfg.seed ^ id,
            eos: None,
        })?;
    }
    let t0 = std::time::Instant::now();
    let mut peak = 0u64;
    let mut new_tokens = 0usize;
    while sched.pending() > 0 {
        let done = sched.tick(sess)?;
        new_tokens += done.iter().map(|c| c.tokens.len()).sum::<usize>();
        peak = peak.max(sched.kv_resident_bytes());
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(CapacityPoint {
        slots,
        token_budget: budget,
        threads,
        peak_kv_mib: peak as f64 / (1024.0 * 1024.0),
        tok_s: new_tokens as f64 / wall.max(1e-9),
    })
}

/// Run the full grid. Points are visited in (slots, budget, threads)
/// lexicographic order; the worker pool is restored to the process
/// default afterwards.
pub fn run_sweep(sess: &Session, cfg: &SweepCfg) -> Result<Vec<CapacityPoint>> {
    let mut points = Vec::new();
    for &slots in &cfg.slots_list {
        for &budget in &cfg.budget_list {
            for &threads in &cfg.threads_list {
                points.push(measure_point(sess, cfg, slots, budget, threads)?);
            }
        }
    }
    crate::tensor::set_threads(0); // restore the default pool width
    Ok(points)
}

/// Leave-one-out check: fit on all points but the last, report the
/// held-out point's relative errors as `(kv_rel_err, tps_rel_err)`.
pub fn holdout_rel_err(
    points: &[CapacityPoint],
    requests: usize,
    prompt_len: usize,
    max_new: usize,
) -> Result<(f64, f64)> {
    ensure!(points.len() >= 3, "holdout needs at least 3 sweep points");
    let (held, train) = points.split_last().expect("non-empty by the ensure");
    let m = CapacityModel::fit(train.to_vec(), requests, prompt_len, max_new)?;
    let kv_pred = m.predict_kv_mib(held.slots, held.token_budget, held.threads);
    let tps_pred = m.predict_tok_s(held.slots, held.token_budget, held.threads);
    Ok((
        (kv_pred - held.peak_kv_mib).abs() / held.peak_kv_mib.abs().max(1e-9),
        (tps_pred - held.tok_s).abs() / held.tok_s.abs().max(1e-9),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Engine;

    #[test]
    fn lstsq_recovers_exact_coefficients() {
        // y = 2 + 3a - 0.5b over a small grid
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for a in 0..4 {
            for b in 0..3 {
                rows.push(vec![1.0, a as f64, b as f64]);
                y.push(2.0 + 3.0 * a as f64 - 0.5 * b as f64);
            }
        }
        let x = lstsq(&rows, &y, 1e-9).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-6, "{x:?}");
        assert!((x[1] - 3.0).abs() < 1e-6, "{x:?}");
        assert!((x[2] + 0.5).abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn lstsq_rejects_degenerate_inputs() {
        assert!(lstsq(&[], &[], 0.0).is_err());
        assert!(lstsq(&[vec![1.0]], &[1.0, 2.0], 0.0).is_err());
        assert!(lstsq(&[vec![1.0], vec![1.0, 2.0]], &[1.0, 2.0], 0.0).is_err());
    }

    #[test]
    fn synthetic_fit_predicts_a_held_out_point() {
        // fabricate points obeying the model's own feature map exactly
        let (requests, prompt_len, max_new) = (8, 8, 8);
        let cost = prompt_len + max_new;
        let mk = |slots: usize, budget: usize, threads: usize| {
            let c = super::eff_conc(slots, budget, requests, cost);
            CapacityPoint {
                slots,
                token_budget: budget,
                threads,
                peak_kv_mib: 0.01 + 0.002 * c * cost as f64,
                tok_s: 50.0 + 40.0 * c + 5.0 * threads as f64,
            }
        };
        let points =
            vec![mk(1, 4096, 1), mk(2, 4096, 1), mk(4, 4096, 2), mk(6, 4096, 4), mk(8, 64, 1)];
        let (kv_err, tps_err) =
            holdout_rel_err(&points, requests, prompt_len, max_new).unwrap();
        assert!(kv_err < 1e-6, "kv holdout err {kv_err}");
        assert!(tps_err < 1e-6, "tps holdout err {tps_err}");
    }

    #[test]
    fn json_round_trips_the_fit() {
        let points = vec![
            CapacityPoint { slots: 1, token_budget: 64, threads: 1, peak_kv_mib: 0.5, tok_s: 10.0 },
            CapacityPoint { slots: 2, token_budget: 64, threads: 1, peak_kv_mib: 1.0, tok_s: 19.0 },
            CapacityPoint { slots: 4, token_budget: 64, threads: 2, peak_kv_mib: 2.0, tok_s: 40.0 },
        ];
        let m = CapacityModel::fit(points, 8, 8, 8).unwrap();
        let re = CapacityModel::from_json(&m.to_json()).unwrap();
        for (a, b) in m.kv_coef.iter().zip(&re.kv_coef) {
            assert!((a - b).abs() < 1e-12);
        }
        for (a, b) in m.tps_coef.iter().zip(&re.tps_coef) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(re.points.len(), m.points.len());
        assert_eq!(
            (re.requests, re.prompt_len, re.max_new),
            (m.requests, m.prompt_len, m.max_new)
        );
        // a prediction computed from the reloaded fit matches
        let a = m.predict_kv_mib(3, 64, 1);
        let b = re.predict_kv_mib(3, 64, 1);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn real_sweep_holdout_is_within_the_pinned_threshold() {
        // the acceptance bar, in-process: fit 3 measured points on the
        // tiny model, predict the 4th, require < 15% kv error
        let mut eng = Engine::host();
        let sess = crate::runtime::Session::create(&mut eng, "tiny", 5).unwrap();
        let cfg = SweepCfg {
            slots_list: vec![1, 2, 3, 4],
            budget_list: vec![4096],
            threads_list: vec![1],
            requests: 4,
            prompt_len: 6,
            max_new: 4,
            seed: 9,
        };
        let points = run_sweep(&sess, &cfg).unwrap();
        assert_eq!(points.len(), 4);
        let (kv_err, _tps_err) =
            holdout_rel_err(&points, cfg.requests, cfg.prompt_len, cfg.max_new).unwrap();
        assert!(kv_err < 0.15, "held-out peak_kv_mib off by {:.1}%", kv_err * 100.0);
    }
}
