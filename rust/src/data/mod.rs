//! Synthetic data substrate.
//!
//! The paper fine-tunes on Commonsense170K/MATH10K/Alpaca-GPT4 and
//! pre-trains on C4 — none of which are available in this offline,
//! CPU-only environment. Per DESIGN.md Sec. 4 we substitute:
//!
//! - [`corpus`]: a Zipf–Markov token stream with learnable bigram
//!   structure (the C4 stand-in; perplexity decreases smoothly and the
//!   optimizer ordering is preserved).
//! - [`tasks`]: twelve seq-to-seq task families — eight
//!   "commonsense-shaped" and four "math-shaped" — evaluated by exact
//!   match, giving the per-task accuracy columns of Tables 1/3/4.
//! - [`loader`]: batching/splitting into the fixed `[b, s]` shapes the
//!   AOT graphs were lowered with.

pub mod corpus;
pub mod loader;
pub mod tasks;

pub use corpus::MarkovCorpus;
pub use loader::{Batch, Loader};
pub use tasks::{Task, TaskKind};

/// Reserved token ids (shared by all vocabularies; vocab >= 64).
pub mod tok {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const SEP: i32 = 2;
    pub const EOS: i32 = 3;
    pub const YES: i32 = 4;
    pub const NO: i32 = 5;
    pub const FIRST: i32 = 6; // "answer is first operand"
    pub const SECOND: i32 = 7; // "answer is second operand"
    /// digits 0..9 → tokens 8..=17
    pub const DIGIT0: i32 = 8;
    /// task-marker tokens 18..=31
    pub const TASK0: i32 = 18;
    /// symbol alphabet starts here
    pub const SYM0: i32 = 32;
}
