//! Batching into the fixed `[b, s]` shapes the AOT graphs expect.

use crate::data::corpus::MarkovCorpus;
use crate::data::tasks::{encode, Task, TaskKind};
use crate::util::Rng;

/// One training/eval batch, row-major `[b, s]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    /// per-row task kind (None for LM batches) — used by exact-match eval
    pub kinds: Vec<Option<TaskKind>>,
}

impl Batch {
    fn empty(b: usize, s: usize) -> Self {
        Batch {
            batch: b,
            seq_len: s,
            tokens: vec![0; b * s],
            targets: vec![0; b * s],
            mask: vec![0.0; b * s],
            kinds: vec![None; b],
        }
    }
}

/// Data source behind a loader.
enum Source {
    /// Language-model stream: mask = 1 everywhere (pre-training).
    Lm(MarkovCorpus),
    /// Mixture of task families (fine-tuning / instruction tuning).
    Tasks { tasks: Vec<Task>, rng: Rng },
}

/// Batch generator. Train/val splits use disjoint seed namespaces so the
/// validation stream is never trained on.
pub struct Loader {
    batch: usize,
    seq_len: usize,
    source: Source,
}

impl Loader {
    /// Pre-training LM loader over the Zipf-Markov corpus.
    pub fn lm(vocab: usize, batch: usize, seq_len: usize, seed: u64) -> Self {
        Loader { batch, seq_len, source: Source::Lm(MarkovCorpus::new(vocab, seed)) }
    }

    /// Task-mixture loader over the given families.
    pub fn tasks(kinds: &[TaskKind], vocab: usize, batch: usize, seq_len: usize,
                 seed: u64) -> Self {
        let tasks = kinds.iter().map(|&k| Task::new(k, vocab)).collect();
        Loader { batch, seq_len, source: Source::Tasks { tasks, rng: Rng::new(seed) } }
    }

    /// Single-family loader (per-task eval sets).
    pub fn single_task(kind: TaskKind, vocab: usize, batch: usize, seq_len: usize,
                       seed: u64) -> Self {
        Self::tasks(&[kind], vocab, batch, seq_len, seed)
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq_len)
    }

    /// Produce the next batch.
    pub fn next_batch(&mut self) -> Batch {
        let mut out = Batch::empty(self.batch, self.seq_len);
        match &mut self.source {
            Source::Lm(corpus) => {
                for row in 0..self.batch {
                    // sample s+1 tokens; input = [..s], target = [1..]
                    let mut seq = vec![0i32; self.seq_len + 1];
                    corpus.fill_sequence(&mut seq);
                    let o = row * self.seq_len;
                    out.tokens[o..o + self.seq_len].copy_from_slice(&seq[..self.seq_len]);
                    out.targets[o..o + self.seq_len].copy_from_slice(&seq[1..]);
                    for m in &mut out.mask[o..o + self.seq_len] {
                        *m = 1.0;
                    }
                }
            }
            Source::Tasks { tasks, rng } => {
                for row in 0..self.batch {
                    let task = &tasks[rng.below(tasks.len())];
                    let (tokens, targets, mask) = loop {
                        let ex = task.generate(rng);
                        if let Some(enc) = encode(&ex, self.seq_len) {
                            break enc;
                        }
                    };
                    let o = row * self.seq_len;
                    out.tokens[o..o + self.seq_len].copy_from_slice(&tokens);
                    out.targets[o..o + self.seq_len].copy_from_slice(&targets);
                    out.mask[o..o + self.seq_len].copy_from_slice(&mask);
                    out.kinds[row] = Some(task.kind);
                }
            }
        }
        out
    }
}

/// Exact-match accuracy from the predict graph's `correct` output:
/// a row counts as correct iff every supervised position is correct.
pub fn exact_match(batch: &Batch, correct: &[f32]) -> (usize, usize) {
    assert_eq!(correct.len(), batch.batch * batch.seq_len);
    let mut hits = 0;
    for row in 0..batch.batch {
        let o = row * batch.seq_len;
        let ok = (0..batch.seq_len).all(|i| {
            batch.mask[o + i] == 0.0 || correct[o + i] > 0.5
        });
        if ok {
            hits += 1;
        }
    }
    (hits, batch.batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tok;

    #[test]
    fn lm_batches_full_mask_and_shift() {
        let mut l = Loader::lm(256, 3, 16, 1);
        let b = l.next_batch();
        assert_eq!(b.tokens.len(), 48);
        assert!(b.mask.iter().all(|&m| m == 1.0));
        // target row is input row shifted by one
        for row in 0..3 {
            let o = row * 16;
            assert_eq!(&b.tokens[o + 1..o + 16], &b.targets[o..o + 15]);
        }
    }

    #[test]
    fn task_batches_have_answer_masks() {
        let mut l = Loader::tasks(&TaskKind::ALL, 256, 8, 64, 2);
        let b = l.next_batch();
        for row in 0..8 {
            let o = row * 64;
            let n: f32 = b.mask[o..o + 64].iter().sum();
            assert!(n >= 1.0, "row {row} has empty mask");
            assert!(b.kinds[row].is_some());
            assert_eq!(b.tokens[o], tok::BOS);
        }
    }

    #[test]
    fn exact_match_counts_rows() {
        let mut l = Loader::single_task(TaskKind::Copy, 256, 4, 32, 3);
        let b = l.next_batch();
        // all-correct prediction
        let all = vec![1.0f32; 4 * 32];
        assert_eq!(exact_match(&b, &all), (4, 4));
        // break one masked position of row 2
        let mut some = all.clone();
        let o = 2 * 32;
        let pos = (0..32).find(|&i| b.mask[o + i] == 1.0).unwrap();
        some[o + pos] = 0.0;
        assert_eq!(exact_match(&b, &some), (3, 4));
    }

    #[test]
    fn disjoint_seeds_give_disjoint_streams() {
        let mut a = Loader::tasks(&[TaskKind::Add], 256, 4, 32, 10);
        let mut b = Loader::tasks(&[TaskKind::Add], 256, 4, 32, 11);
        assert_ne!(a.next_batch().tokens, b.next_batch().tokens);
    }

    #[test]
    fn property_batch_tokens_in_vocab() {
        crate::prop!("loader_vocab", |rng| {
            let vocab = rng.range(64, 512);
            let mut l = Loader::tasks(&TaskKind::ALL, vocab, 2, 64, rng.next_u64());
            let b = l.next_batch();
            for &t in b.tokens.iter().chain(&b.targets) {
                assert!((t as usize) < vocab);
            }
        });
    }
}
