//! Synthetic task families — the fine-tuning benchmark substitute.
//!
//! Eight "commonsense-shaped" families stand in for the paper's
//! BoolQ/PIQA/SIQA/HellaSwag/WinoGrande/ARC-e/ARC-c/OBQA columns and four
//! "math-shaped" families for GSM8K/SVAMP/AQuA/MAWPS. Each family emits
//! `[BOS, marker, input…, SEP, answer…, EOS]` sequences; training
//! supervises only the answer span (teacher forcing) and evaluation is
//! exact match over it — the same row/column structure as paper
//! Tables 1/3/4, produced by real transformer gradients.

use crate::data::tok;
use crate::util::Rng;

/// Task families. The first eight are the commonsense suite, the last
/// four the math suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Copy,
    Reverse,
    SortAsc,
    MaxSym,
    Parity,
    Membership,
    Compare,
    Dedup,
    Add,
    Sub,
    Mul1,
    Mod,
}

impl TaskKind {
    pub const COMMONSENSE: [TaskKind; 8] = [
        TaskKind::Copy,
        TaskKind::Reverse,
        TaskKind::SortAsc,
        TaskKind::MaxSym,
        TaskKind::Parity,
        TaskKind::Membership,
        TaskKind::Compare,
        TaskKind::Dedup,
    ];

    pub const MATH: [TaskKind; 4] =
        [TaskKind::Add, TaskKind::Sub, TaskKind::Mul1, TaskKind::Mod];

    pub const ALL: [TaskKind; 12] = [
        TaskKind::Copy,
        TaskKind::Reverse,
        TaskKind::SortAsc,
        TaskKind::MaxSym,
        TaskKind::Parity,
        TaskKind::Membership,
        TaskKind::Compare,
        TaskKind::Dedup,
        TaskKind::Add,
        TaskKind::Sub,
        TaskKind::Mul1,
        TaskKind::Mod,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Copy => "Copy",
            TaskKind::Reverse => "Rev",
            TaskKind::SortAsc => "Sort",
            TaskKind::MaxSym => "Max",
            TaskKind::Parity => "Parity",
            TaskKind::Membership => "Member",
            TaskKind::Compare => "Cmp",
            TaskKind::Dedup => "Dedup",
            TaskKind::Add => "Add",
            TaskKind::Sub => "Sub",
            TaskKind::Mul1 => "Mul1",
            TaskKind::Mod => "Mod",
        }
    }

    pub fn marker(&self) -> i32 {
        tok::TASK0 + Self::ALL.iter().position(|t| t == self).unwrap() as i32
    }
}

/// One generated example: raw input and answer token streams.
#[derive(Clone, Debug)]
pub struct Example {
    pub input: Vec<i32>,
    pub answer: Vec<i32>,
    pub kind: TaskKind,
}

/// Task-family example generator over a given symbol alphabet.
pub struct Task {
    pub kind: TaskKind,
    sym_lo: i32,
    sym_hi: i32,
}

fn digits_of(mut n: u32) -> Vec<i32> {
    // most-significant first; 0 encodes as a single digit
    let mut ds = Vec::new();
    loop {
        ds.push(tok::DIGIT0 + (n % 10) as i32);
        n /= 10;
        if n == 0 {
            break;
        }
    }
    ds.reverse();
    ds
}

impl Task {
    /// `vocab` bounds the symbol alphabet; symbols live in
    /// `[SYM0, vocab)`, capped at 64 distinct symbols so every family is
    /// learnable by the small fine-tuning models.
    pub fn new(kind: TaskKind, vocab: usize) -> Self {
        let sym_lo = tok::SYM0;
        let sym_hi = (vocab as i32).min(sym_lo + 64);
        assert!(sym_hi > sym_lo + 8, "vocab {vocab} too small for tasks");
        Task { kind, sym_lo, sym_hi }
    }

    fn sym(&self, rng: &mut Rng) -> i32 {
        rng.range(self.sym_lo as usize, self.sym_hi as usize) as i32
    }

    fn syms(&self, n: usize, rng: &mut Rng) -> Vec<i32> {
        (0..n).map(|_| self.sym(rng)).collect()
    }

    /// Generate one example.
    pub fn generate(&self, rng: &mut Rng) -> Example {
        let kind = self.kind;
        let (input, answer) = match kind {
            TaskKind::Copy => {
                let xs = self.syms(rng.range(3, 9), rng);
                (xs.clone(), xs)
            }
            TaskKind::Reverse => {
                let xs = self.syms(rng.range(3, 9), rng);
                let mut a = xs.clone();
                a.reverse();
                (xs, a)
            }
            TaskKind::SortAsc => {
                let xs = self.syms(rng.range(3, 8), rng);
                let mut a = xs.clone();
                a.sort_unstable();
                (xs, a)
            }
            TaskKind::MaxSym => {
                let xs = self.syms(rng.range(3, 9), rng);
                let m = *xs.iter().max().unwrap();
                (xs, vec![m])
            }
            TaskKind::Parity => {
                // is the count of the probe symbol even?
                let probe = self.sym(rng);
                let mut xs = self.syms(rng.range(4, 10), rng);
                // plant the probe a random number of times
                let plant = rng.range(0, 4);
                for _ in 0..plant {
                    let pos = rng.below(xs.len());
                    xs[pos] = probe;
                }
                let count = xs.iter().filter(|&&x| x == probe).count();
                let ans = if count % 2 == 0 { tok::YES } else { tok::NO };
                let mut input = vec![probe, tok::SEP];
                input.extend(&xs);
                (input, vec![ans])
            }
            TaskKind::Membership => {
                let set = self.syms(rng.range(3, 7), rng);
                let inside = rng.f64() < 0.5;
                let probe = if inside {
                    set[rng.below(set.len())]
                } else {
                    // rejection-sample an absent symbol
                    loop {
                        let c = self.sym(rng);
                        if !set.contains(&c) {
                            break c;
                        }
                    }
                };
                let ans = if set.contains(&probe) { tok::YES } else { tok::NO };
                let mut input = vec![probe, tok::SEP];
                input.extend(&set);
                (input, vec![ans])
            }
            TaskKind::Compare => {
                let a = rng.range(0, 1000) as u32;
                let b = loop {
                    let b = rng.range(0, 1000) as u32;
                    if b != a {
                        break b;
                    }
                };
                let mut input = digits_of(a);
                input.push(tok::SEP);
                input.extend(digits_of(b));
                let ans = if a > b { tok::FIRST } else { tok::SECOND };
                (input, vec![ans])
            }
            TaskKind::Dedup => {
                // emit first occurrences in order
                let xs = self.syms(rng.range(4, 10), rng);
                let mut seen = Vec::new();
                for &x in &xs {
                    if !seen.contains(&x) {
                        seen.push(x);
                    }
                }
                (xs, seen)
            }
            TaskKind::Add => {
                let a = rng.range(0, 500) as u32;
                let b = rng.range(0, 500) as u32;
                let mut input = digits_of(a);
                input.push(tok::SEP);
                input.extend(digits_of(b));
                (input, digits_of(a + b))
            }
            TaskKind::Sub => {
                let a = rng.range(0, 1000) as u32;
                let b = rng.range(0, a as usize + 1) as u32;
                let mut input = digits_of(a);
                input.push(tok::SEP);
                input.extend(digits_of(b));
                (input, digits_of(a - b))
            }
            TaskKind::Mul1 => {
                let a = rng.range(0, 200) as u32;
                let b = rng.range(2, 10) as u32;
                let mut input = digits_of(a);
                input.push(tok::SEP);
                input.extend(digits_of(b));
                (input, digits_of(a * b))
            }
            TaskKind::Mod => {
                let a = rng.range(0, 1000) as u32;
                let b = rng.range(2, 10) as u32;
                let mut input = digits_of(a);
                input.push(tok::SEP);
                input.extend(digits_of(b));
                (input, digits_of(a % b))
            }
        };
        Example { input, answer, kind }
    }

    /// Solve an example independently (oracle used by tests).
    #[cfg(test)]
    pub fn oracle(example: &Example) -> &[i32] {
        &example.answer
    }
}

/// Encode an example into fixed-length (tokens, targets, mask) rows.
///
/// Layout: `[BOS, marker, input…, SEP, answer…, EOS, PAD…]`.
/// `targets[i] = tokens[i+1]`; mask=1 exactly on positions predicting
/// the answer span and the EOS.
pub fn encode(example: &Example, seq_len: usize) -> Option<(Vec<i32>, Vec<i32>, Vec<f32>)> {
    let mut seq = Vec::with_capacity(seq_len + 1);
    seq.push(tok::BOS);
    seq.push(example.kind.marker());
    seq.extend(&example.input);
    seq.push(tok::SEP);
    let answer_start = seq.len(); // first answer position in `seq`
    seq.extend(&example.answer);
    seq.push(tok::EOS);
    if seq.len() > seq_len + 1 {
        return None; // does not fit; caller regenerates
    }
    let answer_end = seq.len(); // one past EOS
    seq.resize(seq_len + 1, tok::PAD);
    let tokens = seq[..seq_len].to_vec();
    let targets = seq[1..=seq_len].to_vec();
    let mut mask = vec![0.0f32; seq_len];
    // position i predicts seq[i+1]; supervise i where i+1 in answer span
    for i in answer_start - 1..answer_end - 1 {
        mask[i] = 1.0;
    }
    Some((tokens, targets, mask))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(0xDA7A)
    }

    #[test]
    fn all_families_generate_and_encode() {
        let mut r = rng();
        for kind in TaskKind::ALL {
            let t = Task::new(kind, 256);
            for _ in 0..50 {
                let ex = t.generate(&mut r);
                assert!(!ex.answer.is_empty(), "{kind:?}");
                let (tokens, targets, mask) = encode(&ex, 64).expect("fits");
                assert_eq!(tokens.len(), 64);
                assert_eq!(targets.len(), 64);
                assert_eq!(mask.len(), 64);
                // mask covers answer + EOS
                let n_mask = mask.iter().filter(|&&m| m == 1.0).count();
                assert_eq!(n_mask, ex.answer.len() + 1, "{kind:?}");
                // masked targets reproduce the answer then EOS
                let got: Vec<i32> = (0..64).filter(|&i| mask[i] == 1.0)
                    .map(|i| targets[i]).collect();
                let mut want = ex.answer.clone();
                want.push(tok::EOS);
                assert_eq!(got, want, "{kind:?}");
            }
        }
    }

    #[test]
    fn markers_unique() {
        let mut seen = std::collections::HashSet::new();
        for kind in TaskKind::ALL {
            assert!(seen.insert(kind.marker()));
            assert!(kind.marker() < tok::SYM0);
        }
    }

    #[test]
    fn compare_answers_are_consistent() {
        let mut r = rng();
        let t = Task::new(TaskKind::Compare, 256);
        for _ in 0..200 {
            let ex = t.generate(&mut r);
            assert!(ex.answer[0] == tok::FIRST || ex.answer[0] == tok::SECOND);
        }
    }

    #[test]
    fn add_is_correct() {
        // decode digits back and check arithmetic
        let mut r = rng();
        let t = Task::new(TaskKind::Add, 256);
        for _ in 0..200 {
            let ex = t.generate(&mut r);
            let sep = ex.input.iter().position(|&x| x == tok::SEP).unwrap();
            let val = |ds: &[i32]| ds.iter().fold(0u32, |acc, &d| acc * 10 + (d - tok::DIGIT0) as u32);
            let a = val(&ex.input[..sep]);
            let b = val(&ex.input[sep + 1..]);
            assert_eq!(val(&ex.answer), a + b);
        }
    }

    #[test]
    fn encode_rejects_overlong() {
        let ex = Example { input: vec![tok::SYM0; 100], answer: vec![tok::SYM0], kind: TaskKind::Copy };
        assert!(encode(&ex, 64).is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let t = Task::new(TaskKind::Dedup, 256);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..20 {
            let e1 = t.generate(&mut r1);
            let e2 = t.generate(&mut r2);
            assert_eq!(e1.input, e2.input);
            assert_eq!(e1.answer, e2.answer);
        }
    }

    #[test]
    fn property_answers_within_vocab() {
        crate::prop!("task_vocab", |rng| {
            let vocab = rng.range(48, 512);
            let kind = TaskKind::ALL[rng.below(12)];
            let t = Task::new(kind, vocab);
            let ex = t.generate(rng);
            for &x in ex.input.iter().chain(&ex.answer) {
                assert!(x >= 0 && (x as usize) < vocab, "{kind:?} token {x}");
            }
        });
    }
}
