//! Zipf–Markov synthetic pre-training corpus (the C4 stand-in).
//!
//! Each token has K fixed successor candidates (drawn deterministically
//! from the corpus seed) weighted by a Zipf law. The resulting stream has
//! (a) a skewed unigram distribution and (b) strong bigram structure a
//! language model can learn, so validation perplexity falls smoothly
//! from ln(V)-ish toward the transition entropy — which is what Table 6 /
//! Fig. 4 need: a workload where optimizer quality shows up as a
//! perplexity gap, not absolute C4 numbers.

use crate::data::tok;
use crate::util::Rng;

/// Number of successor candidates per state.
const SUCCESSORS: usize = 8;
/// Zipf exponent for successor weights.
const ZIPF_S: f64 = 1.3;

pub struct MarkovCorpus {
    vocab: usize,
    /// successors[t] = the K candidate next-tokens of t
    successors: Vec<[i32; SUCCESSORS]>,
    /// cumulative Zipf weights over the K candidates
    cdf: [f64; SUCCESSORS],
    state: i32,
    rng: Rng,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab > tok::SYM0 as usize + 8, "vocab too small for corpus");
        let mut structure_rng = Rng::new(seed ^ 0x5EED_C0DE);
        let lo = tok::SYM0 as usize;
        let mut successors = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut succ = [0i32; SUCCESSORS];
            for s in succ.iter_mut() {
                *s = structure_rng.range(lo, vocab) as i32;
            }
            successors.push(succ);
        }
        let mut weights = [0.0f64; SUCCESSORS];
        for (k, w) in weights.iter_mut().enumerate() {
            *w = 1.0 / ((k + 1) as f64).powf(ZIPF_S);
        }
        let total: f64 = weights.iter().sum();
        let mut cdf = [0.0f64; SUCCESSORS];
        let mut acc = 0.0;
        for k in 0..SUCCESSORS {
            acc += weights[k] / total;
            cdf[k] = acc;
        }
        let mut rng = Rng::new(seed);
        let state = rng.range(lo, vocab) as i32;
        MarkovCorpus { vocab, successors, cdf, state, rng }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Entropy (nats) of the transition distribution — the perplexity
    /// floor a perfect bigram model reaches: exp(H) ≈ 3.0 for K=8, s=1.3.
    pub fn transition_entropy(&self) -> f64 {
        let mut weights = [0.0f64; SUCCESSORS];
        for (k, w) in weights.iter_mut().enumerate() {
            *w = 1.0 / ((k + 1) as f64).powf(ZIPF_S);
        }
        let total: f64 = weights.iter().sum();
        -weights.iter().map(|w| (w / total) * (w / total).ln()).sum::<f64>()
    }

    pub fn next_token(&mut self) -> i32 {
        let u = self.rng.f64();
        let k = self.cdf.iter().position(|&c| u <= c).unwrap_or(SUCCESSORS - 1);
        // duplicate candidates merge probability mass — fine, still Markov
        self.state = self.successors[self.state as usize][k];
        self.state
    }

    /// Fill one sequence of length `s` (continuous stream, no BOS).
    pub fn fill_sequence(&mut self, out: &mut [i32]) {
        for x in out.iter_mut() {
            *x = self.next_token();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_symbol_range() {
        let mut c = MarkovCorpus::new(256, 1);
        for _ in 0..1000 {
            let t = c.next_token();
            assert!(t >= tok::SYM0 && (t as usize) < 256);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = MarkovCorpus::new(256, 7);
        let mut b = MarkovCorpus::new(256, 7);
        let mut xa = vec![0i32; 64];
        let mut xb = vec![0i32; 64];
        a.fill_sequence(&mut xa);
        b.fill_sequence(&mut xb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = MarkovCorpus::new(256, 7);
        let mut b = MarkovCorpus::new(256, 8);
        let mut xa = vec![0i32; 64];
        let mut xb = vec![0i32; 64];
        a.fill_sequence(&mut xa);
        b.fill_sequence(&mut xb);
        assert_ne!(xa, xb);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // empirical successor support of each observed state is small
        let mut c = MarkovCorpus::new(256, 3);
        let mut succ: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        let mut prev = c.next_token();
        for _ in 0..50_000 {
            let t = c.next_token();
            succ.entry(prev).or_default().insert(t);
            prev = t;
        }
        let max_support = succ.values().map(|s| s.len()).max().unwrap();
        assert!(max_support <= SUCCESSORS, "support {max_support}");
    }

    #[test]
    fn entropy_floor_reasonable() {
        let c = MarkovCorpus::new(256, 1);
        let h = c.transition_entropy();
        assert!(h > 0.5 && h < (SUCCESSORS as f64).ln() + 1e-9, "H={h}");
    }
}
