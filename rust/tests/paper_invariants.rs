//! Cross-module property tests for the paper's formal claims, at paper
//! scale (no artifacts needed — pure coordinator math).

use misa::memory::{self, Arch, Method, Workload};
use misa::optim::sampler::{
    importance_objective, softmax_tempered, ImportanceSampler, SamplerConfig, Strategy,
};
use misa::optim::{AdamHyper, AdamState};
use misa::util::Rng;

/// Theorem 1 shape on a controllable problem: MISA-style block-Adam on a
/// separable quadratic converges, and the average gradient norm over
/// training decays as N grows.
#[test]
fn misa_dynamics_converge_on_quadratic() {
    // f(x) = 0.5 sum_b w_b ||x_b - c_b||^2, B blocks, skewed curvatures
    let b_count = 12;
    let dim = 24;
    let mut rng = Rng::new(7);
    let weights: Vec<f32> = (0..b_count).map(|i| 0.2 + i as f32 * 0.35).collect();
    let targets: Vec<Vec<f32>> = (0..b_count)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();
    let mut run = |n_outer: usize, t_inner: usize, seed: u64| -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut x = vec![vec![0.0f32; dim]; b_count];
        let mut sampler = ImportanceSampler::new(
            SamplerConfig {
                strategy: Strategy::Importance { eta: 1.0 },
                delta: 0.25,
                ..Default::default()
            },
            vec![dim as u64; b_count],
            (b_count * dim) as u64,
        );
        let mut trace: Vec<f64> = Vec::new();
        for _ in 0..n_outer {
            let active = sampler.select(&mut rng);
            let mut states: Vec<AdamState> =
                active.iter().map(|_| AdamState::zeros(dim)).collect();
            let mut accum = vec![0.0f64; active.len()];
            for _ in 0..t_inner {
                // full-gradient norm for the convergence metric
                let mut total = 0.0f64;
                for b in 0..b_count {
                    for d in 0..dim {
                        let g = weights[b] * (x[b][d] - targets[b][d]);
                        total += (g as f64) * (g as f64);
                    }
                }
                trace.push(total);
                for (slot, &b) in active.iter().enumerate() {
                    let g: Vec<f32> = (0..dim)
                        .map(|d| {
                            let noise = (rng.normal() as f32) * 0.01;
                            weights[b] * (x[b][d] - targets[b][d]) + noise
                        })
                        .collect();
                    let sq: f64 = g.iter().map(|&v| (v as f64) * (v as f64)).sum();
                    accum[slot] += sq / dim as f64;
                    states[slot].step(&mut x[b], &g, 0.01, AdamHyper::default());
                }
            }
            for (slot, &b) in active.iter().enumerate() {
                states[slot].momentum_tail(&mut x[b], 0.01, AdamHyper::default());
                sampler.update_score(b, accum[slot] / t_inner as f64);
            }
            // states dropped here = Alg. 1 line 17
        }
        trace
    };
    let trace = run(60, 10, 1);
    let head: f64 = trace[..60].iter().sum::<f64>() / 60.0;
    let tail: f64 = trace[trace.len() - 60..].iter().sum::<f64>() / 60.0;
    assert!(
        tail < head * 0.2,
        "avg grad^2 did not decay over training: head {head}, tail {tail}"
    );
}

/// Importance sampling must reach targets faster (in block updates) than
/// Bottom-K on the same problem — the Table 10 ordering, distilled.
#[test]
fn importance_beats_bottomk_on_skewed_quadratic() {
    let b_count = 10;
    let dim = 16;
    let run = |strategy: Strategy| -> f64 {
        let mut rng = Rng::new(3);
        // one block carries most of the objective
        let weights: Vec<f32> = (0..b_count)
            .map(|i| if i == 4 { 10.0 } else { 0.05 })
            .collect();
        let mut x = vec![vec![1.0f32; dim]; b_count];
        let mut sampler = ImportanceSampler::new(
            SamplerConfig { strategy, delta: 0.12, ..Default::default() },
            vec![dim as u64; b_count],
            (b_count * dim) as u64,
        );
        for _ in 0..40 {
            let active = sampler.select(&mut rng);
            let mut states: Vec<AdamState> =
                active.iter().map(|_| AdamState::zeros(dim)).collect();
            let mut accum = vec![0.0f64; active.len()];
            for _ in 0..5 {
                for (slot, &b) in active.iter().enumerate() {
                    let g: Vec<f32> = x[b].iter().map(|&v| weights[b] * v).collect();
                    accum[slot] += g.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
                    states[slot].step(&mut x[b], &g, 0.1, AdamHyper::default());
                }
            }
            for (slot, &b) in active.iter().enumerate() {
                sampler.update_score(b, accum[slot] / 5.0);
            }
        }
        // final objective
        (0..b_count)
            .map(|b| {
                0.5 * weights[b] as f64
                    * x[b].iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            })
            .sum()
    };
    let imp = run(Strategy::Importance { eta: 2.0 });
    let bot = run(Strategy::BottomK);
    assert!(imp < bot, "importance {imp} not better than bottom-k {bot}");
}

/// Proposition 2 at paper shape: module-wise softmax dominates any
/// layer-uniform split for every eta, over randomized score profiles.
#[test]
fn prop2_dominance_paper_shape() {
    let mut rng = Rng::new(11);
    for _ in 0..300 {
        let layers = 32;
        let k = 7;
        let scores: Vec<f64> = (0..layers * k).map(|_| rng.f64() * 2.0).collect();
        let eta = rng.f64() * 5.0;
        let layer_scores: Vec<f64> = (0..layers)
            .map(|l| scores[l * k..(l + 1) * k].iter().sum::<f64>() / k as f64)
            .collect();
        let lp = softmax_tempered(&layer_scores, eta);
        let spread: Vec<f64> = (0..layers * k).map(|i| lp[i / k] / k as f64).collect();
        let mp = softmax_tempered(&scores, eta);
        assert!(
            importance_objective(&mp, &scores)
                >= importance_objective(&spread, &scores) - 1e-9
        );
    }
}

/// The Mem.(GB) columns of Tables 1/3/4/5/6: orderings at each paper
/// architecture must match the published ones.
#[test]
fn paper_table_memory_orderings() {
    // Table 5 workload: batch 2
    for arch in [Arch::tinyllama(), Arch::llama2_7b(), Arch::mistral_7b()] {
        let w = Workload::new(2, 512);
        let gb = |m| memory::table_peak_gib(m, &arch, &w);
        // LISA > BAdam, MISA (paper Table 5 per model)
        assert!(gb(Method::Lisa) > gb(Method::BAdam));
        assert!(gb(Method::Lisa) > gb(Method::Misa { delta: 0.03 }));
        assert!(gb(Method::Misa { delta: 0.03 }) <= gb(Method::BAdam) * 1.01);
    }
    // Table 6: MISA(3%) below GaLore(r=32) below Adam at pretraining archs
    for arch in [Arch::llama_130m(), Arch::llama_350m()] {
        let w = Workload::new(32, 256);
        let gb = |m| memory::table_peak_gib(m, &arch, &w);
        assert!(gb(Method::Misa { delta: 0.03 }) < gb(Method::Galore { r: 32 }));
        assert!(gb(Method::Galore { r: 32 }) < gb(Method::FullFT));
        assert!(gb(Method::Misa { delta: 0.25 }) < gb(Method::FullFT));
    }
}

/// Eq. 4 EMA + Cor. 1: scores stay bounded by the max observation, so
/// probabilities never collapse to zero (exploration is preserved).
#[test]
fn ema_bounded_and_probabilities_floored() {
    let mut rng = Rng::new(17);
    let mut s = ImportanceSampler::new(
        SamplerConfig {
            strategy: Strategy::Importance { eta: 2.0 },
            delta: 0.1,
            ..Default::default()
        },
        vec![100; 30],
        6000,
    );
    let bound = 5.0;
    for _ in 0..2000 {
        let m = rng.below(30);
        s.update_score(m, rng.f64() * bound);
    }
    for &g in &s.scores {
        assert!(g <= bound + 1e-9);
    }
    let floor = s.probability_lower_bound();
    assert!(floor > 0.0);
    for &p in &s.probabilities() {
        assert!(p >= floor - 1e-12);
    }
}
