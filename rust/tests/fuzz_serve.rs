//! Fuzz-harness integration tests: the three differential targets at
//! moderate op counts, plus directed cancellation scenarios the random
//! streams only hit by chance (mid-prefill-round, mid-spec-draft).
//!
//! The targets honor `MISA_FUZZ_SEED` / `MISA_FUZZ_OPS`, so a CI
//! failure's printed replay command reproduces here verbatim:
//! `MISA_FUZZ_SEED=0x… MISA_FUZZ_OPS=… cargo test --test fuzz_serve <target>`.

use misa::fuzz::{self, FuzzCfg, SchedFuzzCfg};
use misa::runtime::{Engine, Session};
use misa::serve::{generate, FinishReason, GenerateCfg, Request, SamplerCfg};
use misa::serve::{Scheduler, SchedulerCfg, SpecCfg};

/// Serialize tests that resize the global worker pool — resizing is
/// bit-identical by contract, but keeping one writer at a time makes
/// failures attributable.
static THREAD_KNOB: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn env_cfg(default_seed: u64, default_ops: usize) -> FuzzCfg {
    FuzzCfg::from_env(default_seed, default_ops)
}

#[test]
fn fuzz_kvcache_target_is_clean() {
    let cfg = env_cfg(0x51, 3000);
    let stats = fuzz::run_target("kvcache", cfg, || fuzz::fuzz_kvcache(cfg)).unwrap();
    assert_eq!(stats.ops, cfg.ops);
    assert!(stats.checks as usize > cfg.ops, "every op must check invariants");
}

#[test]
fn fuzz_trie_target_is_clean() {
    let cfg = env_cfg(0x52, 3000);
    let stats = fuzz::run_target("trie", cfg, || fuzz::fuzz_trie(cfg)).unwrap();
    assert_eq!(stats.ops, cfg.ops);
    assert!(stats.count("lookup_hit") > 0, "stream never exercised a cache hit");
    assert!(stats.count("insert_rejected") > 0, "stream never offered a bad donor");
}

#[test]
fn fuzz_scheduler_with_everything_on_is_clean() {
    let _guard = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = env_cfg(0x53, 220);
    let stats = fuzz::run_target("scheduler", cfg, || {
        fuzz::fuzz_scheduler(SchedFuzzCfg {
            fuzz: cfg,
            spec: true,
            prefix_cache: true,
            prefill_chunk: 3,
            resize_threads: true,
        })
    })
    .unwrap();
    assert!(stats.count("verified_exact") > 0, "no stream survived to be replay-checked");
    assert!(stats.count("cancel") > 0, "stream never cancelled anything");
}

#[test]
fn fuzz_scheduler_plain_is_clean() {
    let cfg = env_cfg(0x54, 180);
    let stats = fuzz::run_target("scheduler", cfg, || {
        fuzz::fuzz_scheduler(SchedFuzzCfg {
            fuzz: cfg,
            spec: false,
            prefix_cache: false,
            prefill_chunk: 0,
            resize_threads: false,
        })
    })
    .unwrap();
    assert!(stats.count("verified_exact") > 0);
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request {
        id,
        prompt,
        max_new,
        sampler: SamplerCfg { temperature: 0.7, top_k: 16, top_p: 0.9 },
        seed: 1000 + id,
        eos: None,
    }
}

fn solo(sess: &Session, r: &Request) -> Vec<i32> {
    generate(
        sess,
        &r.prompt,
        &GenerateCfg {
            max_new: r.max_new,
            sampler: r.sampler,
            seed: r.seed,
            eos: r.eos,
            spec: None,
        },
    )
    .unwrap()
    .tokens
}

/// Cancelling a job whose prompt is mid-prefill (chunked, partially
/// resident) must release its budget and its ring immediately, and the
/// survivor's output must be bit-identical to a solo run.
#[test]
fn cancel_mid_prefill_round_releases_budget_and_ring() {
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 31).unwrap();
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 2,
        token_budget: 64,
        prefix_cache: None,
        prefill_chunk: 2, // a 6-token prompt needs 3 ticks of prefill
        spec: None,
    });
    let a = req(0, vec![1, 5, 6, 7, 8, 9], 3);
    let b = req(1, vec![1, 9, 8, 7, 6, 5], 3);
    sched.submit(a.clone()).unwrap();
    sched.submit(b.clone()).unwrap();
    let mut done = sched.tick(&sess).unwrap();
    assert!(done.is_empty(), "nothing can finish while prompts are mid-prefill");
    assert_eq!(sched.in_flight_tokens(), 2 * (6 + 3));

    let resident_before = sched.kv_resident_bytes();
    assert!(resident_before > 0, "prefill rings must be live");
    let c = sched.cancel(0).expect("request 0 is mid-prefill");
    assert_eq!(c.finish, FinishReason::Cancelled);
    assert!(c.tokens.is_empty(), "no tokens existed before first decode");
    assert_eq!(sched.in_flight_tokens(), 6 + 3, "cancel must release the job's charge");
    assert!(
        sched.kv_resident_bytes() < resident_before,
        "cancel must drop the job's partially prefilled ring"
    );

    while sched.pending() > 0 {
        done.extend(sched.tick(&sess).unwrap());
    }
    assert_eq!(sched.in_flight_tokens(), 0);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 1);
    assert_eq!(done[0].tokens, solo(&sess, &b), "survivor must be bit-identical");
}

/// Cancelling an actively speculating slot between ticks must return
/// the tokens generated so far (a prefix of the solo run), release the
/// budget, and leave the surviving speculative stream bit-identical.
#[test]
fn cancel_mid_spec_draft_keeps_survivors_bit_identical() {
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 32).unwrap();
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 2,
        token_budget: 128,
        prefix_cache: None,
        prefill_chunk: 0,
        spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
    });
    // repetitive prompts so the n-gram proposer actually drafts
    let a = req(0, vec![1, 4, 5, 4, 5, 4, 5], 16);
    let b = req(1, vec![1, 6, 7, 6, 7, 6, 7], 16);
    sched.submit(a.clone()).unwrap();
    sched.submit(b.clone()).unwrap();
    let mut done = sched.tick(&sess).unwrap(); // prefill + first token
    done.extend(sched.tick(&sess).unwrap()); // at least one spec tick
    assert!(done.is_empty(), "max_new 16 cannot finish in two ticks");

    let resident_before = sched.kv_resident_bytes();
    let c = sched.cancel(0).expect("request 0 is actively decoding");
    assert_eq!(c.finish, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty(), "the slot had decoded at least the first token");
    let full = solo(&sess, &a);
    assert!(
        c.tokens.len() < full.len() && full[..c.tokens.len()] == c.tokens[..],
        "cancelled mid-spec tokens must be a strict prefix of the solo run"
    );
    assert_eq!(sched.in_flight_tokens(), 7 + 16, "only the survivor's charge remains");
    assert!(sched.kv_resident_bytes() < resident_before, "the cancelled ring must drop");

    while sched.pending() > 0 {
        done.extend(sched.tick(&sess).unwrap());
    }
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 1);
    assert_eq!(done[0].tokens, solo(&sess, &b), "survivor must be bit-identical");
    assert_eq!(sched.in_flight_tokens(), 0);
}

/// The documented acceptance bar: the three targets together clear 10k
/// seeded ops with zero violations (kept at the CI smoke's scale but
/// under the env overrides so it shrinks/grows with them).
#[test]
fn combined_targets_clear_ten_thousand_ops() {
    let _guard = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let kv = env_cfg(0x60, 4200);
    let trie = env_cfg(0x61, 4200);
    let sched = env_cfg(0x62, 1600);
    let mut total = 0usize;
    total += fuzz::run_target("kvcache", kv, || fuzz::fuzz_kvcache(kv)).unwrap().ops;
    total += fuzz::run_target("trie", trie, || fuzz::fuzz_trie(trie)).unwrap().ops;
    total += fuzz::run_target("scheduler", sched, || {
        fuzz::fuzz_scheduler(SchedFuzzCfg {
            fuzz: sched,
            spec: true,
            prefix_cache: true,
            prefill_chunk: 3,
            resize_threads: false,
        })
    })
    .unwrap()
    .ops;
    if std::env::var("MISA_FUZZ_OPS").is_err() {
        assert!(total >= 10_000, "combined ops {total} < 10k");
    }
}
