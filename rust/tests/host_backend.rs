//! HostBackend numerics: finite-difference gradient checks against the
//! hand-derived backward pass, and the sq_norms/fused-update contracts
//! (`python/compile/kernels/ref.py` semantics).

use misa::data::Batch;
use misa::modelspec::{spec_for, ModelConfig, ModelSpec};
use misa::optim::{AdamHyper, AdamState};
use misa::runtime::{init_params, Backend, HostBackend};
use misa::util::Rng;

/// A micro model: 1 layer, GQA (2 query heads over 1 kv head), RoPE-even
/// head_dim — big enough to exercise every code path, small enough for
/// dense finite differencing.
fn micro_spec() -> ModelSpec {
    spec_for(ModelConfig {
        name: "micro".into(),
        vocab: 32,
        dim: 8,
        n_layers: 1,
        n_heads: 2,
        n_kv_heads: 1,
        ffn_dim: 12,
        seq_len: 4,
        batch: 2,
    })
}

/// A two-layer variant so cross-layer backprop (residual stream into a
/// lower layer) is also covered.
fn micro_spec_2l() -> ModelSpec {
    spec_for(ModelConfig {
        name: "micro2".into(),
        vocab: 32,
        dim: 8,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        ffn_dim: 10,
        seq_len: 4,
        batch: 2,
    })
}

fn random_batch(spec: &ModelSpec, seed: u64) -> Batch {
    let mc = &spec.config;
    let (b, s, v) = (mc.batch, mc.seq_len, mc.vocab);
    let mut rng = Rng::new(seed);
    let n = b * s;
    let tokens: Vec<i32> = (0..n).map(|_| rng.below(v) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|_| rng.below(v) as i32).collect();
    // mixed mask: some positions supervised, some not
    let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
    Batch { batch: b, seq_len: s, tokens, targets, mask, kinds: vec![None; b] }
}

/// Central finite difference of the f64 loss along one coordinate.
fn fd_at(be: &HostBackend, host: &[Vec<f32>], batch: &Batch, pi: usize, j: usize,
         eps: f32) -> f64 {
    let mut plus = host.to_vec();
    plus[pi][j] += eps;
    let mut minus = host.to_vec();
    minus[pi][j] -= eps;
    let lp = be.loss_f64(&plus, batch).unwrap();
    let lm = be.loss_f64(&minus, batch).unwrap();
    (lp - lm) / (2.0 * eps as f64)
}

#[test]
fn gradients_match_finite_differences_per_param() {
    for (spec, seed) in [(micro_spec(), 11u64), (micro_spec_2l(), 13)] {
        let host = init_params(&spec, seed);
        let be = HostBackend::new(spec.clone()).unwrap();
        let batch = random_batch(&spec, seed ^ 0xBA7C4);
        let out = be.fwd_bwd(&host, &batch).unwrap();
        assert!(out.loss.is_finite());
        let mut rng = Rng::new(seed ^ 0xFD);
        let eps = 1e-2f32;
        // probe two random coordinates of every registry parameter —
        // norms, attention, MLP, embed and head all get checked
        for (pi, p) in spec.params.iter().enumerate() {
            for _ in 0..2 {
                let j = rng.below(p.numel());
                let fd = fd_at(&be, &host, &batch, pi, j, eps);
                let an = out.grads[pi][j] as f64;
                assert!(
                    (fd - an).abs() <= 1.5e-3 + 0.02 * fd.abs().max(an.abs()),
                    "{} ({}): coord {j} analytic {an} vs fd {fd}",
                    p.name,
                    spec.config.name,
                );
            }
        }
    }
}

#[test]
fn directional_derivative_matches_gradient() {
    // aggregate check over ALL coordinates at once: d/dε L(p + ε·u)
    // must equal <∇L, u> for random directions u
    let spec = micro_spec();
    let host = init_params(&spec, 3);
    let be = HostBackend::new(spec.clone()).unwrap();
    let batch = random_batch(&spec, 17);
    let out = be.fwd_bwd(&host, &batch).unwrap();
    let mut rng = Rng::new(23);
    for trial in 0..4 {
        let dirs: Vec<Vec<f32>> = spec
            .params
            .iter()
            .map(|p| {
                let mut d = vec![0.0f32; p.numel()];
                rng.fill_normal(&mut d, 1.0);
                d
            })
            .collect();
        let eps = 5e-3f32;
        let mut plus = host.clone();
        let mut minus = host.clone();
        for (pi, dir) in dirs.iter().enumerate() {
            for (j, &u) in dir.iter().enumerate() {
                plus[pi][j] += eps * u;
                minus[pi][j] -= eps * u;
            }
        }
        let lp = be.loss_f64(&plus, &batch).unwrap();
        let lm = be.loss_f64(&minus, &batch).unwrap();
        let fd = (lp - lm) / (2.0 * eps as f64);
        let analytic: f64 = out
            .grads
            .iter()
            .zip(&dirs)
            .map(|(g, u)| {
                g.iter().zip(u).map(|(&a, &b)| (a as f64) * (b as f64)).sum::<f64>()
            })
            .sum();
        let tol = 2e-3 + 0.02 * analytic.abs().max(fd.abs());
        assert!(
            (fd - analytic).abs() <= tol,
            "trial {trial}: directional fd {fd} vs analytic {analytic}"
        );
    }
}

#[test]
fn sq_norms_equal_sum_of_squared_grads() {
    let spec = micro_spec_2l();
    let host = init_params(&spec, 5);
    let be = HostBackend::new(spec.clone()).unwrap();
    let batch = random_batch(&spec, 29);
    let out = be.fwd_bwd(&host, &batch).unwrap();
    assert_eq!(out.sq_norms.len(), spec.params.len());
    for (i, g) in out.grads.iter().enumerate() {
        let want: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let got = out.sq_norms[i] as f64;
        assert!(
            (want - got).abs() <= 1e-4 * want.max(1e-9),
            "param {i}: sq_norm {got} vs sum-of-squares {want}"
        );
    }
}

#[test]
fn all_zero_mask_is_safe() {
    // denom clamps to 1 (python: max(sum(mask), 1)); loss and grads are
    // all zero, not NaN
    let spec = micro_spec();
    let host = init_params(&spec, 7);
    let be = HostBackend::new(spec.clone()).unwrap();
    let mut batch = random_batch(&spec, 31);
    batch.mask.iter_mut().for_each(|m| *m = 0.0);
    let out = be.fwd_bwd(&host, &batch).unwrap();
    assert_eq!(out.loss, 0.0);
    for g in &out.grads {
        assert!(g.iter().all(|&x| x == 0.0));
    }
}

#[test]
fn out_of_vocab_tokens_are_rejected() {
    let spec = micro_spec();
    let host = init_params(&spec, 7);
    let be = HostBackend::new(spec.clone()).unwrap();
    let mut batch = random_batch(&spec, 37);
    batch.tokens[0] = spec.config.vocab as i32; // one past the end
    assert!(be.fwd_bwd(&host, &batch).is_err());
    let mut batch2 = random_batch(&spec, 37);
    batch2.targets[1] = -1;
    assert!(be.predict(&host, &batch2).is_err());
}

#[test]
fn predict_correct_flags_are_binary_and_loss_matches() {
    let spec = micro_spec_2l();
    let host = init_params(&spec, 9);
    let be = HostBackend::new(spec.clone()).unwrap();
    let batch = random_batch(&spec, 41);
    let a = be.fwd_bwd(&host, &batch).unwrap();
    let e = be.predict(&host, &batch).unwrap();
    assert!((a.loss - e.loss).abs() < 1e-5);
    assert_eq!(e.correct.len(), batch.batch * batch.seq_len);
    assert!(e.correct.iter().all(|&c| c == 0.0 || c == 1.0));
}

#[test]
fn fused_updates_match_ref_py_oracles() {
    // adam_update == ref.py::adam_ref; tail_update == momentum_tail_ref
    let spec = micro_spec();
    let mut be = HostBackend::new(spec).unwrap();
    let mut rng = Rng::new(43);
    let n = 24;
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let m: Vec<f32> = (0..n).map(|_| 0.1 * rng.normal() as f32).collect();
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() as f32).powi(2)).collect();
    let p0 = p.clone();
    let (m1, v1, sq) = be.adam_update(0, &mut p, &g, &m, &v, 1e-2).unwrap();
    let h = AdamHyper::default();
    let mut want_p = p0.clone();
    let mut st = AdamState { m: m.clone(), v: v.clone() };
    st.step(&mut want_p, &g, 1e-2, h);
    for i in 0..n {
        assert!((p[i] - want_p[i]).abs() < 1e-6);
        assert!((m1[i] - st.m[i]).abs() < 1e-7);
        assert!((v1[i] - st.v[i]).abs() < 1e-7);
    }
    let want_sq: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
    assert!((sq as f64 - want_sq).abs() < 1e-3 * want_sq);
    // momentum tail
    let mut p_tail = p.clone();
    be.tail_update(0, &mut p_tail, &m1, &v1, 1e-2).unwrap();
    let mut want_tail = p.clone();
    let st2 = AdamState { m: m1.clone(), v: v1.clone() };
    st2.momentum_tail(&mut want_tail, 1e-2, h);
    for i in 0..n {
        assert!((p_tail[i] - want_tail[i]).abs() < 1e-6);
    }
    // mismatched lengths are rejected
    assert!(be.adam_update(0, &mut p, &g[..n - 1], &m, &v, 1e-2).is_err());
}

#[test]
fn deterministic_across_runs() {
    let spec = micro_spec_2l();
    let host = init_params(&spec, 13);
    let be = HostBackend::new(spec.clone()).unwrap();
    let batch = random_batch(&spec, 47);
    let a = be.fwd_bwd(&host, &batch).unwrap();
    let b = be.fwd_bwd(&host, &batch).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.grads, b.grads);
    assert_eq!(a.sq_norms, b.sq_norms);
}
